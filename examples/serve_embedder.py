"""End-to-end serving driver: a small LM serves batched embedding requests
feeding a Manu collection — the paper's "embedding generation toolbox"
integrated with the database (DESIGN.md §4).

    PYTHONPATH=src python examples/serve_embedder.py [--requests 64]

Pipeline: (1) instantiate a reduced `yi-9b`-family embedder, (2) embed a
synthetic document corpus and ingest it through the log backbone, (3) serve
batched query requests: each batch is embedded by the jitted model and
searched with bounded staleness, with fresh documents streaming in
concurrently — demonstrating the delta-consistency trade-off end to end.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import ARCHS
from repro.core import ManuConfig, ManuSystem, Metric
from repro.models import model as M
from repro.models.embedder import Embedder


def synth_docs(rng, n, seq_len, vocab, n_topics=16):
    """Synthetic 'documents': topic-biased token streams (related docs share
    token distributions, so embeddings cluster meaningfully)."""
    topics = rng.integers(0, n_topics, n)
    toks = np.empty((n, seq_len), np.int32)
    for i, t in enumerate(topics):
        lo = (t * vocab) // n_topics
        hi = ((t + 1) * vocab) // n_topics
        toks[i] = rng.integers(lo, hi, seq_len)
    return toks, topics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--docs", type=int, default=512)
    args = ap.parse_args()

    cfg = ARCHS["yi-9b"].reduced(d_model=128, num_layers=2, vocab_size=512)
    params = M.init_params(cfg, jax.random.key(0))
    embedder = Embedder(cfg, params, max_batch=args.batch)
    print(f"embedder: {cfg.name}-reduced d={embedder.dim} "
          f"({sum(x.size for x in jax.tree_util.tree_leaves(params))/1e6:.1f}M params)")

    rng = np.random.default_rng(0)
    docs, topics = synth_docs(rng, args.docs, 32, cfg.vocab_size)
    t0 = time.time()
    doc_embeds = embedder.embed(docs)
    print(f"embedded {args.docs} docs in {time.time()-t0:.2f}s")

    manu = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=256))
    coll = manu.create_collection("docs", dim=embedder.dim, metric=Metric.IP)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 8, "nprobe": 4})
    coll.insert({"vector": doc_embeds})
    coll.flush()

    # serve batched requests while new docs stream in
    hits = 0
    lat = []
    for step in range(0, args.requests, args.batch):
        fresh, fresh_topics = synth_docs(rng, 8, 32, cfg.vocab_size)
        coll.insert({"vector": embedder.embed(fresh)})
        q_toks, q_topics = synth_docs(rng, args.batch, 32, cfg.vocab_size)
        t0 = time.perf_counter()
        q_emb = embedder.embed(q_toks)
        res = coll.search(q_emb, limit=5, staleness_ms=200.0)
        lat.append(time.perf_counter() - t0)
        # quality: does the top hit share the query's topic?
        for r in range(args.batch):
            top = res.pks[r][0]
            if top >= 0 and top < len(topics) and topics[top] == q_topics[r]:
                hits += 1
    total = args.requests
    print(f"served {total} requests in batches of {args.batch}: "
          f"mean latency {np.mean(lat)*1e3:.1f} ms/batch "
          f"(embed+search), topic-match@1 = {hits/total:.2f}")
    print("stats:", {k: v for k, v in manu.stats().items() if k != 'log'})


if __name__ == "__main__":
    main()
