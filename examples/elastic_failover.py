"""Elasticity + failure recovery demo (paper Figs 2/9, §3.6).

    PYTHONPATH=src python examples/elastic_failover.py

Replays a bursty workload against Manu: the latency-threshold autoscaler
adds/removes query nodes; mid-run we crash a node *mid-request* and show
the replica groups + HealthMonitor/StateReconciler loop restoring
identical results, introspected through the typed cluster-admin API.
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import ManuConfig, ManuSystem, SearchRequest


def main() -> None:
    rng = np.random.default_rng(0)
    system = ManuSystem(
        ManuConfig(num_query_nodes=3, seal_rows=1_000, replication_factor=2)
    )
    coll = system.create_collection("c", dim=64)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 16, "nprobe": 8})
    base = rng.standard_normal((8_000, 64)).astype(np.float32)
    for lo in range(0, 8_000, 2_000):
        coll.insert({"vector": base[lo : lo + 2_000]})
    coll.flush()
    q = rng.standard_normal((8, 64)).astype(np.float32)
    coll.search(q, limit=10)  # warmup

    d = coll.describe()
    print(f"collection {d.name!r}: {d.num_entities} rows, "
          f"replication_factor={d.replication_factor}, "
          f"index={d.index_on('vector').kind}")

    def live_nodes():
        return system.cluster_state().live_node_ids

    print("\n== elastic scaling on a bursty trace ==")
    for phase, load in enumerate([1, 4, 16, 16, 4, 1]):
        t0 = time.perf_counter()
        for _ in range(load):
            coll.search(q, limit=10)
        ms = (time.perf_counter() - t0) * 1e3 / max(len(live_nodes()), 1)
        action = "-"
        if ms > 60 and len(live_nodes()) < 8:
            system.add_query_node()
            action = "scale-up"
        elif ms < 15 and len(live_nodes()) > 2:
            system.remove_query_node()
            action = "scale-down"
        print(f"phase {phase}: load={load:>2} latency/node={ms:6.1f}ms "
              f"nodes={len(live_nodes())} action={action}")

    print("\n== mid-request failover ==")
    before = coll.search(q, limit=10, staleness_ms=0.0)
    cs = system.cluster_state()
    victim_id = next(p.replicas[0] for p in cs.placement if p.replicas)
    print(f"placement before: "
          f"{[(p.segment_id, p.replicas) for p in cs.placement]}")
    victim = system.query_nodes[victim_id]

    def dying(request):  # the node dies between planning and scan
        victim.alive = False
        raise RuntimeError("injected crash mid-request")

    victim.search_request = dying
    print(f"crashing {victim_id} mid-request ...")
    after = coll.search(q, limit=10, staleness_ms=0.0)
    same = (np.sort(before.pks, 1) == np.sort(after.pks, 1)).all()

    cs = system.cluster_state()
    statuses = {n.node_id: n.status for n in cs.nodes}
    reassigned = all(victim_id not in p.replicas for p in cs.placement)
    print(f"results identical: {same}")
    print(f"node statuses: {statuses}")
    print(f"dead node out of every replica group: {reassigned}; "
          f"under-replicated segments: {cs.under_replicated}")
    assert same and reassigned
    print("placement after:  "
          f"{[(p.segment_id, p.replicas) for p in cs.placement]}")

    print("\n== traced failover: the span tree of a crash mid-request ==")
    while len(live_nodes()) < 2:  # the crash needs a surviving replica
        system.add_query_node()
    system.run_until_idle()  # survivors finish loading healed replicas
    event_mark = system.clock.now_ms()
    victim2_id = next(p.replicas[0] for p in system.cluster_state().placement
                      if p.replicas)
    victim2 = system.query_nodes[victim2_id]

    def dying2(request):
        victim2.alive = False
        raise RuntimeError("injected crash mid-request")

    victim2.search_request = dying2
    print(f"crashing {victim2_id} mid-request, trace=True ...")
    traced = coll.search(
        SearchRequest.single(q, field="vector", k=10, staleness_ms=0.0,
                             trace=True)
    )
    assert (np.sort(before.pks, 1) == np.sort(traced.pks, 1)).all()
    print(traced.trace.format())

    print("\n== control-plane event log of the failover ==")
    for e in system.events(since_ts=event_mark):
        print(f"  {e.ts_ms:>9.0f} {e.kind:<22} {e.source:<13} {e.detail}")

    print("\n== kill -9, per-node restart, then whole-system restart ==")
    expect = np.sort(coll.search(q, limit=10, staleness_ms=0.0).pks, 1)
    system.kill_logger("logger-0")   # a Crash runs no cleanup: claims and
    system.kill_data_node("dn-0")    # half-written state leak, on purpose
    print("killed logger-0 and dn-0 (simulated kill -9, no cleanup ran)")
    system.restart_logger("logger-0")
    system.restart_data_node("dn-0")  # re-subscribes from its WAL checkpoint
    got = np.sort(coll.search(q, limit=10, staleness_ms=0.0).pks, 1)
    print(f"after node restarts, results identical: {(got == expect).all()}")

    # Tear down EVERY process and rebuild coordinators, nodes and serving
    # state from the meta store + object store + WAL replay alone.
    report = system.restart()
    coll = system.collections["c"]  # collection handles are rebuilt too
    got = np.sort(coll.search(q, limit=10, staleness_ms=0.0).pks, 1)
    print(f"whole-system restart: tso_frontier={report['tso_frontier']} "
          f"seals_reconciled={report['seals_reconciled']} "
          f"results identical: {(got == expect).all()}")
    assert (got == expect).all()

    print("\n== serving latency from the metrics registry ==")
    h = system.metrics().histogram("proxy_search_latency_us")
    print(f"  searches={h.count} p50={h.p50:.0f}us p95={h.p95:.0f}us "
          f"p99={h.p99:.0f}us")


if __name__ == "__main__":
    main()
