"""Elasticity + failure recovery demo (paper Figs 2/9, §3.6).

    PYTHONPATH=src python examples/elastic_failover.py

Replays a bursty workload against Manu: the latency-threshold autoscaler
adds/removes query nodes; mid-run we crash a node holding live segments and
show the coordinator's failover restoring identical results.
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import ManuConfig, ManuSystem


def main() -> None:
    rng = np.random.default_rng(0)
    system = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=1_000))
    coll = system.create_collection("c", dim=64)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 16, "nprobe": 8})
    base = rng.standard_normal((8_000, 64)).astype(np.float32)
    for lo in range(0, 8_000, 2_000):
        coll.insert({"vector": base[lo : lo + 2_000]})
    coll.flush()
    q = rng.standard_normal((8, 64)).astype(np.float32)
    coll.search(q, limit=10)  # warmup

    def live_nodes():
        return [n for n, qn in system.query_nodes.items() if qn.alive]

    print("== elastic scaling on a bursty trace ==")
    for phase, load in enumerate([1, 4, 16, 16, 4, 1]):
        t0 = time.perf_counter()
        for _ in range(load):
            coll.search(q, limit=10)
        ms = (time.perf_counter() - t0) * 1e3 / max(len(live_nodes()), 1)
        action = "-"
        if ms > 60 and len(live_nodes()) < 8:
            system.add_query_node()
            action = "scale-up"
        elif ms < 15 and len(live_nodes()) > 2:
            system.remove_query_node()
            action = "scale-down"
        print(f"phase {phase}: load={load:>2} latency/node={ms:6.1f}ms "
              f"nodes={len(live_nodes())} action={action}")

    print("\n== failure recovery ==")
    before = coll.search(q, limit=10, staleness_ms=0.0)
    victim = next(iter(system.query_coord.assignment.values()))
    held = system.query_nodes[victim].held_segments("c")
    print(f"crashing {victim} (held segments {held})")
    system.kill_query_node(victim)
    dead = system.recover_failures()
    after = coll.search(q, limit=10, staleness_ms=0.0)
    same = (np.sort(before.pks, 1) == np.sort(after.pks, 1)).all()
    print(f"coordinator declared dead: {dead}; results identical: {same}")
    assert same
    print("segments now held by:",
          {n: qn.held_segments('c') for n, qn in system.query_nodes.items() if qn.alive})


if __name__ == "__main__":
    main()
