"""Quickstart: the declarative request API end to end.

    PYTHONPATH=src python examples/quickstart.py

Creates a multi-vector collection (a text embedding + an image embedding +
a price attribute), streams inserts through the log backbone, builds one
IVF index per vector field, then exercises the typed ``SearchRequest``
surface: consistency levels, hybrid (multi-vector) search under weighted
and RRF fusion, filtered range search, output-field hydration, and time
travel — plus the legacy kwarg facade, which runs through the exact same
pipeline.  Ends with the serving tier: async micro-batched ingest under
typed backpressure and plan-shape-grouped batched reads.
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    AdmissionRejected,
    AnnsQuery,
    ConsistencyLevel,
    FieldSchema,
    FieldType,
    InsertRequest,
    ManuConfig,
    ManuSystem,
    Metric,
    Ranker,
    SearchRequest,
)


def main() -> None:
    # The small ingest queue makes the serving-tier scene below actually
    # hit backpressure (AdmissionRejected) with 200-row async chunks.
    manu = ManuSystem(ManuConfig(num_query_nodes=2, num_index_nodes=1,
                                 seal_rows=1_000, slice_rows=512,
                                 ingest_queue_rows=512,
                                 ingest_flush_rows=1_024))
    coll = manu.create_collection(
        "products", dim=64, metric=Metric.L2,
        extra_fields=[
            FieldSchema("img_vec", FieldType.VECTOR, dim=32),
            FieldSchema("price", FieldType.FLOAT),
        ],
    )
    # One index spec per vector field (paper §3.5: per-field build tasks).
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 16, "nprobe": 8})
    coll.create_index("img_vec", kind="ivf_flat", params={"nlist": 8, "nprobe": 8})

    rng = np.random.default_rng(0)
    text_vecs = rng.standard_normal((5_000, 64)).astype(np.float32)
    img_vecs = rng.standard_normal((5_000, 32)).astype(np.float32)
    prices = rng.uniform(1, 500, 5_000)
    for lo in range(0, 5_000, 1_000):
        coll.insert({"vector": text_vecs[lo:lo + 1_000],
                     "img_vec": img_vecs[lo:lo + 1_000],
                     "price": prices[lo:lo + 1_000]})
    print(f"ingested 5000 rows; sealed segments: "
          f"{manu.data_coord.sealed_segments('products')}")

    tq = rng.standard_normal((1, 64)).astype(np.float32)
    iq = rng.standard_normal((1, 32)).astype(np.float32)

    # ---- consistency: named levels or an explicit staleness bound -------
    strong = coll.search(SearchRequest.single(tq, k=5,
                                              consistency=ConsistencyLevel.STRONG))
    bounded = coll.search(SearchRequest.single(tq, k=5, staleness_ms=100.0))
    eventual = coll.search(SearchRequest.single(tq, k=5))
    print("strong   :", strong.pks[0])
    print("bounded  :", bounded.pks[0])
    print("eventual :", eventual.pks[0])

    # ---- hybrid multi-vector search ------------------------------------
    weighted = coll.search(SearchRequest(
        anns=[AnnsQuery("vector", tq, weight=0.7),
              AnnsQuery("img_vec", iq, weight=0.3)],
        k=5, staleness_ms=0.0, output_fields=("price",),
    ))
    rrf = coll.hybrid_search(
        [AnnsQuery("vector", tq), AnnsQuery("img_vec", iq)],
        limit=5, ranker=Ranker.rrf(), staleness_ms=0.0,
    )
    print("hybrid weighted :", weighted.pks[0],
          "prices:", np.round(weighted.fields["price"][0], 1))
    print("hybrid rrf      :", rrf.pks[0])

    # ---- filtered range search -----------------------------------------
    radius = float(np.sort(strong.scores[0])[-1]) * 1.2
    cheap_near = coll.search(SearchRequest.single(
        tq, k=10, staleness_ms=0.0, filter="price < 50", radius=radius,
        output_fields=("price",),
    ))
    live = cheap_near.pks[0][cheap_near.pks[0] >= 0]
    print(f"price<50 within radius {radius:.1f}:", live,
          "prices:", np.round(cheap_near.fields["price"][0][:len(live)], 1))

    # ---- selectivity-adaptive filtered search ---------------------------
    # Every sealed segment carries attribute-index satellites (built at
    # seal, persisted next to the binlog).  The planner estimates each
    # filter's selectivity per segment and picks a strategy: pre-filter
    # (bitmap-masked scan), post-filter (inflated-k scan, then cut), or
    # brute (gather the few surviving rows).  ``filter_strategy`` forces
    # one globally — "price < 25" is tight, so adaptive chooses brute and
    # matches the forced-brute answer exactly; pre/post run the IVF index
    # (approximate at nprobe < nlist) and may differ.
    by_strategy = {}
    for strategy in (None, "pre", "post", "brute"):
        by_strategy[strategy] = coll.search(SearchRequest.single(
            tq, k=5, staleness_ms=0.0, filter="price < 25",
            filter_strategy=strategy,
        ))
    assert np.array_equal(by_strategy[None].pks, by_strategy["brute"].pks)
    chosen = {k.split('"')[1]: int(v)
              for k, v in manu.metrics().counters.items()
              if k.startswith("filter_strategy_total")}
    print("price<25 top-5 :", by_strategy[None].pks[0],
          "strategy picks:", chosen)

    # ---- deletes, MVCC, time travel ------------------------------------
    victims = strong.pks[0][:2]
    coll.delete(victims)
    after = coll.search(tq, limit=5, staleness_ms=0.0)  # legacy facade
    print(f"deleted {victims}; new top-5: {after.pks[0]}")

    manu.checkpoint_collection("products")
    rollback = coll.search(tq, limit=5, time_travel_ts=strong.query_ts)
    print("time-travel top-5 (deleted rows resurrected):", rollback.pks[0])
    assert set(victims.tolist()) <= set(rollback.pks[0].tolist())

    # ---- upsert: atomic replace at one timestamp ------------------------
    # Replace the current best match's vectors in ONE WAL record: the old
    # version dies and the new one appears at the same LSN, and the
    # MutationResult watermark feeds a read-your-writes SESSION search.
    target = int(after.pks[0][0])
    res = coll.upsert({
        "pk": np.array([target]),
        "vector": rng.standard_normal((1, 64)).astype(np.float32),
        "img_vec": rng.standard_normal((1, 32)).astype(np.float32),
        "price": np.array([9.99]),
    })
    fresh = coll.search(res.session_request(tq, k=5))
    print(f"upserted pk={target} at LSN {res.watermark_ts}; "
          f"session top-5: {fresh.pks[0]}")
    was = coll.search(tq, limit=5, time_travel_ts=res.watermark_ts - 1)
    print("one tick earlier the old version still answers:", was.pks[0])

    # ---- partitions: placement + pruned search --------------------------
    catalog = manu.create_collection("catalog", dim=16, seal_rows=500)
    for season in ("summer", "winter"):
        catalog.create_partition(season)
    summer = rng.standard_normal((1_000, 16)).astype(np.float32)
    winter = rng.standard_normal((1_000, 16)).astype(np.float32)
    catalog.insert(InsertRequest({"vector": summer}, partition="summer"))
    catalog.insert(InsertRequest({"vector": winter}, partition="winter"))
    catalog.flush()
    cq = rng.standard_normal((1, 16)).astype(np.float32)
    everywhere = catalog.search(cq, limit=5, staleness_ms=0.0)
    only_summer = catalog.search(SearchRequest.single(
        cq, k=5, staleness_ms=0.0, partition_names=("summer",),
    ))
    print("catalog partitions:", catalog.partitions())
    print("all partitions :", everywhere.pks[0])
    print("summer only    :", only_summer.pks[0],
          "(planner skipped every winter segment)")

    # ---- serving tier: async mixed workload -----------------------------
    # Writes enter through the request scheduler's bounded queues and are
    # micro-batched cross-user into single WAL crossings; a full queue
    # rejects at admission time with the typed AdmissionRejected error.
    # Reads queue in the batcher and group by plan shape: one proxy search
    # per group, split back per request.
    jobs = manu.create_collection("jobs", dim=16,
                                  extra_fields=[FieldSchema("price",
                                                            FieldType.FLOAT)])
    tickets = []
    for _ in range(6):
        chunk = {"vector": rng.standard_normal((200, 16)).astype(np.float32),
                 "price": rng.uniform(1, 100, 200)}
        try:
            tickets.append(jobs.insert_async(chunk))
        except AdmissionRejected as e:
            print(f"backpressure: {e.pending_rows}/{e.capacity_rows} rows "
                  f"pending on shard {e.shard}; flushing")
            manu.flush_ingest()  # returns the credits
            tickets.append(jobs.insert_async(chunk))
    manu.flush_ingest()
    lsns = [t.result().watermark_ts for t in tickets]
    assert len(set(lsns)) == len(tickets)  # one LSN per request, batched WAL
    print(f"async-ingested {6 * 200} rows in "
          f"{int(manu.metrics().counters.get('logger_batches_total', 0))} "
          f"WAL batch crossings; one LSN each: {lsns}")

    jq = rng.standard_normal((3, 16)).astype(np.float32)
    idx_cheap = [manu.batcher.submit_request(jobs.info, SearchRequest.single(
        jq[i:i + 1], field="vector", k=3, staleness_ms=0.0,
        filter="price < 50", output_fields=("price",))) for i in range(3)]
    idx_bounded = manu.batcher.submit_request(jobs.info, SearchRequest.single(
        jq[:1], field="vector", k=3, consistency=ConsistencyLevel.BOUNDED))
    batched = manu.batcher.flush(wait_fn=manu._cooperative_wait)
    print("batched cheap top-3:", [batched[i].pks[0] for i in idx_cheap],
          "| bounded top-3:", batched[idx_bounded].pks[0])

    print("\nsystem stats:", {k: v for k, v in manu.stats().items() if k != "log"})


if __name__ == "__main__":
    main()
