"""Quickstart: the PyManu-style API end to end.

    PYTHONPATH=src python examples/quickstart.py

Creates a collection, streams inserts through the log backbone, builds an
IVF index on sealed segments, searches under three consistency levels,
deletes, filters by attribute, and time-travels to before the delete.
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import FieldSchema, FieldType, ManuConfig, ManuSystem, Metric


def main() -> None:
    manu = ManuSystem(ManuConfig(num_query_nodes=2, num_index_nodes=1,
                                 seal_rows=1_000, slice_rows=512))
    coll = manu.create_collection(
        "products", dim=64, metric=Metric.L2,
        extra_fields=[FieldSchema("price", FieldType.FLOAT)],
    )
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 16, "nprobe": 8})

    rng = np.random.default_rng(0)
    vectors = rng.standard_normal((5_000, 64)).astype(np.float32)
    prices = rng.uniform(1, 500, 5_000)
    for lo in range(0, 5_000, 1_000):
        coll.insert({"vector": vectors[lo : lo + 1_000],
                     "price": prices[lo : lo + 1_000]})
    print(f"ingested 5000 rows; sealed segments: "
          f"{manu.data_coord.sealed_segments('products')}")

    query = rng.standard_normal((1, 64)).astype(np.float32)

    strong = coll.search(query, limit=5, staleness_ms=0.0)
    bounded = coll.search(query, limit=5, staleness_ms=100.0)
    eventual = coll.search(query, limit=5)  # default: eventual
    print("strong   :", strong.pks[0])
    print("bounded  :", bounded.pks[0])
    print("eventual :", eventual.pks[0])

    cheap = coll.query(query, limit=5, expr="price < 50", staleness_ms=0.0)
    print("price<50 :", cheap.pks[0], "prices:", np.round(prices[cheap.pks[0][cheap.pks[0] >= 0]], 1))

    victims = strong.pks[0][:2]
    coll.delete(victims)
    after = coll.search(query, limit=5, staleness_ms=0.0)
    print(f"deleted {victims}; new top-5: {after.pks[0]}")

    manu.checkpoint_collection("products")
    rollback = coll.search(query, limit=5, time_travel_ts=strong.query_ts)
    print("time-travel top-5 (deleted rows resurrected):", rollback.pks[0])
    assert set(victims.tolist()) <= set(rollback.pks[0].tolist())

    print("\nsystem stats:", {k: v for k, v in manu.stats().items() if k != "log"})


if __name__ == "__main__":
    main()
