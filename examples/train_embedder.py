"""Fault-tolerant embedder training with checkpoint/restart + ingestion.

    PYTHONPATH=src python examples/train_embedder.py --steps 120
    PYTHONPATH=src python examples/train_embedder.py --steps 120 --crash-at 60
    # run again with the same args: training RESUMES from the last committed
    # checkpoint in the object store.

Trains a small LM on a synthetic corpus (loss visibly decreases), commits
step-atomic checkpoints to the object store, optionally simulates a crash,
resumes, and finally embeds + ingests the corpus into Manu.  Scale knobs:
--preset full trains a ~100M-parameter model (hours on CPU; the default
preset finishes in minutes).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import ARCHS
from repro.core import ManuConfig, ManuSystem, Metric
from repro.core.object_store import FileObjectStore
from repro.models import model as M
from repro.models.embedder import Embedder
from repro.train.loop import TrainConfig, train

import jax


def build_cfg(preset: str):
    if preset == "full":
        # ~100M params (paper-scale end-to-end driver; slow on CPU)
        return ARCHS["yi-9b"].reduced(
            d_model=512, num_layers=12, num_heads=8, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=8192,
        )
    return ARCHS["yi-9b"].reduced(d_model=128, num_layers=2, vocab_size=512)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate preemption after N steps (restart resumes)")
    ap.add_argument("--preset", choices=["small", "full"], default="small")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpts")
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    store = FileObjectStore(args.ckpt_dir)
    tc = TrainConfig(steps=args.steps, batch=8, seq_len=64,
                     checkpoint_every=20, run_name=f"embedder-{args.preset}")

    crash = {"at": args.crash_at}

    def on_step(step, loss):
        if crash["at"] and step + 1 >= crash["at"]:
            print(f"[train] simulating node failure at step {step+1} "
                  f"(rerun this script: it resumes from the last checkpoint)")
            raise SystemExit(17)

    params, _opt, losses = train(cfg, store, tc, on_step=on_step)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")

    embedder = Embedder(cfg, params)
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cfg.vocab_size, (256, 64)).astype(np.int32)
    embeds = embedder.embed(corpus)

    manu = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=128))
    coll = manu.create_collection("corpus", dim=cfg.d_model, metric=Metric.IP)
    coll.insert({"vector": embeds})
    coll.flush()
    res = coll.search(embeds[:3], limit=3, staleness_ms=0.0)
    print("self-retrieval sanity (row i should find pk i):", res.pks[:, 0])
    assert (res.pks[:, 0] == np.arange(3)).all()
    print("trained, checkpointed, ingested, searchable — done")


if __name__ == "__main__":
    main()
