"""Mini-batch / Lloyd k-means on top of the ``kmeans_assign`` kernel.

Used by: IVF coarse quantizer, PQ codebook training, and the bucket index's
hierarchical clustering.  k-means++-style seeding (D^2 sampling) for quality,
empty-cluster re-seeding, early stop on assignment stability.
"""

from __future__ import annotations

import numpy as np

from ..kernels import ops


def kmeanspp_seed(
    x: np.ndarray, k: int, rng: np.random.Generator, sample_cap: int = 4096
) -> np.ndarray:
    """D^2-weighted seeding on a subsample (full k-means++ is O(nk))."""
    n = len(x)
    if n > sample_cap:
        x = x[rng.choice(n, sample_cap, replace=False)]
        n = sample_cap
    centroids = np.empty((k, x.shape[1]), np.float32)
    centroids[0] = x[rng.integers(n)]
    d2 = np.sum((x - centroids[0]) ** 2, axis=1)
    for i in range(1, k):
        probs = d2 / max(d2.sum(), 1e-12)
        centroids[i] = x[rng.choice(n, p=probs)]
        d2 = np.minimum(d2, np.sum((x - centroids[i]) ** 2, axis=1))
    return centroids


def kmeans(
    x: np.ndarray,
    k: int,
    max_iters: int = 25,
    seed: int = 0,
    tol: float = 1e-4,
    sample_cap: int = 100_000,
) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd iterations; returns (centroids [k,d], assignments [n]).

    Training runs on a subsample (paper §4.2: 'sampling a subset of the
    collection for the trials'); the final assignment covers all rows.
    """
    x = np.asarray(x, np.float32)
    n = len(x)
    if n == 0:
        raise ValueError("kmeans on empty data")
    k = min(k, n)
    rng = np.random.default_rng(seed)

    train = x if n <= sample_cap else x[rng.choice(n, sample_cap, replace=False)]
    centroids = kmeanspp_seed(train, k, rng)

    prev_inertia = np.inf
    for _ in range(max_iters):
        assign, d2 = ops.kmeans_assign(train, centroids)
        inertia = float(d2.sum())
        # M-step via bincount (vectorized mean per cluster)
        counts = np.bincount(assign, minlength=k).astype(np.float32)
        sums = np.zeros((k, x.shape[1]), np.float32)
        np.add.at(sums, assign, train)
        nonempty = counts > 0
        centroids[nonempty] = sums[nonempty] / counts[nonempty, None]
        # Re-seed empty clusters from the farthest points
        n_empty = int((~nonempty).sum())
        if n_empty:
            far = np.argsort(-d2)[:n_empty]
            centroids[~nonempty] = train[far]
        if prev_inertia - inertia <= tol * max(prev_inertia, 1e-12):
            break
        prev_inertia = inertia

    assign_full, _ = ops.kmeans_assign(x, centroids)
    return centroids.astype(np.float32), assign_full


def balanced_kmeans(
    x: np.ndarray,
    target_cluster_size: int,
    max_cluster_size: int,
    seed: int = 0,
    max_depth: int = 8,
) -> tuple[np.ndarray, np.ndarray]:
    """Hierarchical k-means with bounded cluster sizes (bucket index, §4.4).

    Recursively splits clusters larger than ``max_cluster_size`` so every
    final bucket fits the paper's 4KB-page analogue (a VMEM tile quantum).
    Returns (centroids [B,d], assignments [n] -> bucket id).
    """
    x = np.asarray(x, np.float32)
    n = len(x)
    k0 = max(1, int(round(n / max(target_cluster_size, 1))))
    centroids, assign = kmeans(x, k0, seed=seed)

    final_centroids: list[np.ndarray] = []
    final_assign = np.full(n, -1, np.int64)

    stack: list[tuple[np.ndarray, int]] = []  # (row indices, depth)
    for c in range(len(centroids)):
        stack.append((np.nonzero(assign == c)[0], 0))

    while stack:
        rows, depth = stack.pop()
        if len(rows) == 0:
            continue
        if len(rows) <= max_cluster_size or depth >= max_depth or len(rows) <= 1:
            bucket_id = len(final_centroids)
            final_centroids.append(x[rows].mean(axis=0))
            final_assign[rows] = bucket_id
            continue
        sub_k = max(2, int(np.ceil(len(rows) / target_cluster_size)))
        sub_c, sub_a = kmeans(x[rows], sub_k, seed=seed + depth + len(rows))
        for c in range(len(sub_c)):
            stack.append((rows[sub_a == c], depth + 1))

    return np.stack(final_centroids).astype(np.float32), final_assign
