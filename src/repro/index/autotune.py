"""BOHB-style automatic index-parameter configuration (paper §4.2).

Bayesian Optimization with Hyperband: successive halving allocates budget
(training-sample size) across configurations; a TPE-lite density model
(good/bad quantile split, Gaussian KDE per dimension) proposes new
candidates near historically good regions.  Users supply a utility function
over (recall, qps) and a total budget.

No external dependency — this is a faithful, self-contained BOHB-lite.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from ..core.collection import Metric
from .base import IndexSpec
from .registry import create_index


@dataclass
class ParamSpace:
    """Discrete/log-int search dimensions: name -> sorted candidate values."""

    dims: dict[str, list[Any]]

    def sample(self, rng: np.random.Generator) -> dict[str, Any]:
        return {k: v[rng.integers(len(v))] for k, v in self.dims.items()}

    def index_of(self, cfg: dict[str, Any]) -> np.ndarray:
        return np.array(
            [self.dims[k].index(cfg[k]) for k in sorted(self.dims)], dtype=np.float64
        )

    def from_indices(self, idx: np.ndarray) -> dict[str, Any]:
        keys = sorted(self.dims)
        return {
            k: self.dims[k][int(np.clip(round(i), 0, len(self.dims[k]) - 1))]
            for k, i in zip(keys, idx)
        }


DEFAULT_SPACES: dict[str, ParamSpace] = {
    "ivf_flat": ParamSpace({"nlist": [16, 32, 64, 128, 256], "nprobe": [1, 2, 4, 8, 16, 32]}),
    "ivf_sq": ParamSpace({"nlist": [16, 32, 64, 128, 256], "nprobe": [1, 2, 4, 8, 16, 32]}),
    "ivf_pq": ParamSpace({"nlist": [16, 32, 64], "nprobe": [2, 4, 8, 16], "m": [4, 8, 16]}),
    "hnsw": ParamSpace({"m": [8, 16, 32], "ef_construction": [50, 100, 200], "ef_search": [16, 32, 64, 128]}),
    "bucket": ParamSpace({"target_bucket_rows": [48, 96, 120], "replicas": [1, 2, 3], "nprobe_buckets": [4, 8, 16, 32]}),
}


@dataclass
class Trial:
    config: dict[str, Any]
    budget_rows: int
    utility: float
    recall: float
    qps: float


@dataclass
class TuneResult:
    best_config: dict[str, Any]
    best_utility: float
    trials: list[Trial] = field(default_factory=list)


def evaluate_config(
    kind: str,
    metric: Metric,
    config: dict[str, Any],
    base: np.ndarray,
    queries: np.ndarray,
    gt_idx: np.ndarray,
    k: int,
) -> tuple[float, float]:
    """Returns (recall@k, qps) for one configuration on one budget slice."""
    idx = create_index(IndexSpec(kind=kind, metric=metric, params=config))
    idx.build(base)
    t0 = time.perf_counter()
    _s, found = idx.search(queries, k)
    dt = max(time.perf_counter() - t0, 1e-9)
    qps = len(queries) / dt
    hits = 0
    for r in range(len(queries)):
        hits += len(set(found[r].tolist()) & set(gt_idx[r].tolist()))
    recall = hits / (len(queries) * k)
    return recall, qps


def _tpe_propose(
    space: ParamSpace,
    history: list[Trial],
    rng: np.random.Generator,
    n_candidates: int = 24,
    gamma: float = 0.3,
) -> dict[str, Any]:
    """TPE-lite: sample candidates, score by good/bad KDE ratio."""
    if len(history) < 6:
        return space.sample(rng)
    utilities = np.array([t.utility for t in history])
    cut = np.quantile(utilities, 1 - gamma)
    good = np.stack([space.index_of(t.config) for t in history if t.utility >= cut])
    bad_trials = [t for t in history if t.utility < cut]
    bad = (
        np.stack([space.index_of(t.config) for t in bad_trials])
        if bad_trials
        else np.zeros((1, good.shape[1]))
    )

    def kde(pts: np.ndarray, x: np.ndarray) -> float:
        bw = 1.0
        d2 = np.sum((pts - x[None, :]) ** 2, axis=1)
        return float(np.exp(-d2 / (2 * bw * bw)).mean() + 1e-9)

    best_cfg, best_score = None, -np.inf
    for _ in range(n_candidates):
        cfg = space.sample(rng)
        x = space.index_of(cfg)
        score = kde(good, x) / kde(bad, x)
        if score > best_score:
            best_cfg, best_score = cfg, score
    return best_cfg


def bohb_tune(
    kind: str,
    base: np.ndarray,
    queries: np.ndarray,
    metric: Metric = Metric.L2,
    k: int = 10,
    utility: Callable[[float, float], float] | None = None,
    max_trials: int = 16,
    min_budget_rows: int = 2_000,
    eta: int = 2,
    seed: int = 0,
    space: ParamSpace | None = None,
) -> TuneResult:
    """Hyperband outer loop + TPE proposals (paper §4.2).

    Budget = number of base rows used for the trial build; successive
    halving promotes the best configs to larger row budgets.
    """
    from .flat import FlatIndex

    rng = np.random.default_rng(seed)
    space = space or DEFAULT_SPACES[kind]
    utility = utility or (lambda recall, qps: recall + 0.05 * np.log10(max(qps, 1.0)))

    max_budget = len(base)
    budgets = [min(min_budget_rows * (eta ** i), max_budget) for i in range(8)]
    budgets = sorted(set(b for b in budgets if b <= max_budget)) or [max_budget]

    # Ground truth per budget slice, computed once with FLAT.
    gt_cache: dict[int, np.ndarray] = {}

    def gt_for(b: int) -> np.ndarray:
        if b not in gt_cache:
            flat = FlatIndex(metric=metric)
            flat.build(base[:b])
            _s, i = flat.search(queries, k)
            gt_cache[b] = i
        return gt_cache[b]

    history: list[Trial] = []
    n_initial = max(2, max_trials // 2)
    ladder: list[dict[str, Any]] = [
        _tpe_propose(space, history, rng) for _ in range(n_initial)
    ]
    trials_done = 0
    rung = 0
    while trials_done < max_trials and ladder:
        b = budgets[min(rung, len(budgets) - 1)]
        scored: list[tuple[float, dict[str, Any]]] = []
        for cfg in ladder:
            if trials_done >= max_trials:
                break
            recall, qps = evaluate_config(kind, metric, cfg, base[:b], queries, gt_for(b), k)
            u = utility(recall, qps)
            history.append(Trial(cfg, b, u, recall, qps))
            scored.append((u, cfg))
            trials_done += 1
        scored.sort(key=lambda t: -t[0])
        keep = max(1, len(scored) // eta)
        ladder = [cfg for _u, cfg in scored[:keep]]
        if len(ladder) <= 1 and trials_done < max_trials:
            ladder.append(_tpe_propose(space, history, rng))
        rung += 1

    best = max(history, key=lambda t: t.utility)
    return TuneResult(best_config=best.config, best_utility=best.utility, trials=history)
