"""Index factory keyed by kind string (what index nodes instantiate)."""

from __future__ import annotations

from .base import IndexSpec, VectorIndex
from .bucket import BucketIndex
from .flat import FlatIndex, SQIndex
from .hnsw import HNSWIndex
from .ivf import IVFFlatIndex, IVFPQIndex, IVFSQIndex
from .pq import OPQIndex, PQIndex

INDEX_KINDS: dict[str, type[VectorIndex]] = {
    FlatIndex.KIND: FlatIndex,
    SQIndex.KIND: SQIndex,
    PQIndex.KIND: PQIndex,
    OPQIndex.KIND: OPQIndex,
    IVFFlatIndex.KIND: IVFFlatIndex,
    IVFSQIndex.KIND: IVFSQIndex,
    IVFPQIndex.KIND: IVFPQIndex,
    HNSWIndex.KIND: HNSWIndex,
    BucketIndex.KIND: BucketIndex,
}


def create_index(spec: IndexSpec) -> VectorIndex:
    cls = INDEX_KINDS.get(spec.kind)
    if cls is None:
        raise KeyError(f"unknown index kind '{spec.kind}'; have {sorted(INDEX_KINDS)}")
    return cls(metric=spec.metric, **spec.normalized_params())
