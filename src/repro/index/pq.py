"""Product quantization (PQ) and optimized PQ (OPQ) indexes.

PQ splits each d-dim vector into ``m`` sub-vectors quantized against
per-subspace codebooks of ``ksub`` centroids; search computes per-query
lookup tables and scans codes with the ADC Pallas kernel.  OPQ learns an
orthogonal rotation R minimizing quantization error before PQ-encoding
(alternating R via SVD / codebooks via k-means, Ge et al. 2013).
"""

from __future__ import annotations

import numpy as np

from ..core.collection import Metric
from ..kernels import ops
from .base import VectorIndex, normalize_if_cosine
from .kmeans import kmeans


def train_pq_codebooks(
    x: np.ndarray, m: int, ksub: int, seed: int = 0, iters: int = 15
) -> np.ndarray:
    n, d = x.shape
    if d % m != 0:
        raise ValueError(f"dim {d} not divisible by m={m}")
    dsub = d // m
    codebooks = np.empty((m, ksub, dsub), np.float32)
    for j in range(m):
        sub = x[:, j * dsub : (j + 1) * dsub]
        codebooks[j], _ = kmeans(sub, ksub, max_iters=iters, seed=seed + j)
    return codebooks


def pq_encode(x: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    m, ksub, dsub = codebooks.shape
    codes = np.empty((len(x), m), np.int32)
    for j in range(m):
        sub = x[:, j * dsub : (j + 1) * dsub]
        assign, _ = ops.kmeans_assign(sub, codebooks[j])
        codes[:, j] = assign
    return codes


def pq_decode(codes: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    m, ksub, dsub = codebooks.shape
    out = np.empty((len(codes), m * dsub), np.float32)
    for j in range(m):
        out[:, j * dsub : (j + 1) * dsub] = codebooks[j][codes[:, j]]
    return out


def adc_tables(queries: np.ndarray, codebooks: np.ndarray, metric: Metric) -> np.ndarray:
    """Per-query ADC lookup tables [nq, m, ksub].

    One batched contraction over all subspaces at once — the batched IVF-PQ
    pipeline feeds every (query, probed-list) residual pair through a single
    call, so the per-subspace gemm loop would dominate at nq*nprobe rows.
    """
    m, ksub, dsub = codebooks.shape
    q = np.ascontiguousarray(np.asarray(queries, np.float32)).reshape(-1, m, dsub)
    dots = np.einsum("nmd,mkd->nmk", q, codebooks, optimize=True)
    if metric is Metric.L2:
        q2 = np.einsum("nmd,nmd->nm", q, q)
        c2 = np.einsum("mkd,mkd->mk", codebooks, codebooks)
        return (q2[:, :, None] - 2.0 * dots + c2[None, :, :]).astype(np.float32)
    # IP / cosine: ADC accumulates NEGATED similarity (min-scan)
    return (-dots).astype(np.float32)


class PQIndex(VectorIndex):
    KIND = "pq"

    def __init__(self, metric: Metric = Metric.L2, m: int = 8, ksub: int = 256, **params):
        super().__init__(metric, m=m, ksub=ksub, **params)
        self.m, self.ksub = m, ksub
        self.codebooks: np.ndarray | None = None
        self.codes: np.ndarray | None = None

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        self.codebooks = train_pq_codebooks(x, self.m, self.ksub)
        self.codes = pq_encode(x, self.codebooks)
        self.num_rows = len(x)

    def search(self, queries, k, valid=None):
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        luts = adc_tables(q, self.codebooks, self.metric)
        vals, idx = ops.pq_adc_topk(luts, self.codes, k, valid=valid)
        if self.metric is not Metric.L2:
            vals = -vals  # back to similarity scale
        return vals, idx

    def _state(self):
        return {"codebooks": self.codebooks, "codes": self.codes.astype(np.int32)}

    def _load_state(self, state):
        self.codebooks = state["codebooks"]
        self.codes = state["codes"]
        self.m, self.ksub = self.codebooks.shape[0], self.codebooks.shape[1]
        self.num_rows = len(self.codes)


def train_opq_rotation(
    x: np.ndarray, m: int, ksub: int, iters: int = 5, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Alternating optimization of rotation R and PQ codebooks (OPQ)."""
    d = x.shape[1]
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((d, d)).astype(np.float32)
    r, _ = np.linalg.qr(a)
    codebooks = None
    for _ in range(iters):
        xr = x @ r
        codebooks = train_pq_codebooks(xr, m, ksub, seed=seed, iters=8)
        recon = pq_decode(pq_encode(xr, codebooks), codebooks)
        # Procrustes: R = argmin |xR - recon|  =>  SVD of x^T recon
        u, _s, vt = np.linalg.svd(x.T @ recon, full_matrices=False)
        r = (u @ vt).astype(np.float32)
    return r, codebooks


class OPQIndex(PQIndex):
    KIND = "opq"

    def __init__(self, metric: Metric = Metric.L2, m: int = 8, ksub: int = 256, **params):
        super().__init__(metric, m=m, ksub=ksub, **params)
        self.rotation: np.ndarray | None = None

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        self.rotation, self.codebooks = train_opq_rotation(x, self.m, self.ksub)
        self.codes = pq_encode(x @ self.rotation, self.codebooks)
        self.num_rows = len(x)

    def search(self, queries, k, valid=None):
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        q = q @ self.rotation
        luts = adc_tables(q, self.codebooks, self.metric)
        vals, idx = ops.pq_adc_topk(luts, self.codes, k, valid=valid)
        if self.metric is not Metric.L2:
            vals = -vals
        return vals, idx

    def _state(self):
        s = super()._state()
        s["rotation"] = self.rotation
        return s

    def _load_state(self, state):
        super()._load_state({k: v for k, v in state.items() if k != "rotation"})
        self.rotation = state["rotation"]
