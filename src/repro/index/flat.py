"""FLAT (exact brute-force) and SQ-compressed flat indexes.

FLAT is both a real index (small segments, growing-slice temporary scans)
and the recall oracle every other index is measured against.
"""

from __future__ import annotations

import numpy as np

from ..core.collection import Metric
from ..kernels import ops
from .base import VectorIndex, normalize_if_cosine, scan_metric


class FlatIndex(VectorIndex):
    KIND = "flat"

    def __init__(self, metric: Metric = Metric.L2, **params):
        super().__init__(metric, **params)
        self.vectors: np.ndarray | None = None

    def build(self, vectors: np.ndarray) -> None:
        self.vectors = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        self.num_rows = len(self.vectors)

    def search(self, queries, k, valid=None):
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        return ops.topk_scan(q, self.vectors, k, metric=scan_metric(self.metric), valid=valid)

    def _state(self):
        return {"vectors": self.vectors}

    def _load_state(self, state):
        self.vectors = state["vectors"]
        self.num_rows = len(self.vectors)


class SQIndex(VectorIndex):
    """Scalar-quantized flat index: 4x memory saving, distances on codes."""

    KIND = "sq"

    def __init__(self, metric: Metric = Metric.L2, **params):
        super().__init__(metric, **params)
        self.codes: np.ndarray | None = None
        self.vmin: np.ndarray | None = None
        self.vmax: np.ndarray | None = None

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        self.vmin = x.min(axis=0) if len(x) else np.zeros(x.shape[1], np.float32)
        self.vmax = x.max(axis=0) if len(x) else np.ones(x.shape[1], np.float32)
        self.codes = ops.sq_encode(x, self.vmin, self.vmax)
        self.num_rows = len(x)

    def search(self, queries, k, valid=None):
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        return ops.sq_topk_scan(
            q, self.codes, self.vmin, self.vmax, k,
            metric=scan_metric(self.metric), valid=valid,
        )

    def _state(self):
        return {"codes": self.codes, "vmin": self.vmin, "vmax": self.vmax}

    def _load_state(self, state):
        self.codes = state["codes"]
        self.vmin = state["vmin"]
        self.vmax = state["vmax"]
        self.num_rows = len(self.codes)
