"""Vector index interface (paper Table 1) and shared serialization.

Indexes are *modular* (paper §7 "Modularized algorithms"): a coarse
partitioner (IVF lists / k-means buckets / graph), an optional compressor
(PQ / SQ), and a scanner (the Pallas kernel layer).  Every index implements
``build / search / save / load`` and reports build parameters so the
auto-tuner (``autotune.py``) can explore the configuration space.

Search contract: ``search(queries, k, valid=None)`` returns
``(scores [nq,k], local_idx [nq,k])`` where local_idx indexes into the rows
the index was built on; -1 marks empty slots.  Scores are L2 distances
(ascending) or IP similarities (descending) per the index's metric.
"""

from __future__ import annotations

import io
from dataclasses import dataclass
from typing import Any

import numpy as np

from ..core.collection import Metric


@dataclass
class IndexSpec:
    kind: str
    metric: Metric = Metric.L2
    params: dict[str, Any] | None = None
    # Schema vector field the index serves (multi-vector collections build
    # one index per spec'd field); purely descriptive for the factory.
    field: str = "vector"

    def normalized_params(self) -> dict[str, Any]:
        return dict(self.params or {})


class VectorIndex:
    KIND = "base"

    def __init__(self, metric: Metric = Metric.L2, **params):
        self.metric = metric
        self.params = params
        self.num_rows = 0

    # -- lifecycle ----------------------------------------------------------
    def build(self, vectors: np.ndarray) -> None:
        raise NotImplementedError

    def search(
        self, queries: np.ndarray, k: int, valid: np.ndarray | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    # -- batched candidate-pool surface -------------------------------------
    def batch_spec(self) -> tuple:
        """Hashable spec key: units whose indexes share a spec may execute
        as one ``search_batched`` dispatch."""
        return (
            type(self),
            self.metric,
            tuple(sorted((k, repr(v)) for k, v in self.params.items())),
        )

    @classmethod
    def search_batched(
        cls,
        indexes: "list[VectorIndex]",
        queries: np.ndarray,
        k: int,
        valids: "list[np.ndarray | None] | None" = None,
    ) -> tuple[np.ndarray, np.ndarray, "list[int]"]:
        """Candidate-pool search over co-located indexes of one spec.

        Returns ``(scores [nq, M], local_idx [nq, M], splits)`` where
        ``splits`` (length ``len(indexes)+1``) bounds each index's column
        block; block ``u`` holds top candidates from ``indexes[u]`` with
        row indices local to it (-1 = empty slot).  Blocks are candidate
        POOLS — they may be wider than ``k`` and are not globally reduced;
        the caller maps local indices to pks per block and merges once
        (``ops.merge_topk``).  The base implementation dispatches per
        index; batched engines (IVF) override it to share orchestration
        across the whole group.
        """
        if valids is None:
            valids = [None] * len(indexes)
        ss, ii, splits = [], [], [0]
        for idx, v in zip(indexes, valids):
            s, i = idx.search(queries, k, valid=v)
            ss.append(s)
            ii.append(i)
            splits.append(splits[-1] + s.shape[1])
        nq = len(queries)
        if not ss:
            return (
                np.zeros((nq, 0), np.float32),
                np.full((nq, 0), -1, np.int64),
                splits,
            )
        return np.concatenate(ss, axis=1), np.concatenate(ii, axis=1), splits

    # -- (de)serialization to the object store ------------------------------
    def _state(self) -> dict[str, np.ndarray]:
        raise NotImplementedError

    def _load_state(self, state: dict[str, np.ndarray]) -> None:
        raise NotImplementedError

    def save(self) -> bytes:
        import json

        buf = io.BytesIO()
        meta = {
            "kind": np.bytes_(self.KIND.encode()),
            "metric": np.bytes_(self.metric.value.encode()),
            "num_rows": np.int64(self.num_rows),
            "params_json": np.bytes_(json.dumps(self.params, default=str).encode()),
        }
        np.savez_compressed(buf, **meta, **self._state())
        return buf.getvalue()

    @classmethod
    def load(cls, data: bytes) -> "VectorIndex":
        import json

        from .registry import create_index  # local import to avoid cycle

        _META = ("kind", "metric", "num_rows", "params_json")
        with np.load(io.BytesIO(data), allow_pickle=False) as z:
            kind = bytes(z["kind"]).decode()
            metric = Metric(bytes(z["metric"]).decode())
            params = json.loads(bytes(z["params_json"]).decode()) if "params_json" in z.files else {}
            idx = create_index(IndexSpec(kind=kind, metric=metric, params=params))
            idx.num_rows = int(z["num_rows"])
            state = {k: z[k] for k in z.files if k not in _META}
            idx._load_state(state)
            return idx

    # -- misc ---------------------------------------------------------------
    def memory_bytes(self) -> int:
        return sum(v.nbytes for v in self._state().values())

    def metric_is_descending(self) -> bool:
        return self.metric in (Metric.IP, Metric.COSINE)


def normalize_if_cosine(metric: Metric, x: np.ndarray) -> np.ndarray:
    """Cosine = IP over unit vectors; normalize once at build/query time."""
    if metric is Metric.COSINE:
        norms = np.linalg.norm(x, axis=1, keepdims=True)
        return x / np.maximum(norms, 1e-12)
    return x


def scan_metric(metric: Metric) -> str:
    return "l2" if metric is Metric.L2 else "ip"


def worst_score(metric: Metric) -> float:
    return np.inf if metric is Metric.L2 else -np.inf


def better(metric: Metric, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise 'a is better than b' under the metric's ordering."""
    return a < b if metric is Metric.L2 else a > b
