"""Numeric/label attribute indexes (paper Table 1: B-Tree, Sorted List).

Used by attribute filtering: expressions like ``price < 100 AND label ==
'book'`` are resolved to a row bitmap which the vector kernels consume as a
validity mask.  A sorted-list index gives O(log n) range resolution; label
(categorical) fields use posting bitmaps.
"""

from __future__ import annotations

import io

import numpy as np

# Comparison-op vocabulary shared by the indexes and FilterExpr leaves.
_OP_FNS = {
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
}


class SortedListIndex:
    """Sorted projection of a numeric column with binary-search ranges."""

    KIND = "sorted_list"

    def __init__(self, values: np.ndarray):
        self.n = len(values)
        self.order = np.argsort(values, kind="stable")
        self.sorted_vals = np.asarray(values)[self.order]

    def range_mask(self, lo=None, hi=None, lo_open=False, hi_open=False) -> np.ndarray:
        left = 0
        right = self.n
        if lo is not None:
            left = np.searchsorted(self.sorted_vals, lo, side="right" if lo_open else "left")
        if hi is not None:
            right = np.searchsorted(self.sorted_vals, hi, side="left" if hi_open else "right")
        mask = np.zeros(self.n, dtype=bool)
        if right > left:
            mask[self.order[left:right]] = True
        return mask

    def _bounds(self, op: str, value) -> tuple[int, int]:
        """[left, right) slice of the sorted projection matching ``op value``
        (``ne`` callers complement the ``eq`` interval)."""
        if op in ("eq", "ne"):
            return (
                int(np.searchsorted(self.sorted_vals, value, side="left")),
                int(np.searchsorted(self.sorted_vals, value, side="right")),
            )
        if op == "lt":
            return 0, int(np.searchsorted(self.sorted_vals, value, side="left"))
        if op == "le":
            return 0, int(np.searchsorted(self.sorted_vals, value, side="right"))
        if op == "gt":
            return int(np.searchsorted(self.sorted_vals, value, side="right")), self.n
        if op == "ge":
            return int(np.searchsorted(self.sorted_vals, value, side="left")), self.n
        raise ValueError(f"unknown op {op!r}")

    def op_mask(self, op: str, value) -> np.ndarray:
        left, right = self._bounds(op, value)
        mask = np.zeros(self.n, dtype=bool)
        if right > left:
            mask[self.order[left:right]] = True
        return ~mask if op == "ne" else mask

    def op_count(self, op: str, value) -> int:
        left, right = self._bounds(op, value)
        hit = max(0, right - left)
        return self.n - hit if op == "ne" else hit

    def _state(self) -> dict[str, np.ndarray]:
        return {"order": self.order, "sorted_vals": self.sorted_vals}

    @classmethod
    def _from_state(cls, state: dict[str, np.ndarray]) -> "SortedListIndex":
        idx = cls.__new__(cls)
        idx.order = state["order"]
        idx.sorted_vals = state["sorted_vals"]
        idx.n = len(idx.order)
        return idx

    def save(self) -> bytes:
        return _dump_attr(self.KIND, self._state())


class LabelIndex:
    """Posting bitmaps per distinct label value (dictionary-encoded)."""

    KIND = "label"

    def __init__(self, values: np.ndarray):
        vals = np.asarray(values)
        self.n = len(vals)
        self.keys, self.codes = np.unique(vals, return_inverse=True)
        self._finish()

    def _finish(self) -> None:
        self.codes = self.codes.astype(np.int32)
        self.counts = np.bincount(self.codes, minlength=len(self.keys))
        self.postings: dict[object, np.ndarray] = {}
        for i, k in enumerate(self.keys):
            self.postings[k.item() if hasattr(k, "item") else k] = self.codes == i

    def eq_mask(self, value) -> np.ndarray:
        return self.postings.get(value, np.zeros(self.n, dtype=bool)).copy()

    def in_mask(self, values) -> np.ndarray:
        mask = np.zeros(self.n, dtype=bool)
        for v in values:
            mask |= self.postings.get(v, False)
        return mask

    def _key_mask(self, op: str, value) -> np.ndarray:
        with np.errstate(all="ignore"):
            return np.asarray(_OP_FNS[op](self.keys, value), dtype=bool)

    def op_mask(self, op: str, value) -> np.ndarray:
        km = self._key_mask(op, value)
        if km.shape != self.keys.shape:  # scalar broadcast (e.g. no match)
            km = np.broadcast_to(km, self.keys.shape)
        return km[self.codes] if len(self.keys) else np.zeros(self.n, dtype=bool)

    def op_count(self, op: str, value) -> int:
        km = self._key_mask(op, value)
        if km.shape != self.keys.shape:
            km = np.broadcast_to(km, self.keys.shape)
        return int(self.counts[km].sum()) if len(self.keys) else 0

    def _state(self) -> dict[str, np.ndarray]:
        return {"keys": self.keys, "codes": self.codes}

    @classmethod
    def _from_state(cls, state: dict[str, np.ndarray]) -> "LabelIndex":
        idx = cls.__new__(cls)
        idx.keys = state["keys"]
        idx.codes = state["codes"]
        idx.n = len(idx.codes)
        idx._finish()
        return idx

    def save(self) -> bytes:
        return _dump_attr(self.KIND, self._state())


_ATTR_KINDS = {SortedListIndex.KIND: SortedListIndex, LabelIndex.KIND: LabelIndex}
_ATTR_META = ("kind",)


def _dump_attr(kind: str, state: dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez_compressed(buf, kind=np.bytes_(kind.encode()), **state)
    return buf.getvalue()


def build_attribute_index(values: np.ndarray):
    """Numeric 1-D columns get a sorted-list index, everything else postings."""
    vals = np.asarray(values)
    if vals.ndim != 1:
        raise ValueError(f"attribute index needs a 1-D column, got shape {vals.shape}")
    if np.issubdtype(vals.dtype, np.number):
        return SortedListIndex(vals)
    return LabelIndex(vals)


def load_attribute_index(data: bytes):
    with np.load(io.BytesIO(data), allow_pickle=False) as z:
        kind = bytes(z["kind"]).decode()
        cls = _ATTR_KINDS.get(kind)
        if cls is None:
            raise ValueError(f"unknown attribute index kind {kind!r}")
        state = {k: z[k] for k in z.files if k not in _ATTR_META}
        return cls._from_state(state)


# ---------------------------------------------------------------------------
# A tiny filter-expression evaluator: supports comparisons on numeric fields,
# equality on labels, AND/OR/NOT.  Grammar kept deliberately small (Manu's
# filtering surface), parsed with Python's ast over a restricted node set.
# ---------------------------------------------------------------------------

import ast


class FilterExpr:
    """Compile ``"price < 100 and label == 'book'"`` into a mask evaluator."""

    _CMP = {
        ast.Lt: lambda a, b: a < b,
        ast.LtE: lambda a, b: a <= b,
        ast.Gt: lambda a, b: a > b,
        ast.GtE: lambda a, b: a >= b,
        ast.Eq: lambda a, b: a == b,
        ast.NotEq: lambda a, b: a != b,
    }

    def __init__(self, expr: str):
        self.expr = expr
        self.tree = ast.parse(expr, mode="eval").body
        self._validate(self.tree)

    def _validate(self, node) -> None:
        ok = (
            ast.BoolOp, ast.And, ast.Or, ast.UnaryOp, ast.Not, ast.Compare,
            ast.Name, ast.Load, ast.Constant, ast.Lt, ast.LtE, ast.Gt,
            ast.GtE, ast.Eq, ast.NotEq,
        )
        for child in ast.walk(node):
            if not isinstance(child, ok):
                raise ValueError(f"unsupported filter syntax: {ast.dump(child)}")

    def evaluate(self, columns: dict[str, np.ndarray], n: int) -> np.ndarray:
        def ev(node) -> np.ndarray:
            if isinstance(node, ast.BoolOp):
                masks = [ev(v) for v in node.values]
                out = masks[0]
                for m in masks[1:]:
                    out = out & m if isinstance(node.op, ast.And) else out | m
                return out
            if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
                return ~ev(node.operand)
            if isinstance(node, ast.Compare):
                if len(node.ops) != 1:
                    raise ValueError("chained comparisons unsupported")
                left, right = node.left, node.comparators[0]
                name_node, const_node, flip = (
                    (left, right, False)
                    if isinstance(left, ast.Name)
                    else (right, left, True)
                )
                if not isinstance(name_node, ast.Name) or not isinstance(const_node, ast.Constant):
                    raise ValueError("comparison must be field <op> constant")
                col = columns.get(name_node.id)
                if col is None:
                    raise KeyError(f"unknown filter field '{name_node.id}'")
                op = type(node.ops[0])
                fn = self._CMP[op]
                if flip:  # const <op> field  ->  field <flipped-op> const
                    flipped = {ast.Lt: ast.Gt, ast.LtE: ast.GtE,
                               ast.Gt: ast.Lt, ast.GtE: ast.LtE,
                               ast.Eq: ast.Eq, ast.NotEq: ast.NotEq}[op]
                    fn = self._CMP[flipped]
                return np.asarray(fn(col, const_node.value))
            raise ValueError(f"unsupported node {node!r}")

        mask = ev(self.tree)
        if mask.shape != (n,):
            mask = np.broadcast_to(mask, (n,)).copy()
        return mask

    # -- attribute-index resolution -----------------------------------------

    _OPSTR = {
        ast.Lt: "lt", ast.LtE: "le", ast.Gt: "gt",
        ast.GtE: "ge", ast.Eq: "eq", ast.NotEq: "ne",
    }
    _FLIP = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le", "eq": "eq", "ne": "ne"}

    def fields(self) -> set[str]:
        return {node.id for node in ast.walk(self.tree) if isinstance(node, ast.Name)}

    def _leaf(self, node: ast.Compare) -> tuple[str, str, object]:
        """Normalize a comparison to ``(field, op, constant)``."""
        if len(node.ops) != 1:
            raise ValueError("chained comparisons unsupported")
        left, right = node.left, node.comparators[0]
        name_node, const_node, flip = (
            (left, right, False) if isinstance(left, ast.Name) else (right, left, True)
        )
        if not isinstance(name_node, ast.Name) or not isinstance(const_node, ast.Constant):
            raise ValueError("comparison must be field <op> constant")
        op = self._OPSTR[type(node.ops[0])]
        if flip:
            op = self._FLIP[op]
        return name_node.id, op, const_node.value

    def bitmap(self, attr_indexes: dict[str, object], n: int) -> np.ndarray:
        """Exact row bitmap resolved through per-field attribute indexes.

        Bit-for-bit identical to ``evaluate`` over the same rows; raises
        ``KeyError`` when a referenced field has no index (callers fall back
        to row-wise evaluation)."""
        def ev(node) -> np.ndarray:
            if isinstance(node, ast.BoolOp):
                masks = [ev(v) for v in node.values]
                out = masks[0]
                for m in masks[1:]:
                    out = out & m if isinstance(node.op, ast.And) else out | m
                return out
            if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
                return ~ev(node.operand)
            if isinstance(node, ast.Compare):
                field, op, value = self._leaf(node)
                idx = attr_indexes.get(field)
                if idx is None:
                    raise KeyError(f"no attribute index for field '{field}'")
                return idx.op_mask(op, value)
            raise ValueError(f"unsupported node {node!r}")

        mask = ev(self.tree)
        if mask.shape != (n,):
            mask = np.broadcast_to(mask, (n,)).copy()
        return mask

    def estimate_selectivity(self, attr_indexes: dict[str, object], n: int) -> float:
        """Cheap selectivity estimate from exact leaf counts combined under
        an independence assumption (and: a*b, or: 1-(1-a)(1-b), not: 1-a).
        Leaves without an index estimate 0.5."""
        if n <= 0:
            return 0.0

        def ev(node) -> float:
            if isinstance(node, ast.BoolOp):
                parts = [ev(v) for v in node.values]
                if isinstance(node.op, ast.And):
                    out = 1.0
                    for p in parts:
                        out *= p
                else:
                    out = 1.0
                    for p in parts:
                        out *= 1.0 - p
                    out = 1.0 - out
                return out
            if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
                return 1.0 - ev(node.operand)
            if isinstance(node, ast.Compare):
                field, op, value = self._leaf(node)
                idx = attr_indexes.get(field)
                if idx is None:
                    return 0.5
                return idx.op_count(op, value) / n
            raise ValueError(f"unsupported node {node!r}")

        return float(min(1.0, max(0.0, ev(self.tree))))
