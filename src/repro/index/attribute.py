"""Numeric/label attribute indexes (paper Table 1: B-Tree, Sorted List).

Used by attribute filtering: expressions like ``price < 100 AND label ==
'book'`` are resolved to a row bitmap which the vector kernels consume as a
validity mask.  A sorted-list index gives O(log n) range resolution; label
(categorical) fields use posting bitmaps.
"""

from __future__ import annotations

import numpy as np


class SortedListIndex:
    """Sorted projection of a numeric column with binary-search ranges."""

    def __init__(self, values: np.ndarray):
        self.n = len(values)
        self.order = np.argsort(values, kind="stable")
        self.sorted_vals = np.asarray(values)[self.order]

    def range_mask(self, lo=None, hi=None, lo_open=False, hi_open=False) -> np.ndarray:
        left = 0
        right = self.n
        if lo is not None:
            left = np.searchsorted(self.sorted_vals, lo, side="right" if lo_open else "left")
        if hi is not None:
            right = np.searchsorted(self.sorted_vals, hi, side="left" if hi_open else "right")
        mask = np.zeros(self.n, dtype=bool)
        if right > left:
            mask[self.order[left:right]] = True
        return mask


class LabelIndex:
    """Posting bitmaps per distinct label value."""

    def __init__(self, values: np.ndarray):
        self.n = len(values)
        self.postings: dict[object, np.ndarray] = {}
        vals = np.asarray(values)
        for v in np.unique(vals):
            self.postings[v.item() if hasattr(v, "item") else v] = vals == v

    def eq_mask(self, value) -> np.ndarray:
        return self.postings.get(value, np.zeros(self.n, dtype=bool)).copy()

    def in_mask(self, values) -> np.ndarray:
        mask = np.zeros(self.n, dtype=bool)
        for v in values:
            mask |= self.postings.get(v, False)
        return mask


# ---------------------------------------------------------------------------
# A tiny filter-expression evaluator: supports comparisons on numeric fields,
# equality on labels, AND/OR/NOT.  Grammar kept deliberately small (Manu's
# filtering surface), parsed with Python's ast over a restricted node set.
# ---------------------------------------------------------------------------

import ast


class FilterExpr:
    """Compile ``"price < 100 and label == 'book'"`` into a mask evaluator."""

    _CMP = {
        ast.Lt: lambda a, b: a < b,
        ast.LtE: lambda a, b: a <= b,
        ast.Gt: lambda a, b: a > b,
        ast.GtE: lambda a, b: a >= b,
        ast.Eq: lambda a, b: a == b,
        ast.NotEq: lambda a, b: a != b,
    }

    def __init__(self, expr: str):
        self.expr = expr
        self.tree = ast.parse(expr, mode="eval").body
        self._validate(self.tree)

    def _validate(self, node) -> None:
        ok = (
            ast.BoolOp, ast.And, ast.Or, ast.UnaryOp, ast.Not, ast.Compare,
            ast.Name, ast.Load, ast.Constant, ast.Lt, ast.LtE, ast.Gt,
            ast.GtE, ast.Eq, ast.NotEq,
        )
        for child in ast.walk(node):
            if not isinstance(child, ok):
                raise ValueError(f"unsupported filter syntax: {ast.dump(child)}")

    def evaluate(self, columns: dict[str, np.ndarray], n: int) -> np.ndarray:
        def ev(node) -> np.ndarray:
            if isinstance(node, ast.BoolOp):
                masks = [ev(v) for v in node.values]
                out = masks[0]
                for m in masks[1:]:
                    out = out & m if isinstance(node.op, ast.And) else out | m
                return out
            if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
                return ~ev(node.operand)
            if isinstance(node, ast.Compare):
                if len(node.ops) != 1:
                    raise ValueError("chained comparisons unsupported")
                left, right = node.left, node.comparators[0]
                name_node, const_node, flip = (
                    (left, right, False)
                    if isinstance(left, ast.Name)
                    else (right, left, True)
                )
                if not isinstance(name_node, ast.Name) or not isinstance(const_node, ast.Constant):
                    raise ValueError("comparison must be field <op> constant")
                col = columns.get(name_node.id)
                if col is None:
                    raise KeyError(f"unknown filter field '{name_node.id}'")
                op = type(node.ops[0])
                fn = self._CMP[op]
                if flip:  # const <op> field  ->  field <flipped-op> const
                    flipped = {ast.Lt: ast.Gt, ast.LtE: ast.GtE,
                               ast.Gt: ast.Lt, ast.GtE: ast.LtE,
                               ast.Eq: ast.Eq, ast.NotEq: ast.NotEq}[op]
                    fn = self._CMP[flipped]
                return np.asarray(fn(col, const_node.value))
            raise ValueError(f"unsupported node {node!r}")

        mask = ev(self.tree)
        if mask.shape != (n,):
            mask = np.broadcast_to(mask, (n,)).copy()
        return mask
