"""HNSW proximity graph (Malkov & Yashunin) — host-side index.

Graph walks are pointer-chasing and do not vectorize onto the MXU (see
DESIGN.md §3: the one Manu component with no TPU-native analogue), so HNSW
stays a CPU/numpy index exactly as it is in production Milvus.  Neighbor
lists are fixed-width int arrays (-1 padded), distance evaluations are
batched numpy — the idiomatic vectorized form of the algorithm.

Parameters: M (graph degree), ef_construction, ef_search.  These are the
knobs the BOHB auto-tuner (autotune.py) explores.
"""

from __future__ import annotations

import heapq
import threading

import numpy as np

from ..core.collection import Metric
from .base import VectorIndex, normalize_if_cosine


class HNSWIndex(VectorIndex):
    KIND = "hnsw"

    def __init__(
        self,
        metric: Metric = Metric.L2,
        m: int = 16,
        ef_construction: int = 100,
        ef_search: int = 64,
        seed: int = 0,
        **params,
    ):
        super().__init__(metric, m=m, ef_construction=ef_construction,
                         ef_search=ef_search, **params)
        self.m = m
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self.vectors: np.ndarray | None = None
        self.levels: np.ndarray | None = None  # [n] max level per node
        self.graph: list[np.ndarray] = []  # per level: [n, M_l] neighbors (-1 pad)
        self.entry_point: int = -1
        self._tls = threading.local()  # per-thread visited scratch

    # ------------------------------------------------------------ distances
    def _dist(self, q: np.ndarray, ids: np.ndarray) -> np.ndarray:
        x = self.vectors[ids]
        if self.metric is Metric.L2:
            diff = x - q[None, :]
            return np.sum(diff * diff, axis=1)
        return -(x @ q)  # negated similarity => smaller is better everywhere

    # --------------------------------------------------------------- search
    def _visited_scratch(self) -> tuple[np.ndarray, int]:
        """Epoch-stamped visited array: `stamp[i] == epoch` means visited.

        Bumping the per-thread epoch resets the whole array in O(1), so a
        beam search costs O(nodes actually visited) instead of an O(n)
        allocation per call (build runs ~levels calls per inserted row).
        Thread-local keeps concurrent searches (hedged requests)
        independent.
        """
        tls = self._tls
        stamp = getattr(tls, "stamp", None)
        n = len(self.vectors)
        if stamp is None or len(stamp) < n:
            tls.stamp = np.zeros(n, np.int64)
            tls.epoch = 0
        tls.epoch += 1
        return tls.stamp, tls.epoch

    def _search_layer(self, q: np.ndarray, entry: int, ef: int, level: int) -> list[tuple[float, int]]:
        """Best-first beam search on one layer; returns [(dist, id)] sorted."""
        stamp, epoch = self._visited_scratch()
        stamp[entry] = epoch
        d0 = float(self._dist(q, np.array([entry]))[0])
        candidates = [(d0, entry)]  # min-heap
        results = [(-d0, entry)]  # max-heap of negatives
        graph = self.graph[level]
        while candidates:
            d_c, c = heapq.heappop(candidates)
            if d_c > -results[0][0] and len(results) >= ef:
                break
            neigh = graph[c]
            neigh = neigh[neigh >= 0]
            fresh = neigh[stamp[neigh] != epoch]  # vectorized visited mask
            if len(fresh) == 0:
                continue
            stamp[fresh] = epoch
            dists = self._dist(q, fresh)
            for dn, n in zip(dists.tolist(), fresh.tolist()):
                if len(results) < ef or dn < -results[0][0]:
                    heapq.heappush(candidates, (dn, n))
                    heapq.heappush(results, (-dn, n))
                    if len(results) > ef:
                        heapq.heappop(results)
        out = sorted((-nd, i) for nd, i in results)
        return out

    def _select_neighbors(self, q: np.ndarray, cand: list[tuple[float, int]], m: int) -> np.ndarray:
        """Heuristic neighbor selection (keeps diverse edges)."""
        selected: list[int] = []
        for d_c, c in sorted(cand):
            if len(selected) >= m:
                break
            ok = True
            if selected:
                d_to_sel = self._dist(self.vectors[c], np.array(selected))
                ok = bool((d_to_sel >= d_c).all())
            if ok:
                selected.append(c)
        # fill remainder with closest unselected
        if len(selected) < m:
            for d_c, c in sorted(cand):
                if c not in selected:
                    selected.append(c)
                    if len(selected) >= m:
                        break
        return np.array(selected[:m], dtype=np.int64)

    # ---------------------------------------------------------------- build
    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        self.vectors = x
        n = len(x)
        self.num_rows = n
        if n == 0:
            return
        rng = np.random.default_rng(self.seed)
        ml = 1.0 / np.log(max(self.m, 2))
        self.levels = np.minimum(
            (-np.log(rng.random(n)) * ml).astype(np.int64), 8
        )
        max_level = int(self.levels.max())
        m0 = self.m * 2  # level-0 degree, per the paper
        self.graph = [
            np.full((n, m0 if l == 0 else self.m), -1, dtype=np.int64)
            for l in range(max_level + 1)
        ]
        self.entry_point = 0
        self.levels[0] = max_level  # first node spans all levels

        for i in range(1, n):
            q = x[i]
            lvl = int(self.levels[i])
            ep = self.entry_point
            # zoom down from top to lvl+1 greedily
            for l in range(int(self.levels[self.entry_point]), lvl, -1):
                if l >= len(self.graph):
                    continue
                res = self._search_layer(q, ep, 1, l)
                ep = res[0][1]
            # insert at each level from min(lvl, top) down to 0
            for l in range(min(lvl, len(self.graph) - 1), -1, -1):
                cand = self._search_layer(q, ep, self.ef_construction, l)
                m_l = self.graph[l].shape[1]
                neighbors = self._select_neighbors(q, cand, min(m_l, len(cand)))
                self.graph[l][i, : len(neighbors)] = neighbors
                # back-edges with pruning
                for nb in neighbors.tolist():
                    row = self.graph[l][nb]
                    free = np.nonzero(row < 0)[0]
                    if len(free):
                        row[free[0]] = i
                    else:
                        # prune with the DIVERSITY heuristic (plain
                        # closest-m pruning drops long-range bridge edges
                        # and disconnects clusters; -1 padding must never
                        # enter the ranking)
                        ids = np.concatenate([row[row >= 0], [i]])
                        d = self._dist(x[nb], ids)
                        cand = sorted(zip(d.tolist(), ids.tolist()))
                        keep = self._select_neighbors(x[nb], cand, m_l)
                        new_row = np.full(m_l, -1, dtype=np.int64)
                        new_row[: len(keep)] = keep
                        self.graph[l][nb] = new_row
                ep = cand[0][1]
        self.entry_point = int(np.argmax(self.levels))

    def search(self, queries, k, valid=None):
        q_all = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        nq = len(q_all)
        ef = max(int(self.params.get("ef_search", self.ef_search)), k)
        out_s = np.full((nq, k), np.inf, np.float32)
        out_i = np.full((nq, k), -1, np.int64)
        if self.num_rows == 0:
            return out_s, out_i
        for r in range(nq):
            q = q_all[r]
            ep = self.entry_point
            for l in range(int(self.levels[self.entry_point]), 0, -1):
                if l >= len(self.graph):
                    continue
                ep = self._search_layer(q, ep, 1, l)[0][1]
            res = self._search_layer(q, ep, ef, 0)
            if valid is not None:
                res = [(d, i) for d, i in res if valid[i]]
            for j, (d, i) in enumerate(res[:k]):
                out_s[r, j] = d
                out_i[r, j] = i
        if self.metric is not Metric.L2:
            out_s = np.where(out_i >= 0, -out_s, -np.inf)
        return out_s, out_i

    # ------------------------------------------------------------ serialize
    def _state(self):
        state = {
            "vectors": self.vectors,
            "levels": self.levels,
            "entry_point": np.int64(self.entry_point),
            "n_levels": np.int64(len(self.graph)),
        }
        for l, g in enumerate(self.graph):
            state[f"graph_{l}"] = g
        return state

    def _load_state(self, state):
        self.vectors = state["vectors"]
        self.levels = state["levels"]
        self.entry_point = int(state["entry_point"])
        self.graph = [state[f"graph_{l}"] for l in range(int(state["n_levels"]))]
        self.num_rows = len(self.vectors)
