"""Inverted-file indexes: IVF-FLAT, IVF-SQ, IVF-PQ (paper Table 1).

Vectors are grouped into ``nlist`` k-means clusters; a query scans only the
``nprobe`` most promising lists.  Lists are stored contiguously (CSR-style)
so each probed list is one dense kernel scan — the TPU adaptation of the
cache-friendly layout Milvus uses on CPU.

IVF-PQ encodes residuals (x - centroid) which materially improves recall at
the same code budget.
"""

from __future__ import annotations

import numpy as np

from ..core.collection import Metric
from ..kernels import ops
from .base import VectorIndex, normalize_if_cosine, scan_metric, worst_score
from .kmeans import kmeans
from .pq import adc_tables, pq_encode, train_pq_codebooks


def _merge_topk(
    metric: Metric, scores: list[np.ndarray], ids: list[np.ndarray], k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Merge per-list candidate pools into final top-k (host-side reduce)."""
    s = np.concatenate(scores, axis=1)
    i = np.concatenate(ids, axis=1)
    if metric is Metric.L2:
        order = np.argsort(s, axis=1, kind="stable")[:, :k]
    else:
        order = np.argsort(-s, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(s, order, 1), np.take_along_axis(i, order, 1)


class IVFBase(VectorIndex):
    def __init__(
        self, metric: Metric = Metric.L2, nlist: int = 64, nprobe: int = 8, **params
    ):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, **params)
        self.nlist = nlist
        self.nprobe = nprobe
        self.centroids: np.ndarray | None = None
        self.list_offsets: np.ndarray | None = None  # [nlist+1] CSR offsets
        self.row_ids: np.ndarray | None = None  # [n] permutation: list order -> original

    def _partition(self, x: np.ndarray) -> np.ndarray:
        """Cluster and build CSR layout; returns x permuted to list order."""
        self.centroids, assign = kmeans(x, min(self.nlist, max(1, len(x))), seed=0)
        self.nlist = len(self.centroids)
        order = np.argsort(assign, kind="stable")
        counts = np.bincount(assign, minlength=self.nlist)
        self.list_offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.row_ids = order.astype(np.int64)
        return x[order]

    def _probe_lists(self, q: np.ndarray, nprobe: int) -> np.ndarray:
        """[nq, nprobe] most promising list ids per query."""
        nprobe = min(nprobe, self.nlist)
        # For IP, the best lists are by centroid similarity; for L2 by distance.
        vals, idx = ops.topk_scan(
            q, self.centroids, nprobe, metric=scan_metric(self.metric)
        )
        return idx

    # Subclasses implement one-list scan over the permuted storage.
    def _scan_range(
        self, q: np.ndarray, lo: int, hi: int, k: int, valid_perm: np.ndarray | None
    ) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def search(self, queries, k, valid=None):
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        nq = len(q)
        nprobe = int(self.params.get("nprobe", self.nprobe))
        probes = self._probe_lists(q, nprobe)  # [nq, nprobe]
        valid_perm = None
        if valid is not None:
            valid_perm = np.asarray(valid)[self.row_ids]

        # Group queries by probed list so each list is scanned once per
        # batch — the paper's request batching at the segment level.
        out_s = np.full((nq, k), worst_score(self.metric), np.float32)
        out_i = np.full((nq, k), -1, np.int64)
        unique_lists = np.unique(probes)
        per_q_scores: list[list[np.ndarray]] = [[] for _ in range(nq)]
        per_q_ids: list[list[np.ndarray]] = [[] for _ in range(nq)]
        for lst in unique_lists:
            if lst < 0:
                continue
            qmask = (probes == lst).any(axis=1)
            lo, hi = int(self.list_offsets[lst]), int(self.list_offsets[lst + 1])
            if hi <= lo or not qmask.any():
                continue
            sub_q = q[qmask]
            s, i = self._scan_range(sub_q, lo, hi, min(k, hi - lo), valid_perm)
            # map local list offsets -> original row ids
            gi = np.where(i >= 0, self.row_ids[np.clip(i + lo, 0, len(self.row_ids) - 1)], -1)
            rows = np.nonzero(qmask)[0]
            for r_local, r in enumerate(rows):
                per_q_scores[r].append(s[r_local : r_local + 1])
                per_q_ids[r].append(gi[r_local : r_local + 1])
        for r in range(nq):
            if per_q_scores[r]:
                s, i = _merge_topk(self.metric, per_q_scores[r], per_q_ids[r], k)
                out_s[r, : s.shape[1]] = s[0]
                out_i[r, : i.shape[1]] = i[0]
        return out_s, out_i

    def _base_state(self) -> dict[str, np.ndarray]:
        return {
            "centroids": self.centroids,
            "list_offsets": self.list_offsets,
            "row_ids": self.row_ids,
        }

    def _load_base_state(self, state) -> None:
        self.centroids = state["centroids"]
        self.list_offsets = state["list_offsets"]
        self.row_ids = state["row_ids"]
        self.nlist = len(self.centroids)


class IVFFlatIndex(IVFBase):
    KIND = "ivf_flat"

    def __init__(self, metric: Metric = Metric.L2, nlist: int = 64, nprobe: int = 8, **params):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, **params)
        self.storage: np.ndarray | None = None  # permuted vectors

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        self.storage = self._partition(x)
        self.num_rows = len(x)

    def _scan_range(self, q, lo, hi, k, valid_perm):
        v = None if valid_perm is None else valid_perm[lo:hi]
        return ops.topk_scan(
            q, self.storage[lo:hi], k, metric=scan_metric(self.metric), valid=v
        )

    def _state(self):
        return {**self._base_state(), "storage": self.storage}

    def _load_state(self, state):
        self._load_base_state(state)
        self.storage = state["storage"]
        self.num_rows = len(self.storage)


class IVFSQIndex(IVFBase):
    KIND = "ivf_sq"

    def __init__(self, metric: Metric = Metric.L2, nlist: int = 64, nprobe: int = 8, **params):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, **params)
        self.codes: np.ndarray | None = None
        self.vmin: np.ndarray | None = None
        self.vmax: np.ndarray | None = None

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        xp = self._partition(x)
        self.vmin, self.vmax = xp.min(axis=0), xp.max(axis=0)
        self.codes = ops.sq_encode(xp, self.vmin, self.vmax)
        self.num_rows = len(x)

    def _scan_range(self, q, lo, hi, k, valid_perm):
        v = None if valid_perm is None else valid_perm[lo:hi]
        return ops.sq_topk_scan(
            q, self.codes[lo:hi], self.vmin, self.vmax, k,
            metric=scan_metric(self.metric), valid=v,
        )

    def _state(self):
        return {
            **self._base_state(),
            "codes": self.codes,
            "vmin": self.vmin,
            "vmax": self.vmax,
        }

    def _load_state(self, state):
        self._load_base_state(state)
        self.codes, self.vmin, self.vmax = state["codes"], state["vmin"], state["vmax"]
        self.num_rows = len(self.codes)


class IVFPQIndex(IVFBase):
    KIND = "ivf_pq"

    def __init__(
        self,
        metric: Metric = Metric.L2,
        nlist: int = 64,
        nprobe: int = 8,
        m: int = 8,
        ksub: int = 256,
        **params,
    ):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, m=m, ksub=ksub, **params)
        self.m, self.ksub = m, ksub
        self.codebooks: np.ndarray | None = None
        self.codes: np.ndarray | None = None
        self._perm_assign: np.ndarray | None = None  # list id per permuted row

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        xp = self._partition(x)
        assign = np.repeat(
            np.arange(self.nlist), np.diff(self.list_offsets).astype(int)
        )
        residual = xp - self.centroids[assign]
        self.codebooks = train_pq_codebooks(residual, self.m, self.ksub)
        self.codes = pq_encode(residual, self.codebooks)
        self._perm_assign = assign.astype(np.int32)
        self.num_rows = len(x)

    def search(self, queries, k, valid=None):
        # Residual ADC: LUTs must be recomputed per (query, probed list) on
        # q - centroid. We scan per list with shifted queries.
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        nq = len(q)
        nprobe = int(self.params.get("nprobe", self.nprobe))
        probes = self._probe_lists(q, nprobe)
        valid_perm = None if valid is None else np.asarray(valid)[self.row_ids]
        pools_s: list[list[np.ndarray]] = [[] for _ in range(nq)]
        pools_i: list[list[np.ndarray]] = [[] for _ in range(nq)]
        for lst in np.unique(probes):
            if lst < 0:
                continue
            lo, hi = int(self.list_offsets[lst]), int(self.list_offsets[lst + 1])
            qmask = (probes == lst).any(axis=1)
            if hi <= lo or not qmask.any():
                continue
            sub_q = q[qmask] - self.centroids[lst][None, :]
            luts = adc_tables(sub_q, self.codebooks, self.metric)
            v = None if valid_perm is None else valid_perm[lo:hi]
            s, i = ops.pq_adc_topk(luts, self.codes[lo:hi], min(k, hi - lo), valid=v)
            if self.metric is not Metric.L2:
                s = -s
            gi = np.where(i >= 0, self.row_ids[np.clip(i + lo, 0, len(self.row_ids) - 1)], -1)
            rows = np.nonzero(qmask)[0]
            for r_local, r in enumerate(rows):
                pools_s[r].append(s[r_local : r_local + 1])
                pools_i[r].append(gi[r_local : r_local + 1])
        out_s = np.full((nq, k), worst_score(self.metric), np.float32)
        out_i = np.full((nq, k), -1, np.int64)
        for r in range(nq):
            if pools_s[r]:
                s, i = _merge_topk(self.metric, pools_s[r], pools_i[r], k)
                out_s[r, : s.shape[1]] = s[0]
                out_i[r, : i.shape[1]] = i[0]
        return out_s, out_i

    def _state(self):
        return {
            **self._base_state(),
            "codebooks": self.codebooks,
            "codes": self.codes,
            "perm_assign": self._perm_assign,
        }

    def _load_state(self, state):
        self._load_base_state(state)
        self.codebooks = state["codebooks"]
        self.codes = state["codes"]
        self._perm_assign = state["perm_assign"]
        self.m, self.ksub = self.codebooks.shape[0], self.codebooks.shape[1]
        self.num_rows = len(self.codes)
