"""Inverted-file indexes: IVF-FLAT, IVF-SQ, IVF-PQ (paper Table 1).

Vectors are grouped into ``nlist`` k-means clusters; a query scans only the
``nprobe`` most promising lists.  Lists are stored contiguously (CSR-style)
so each probed list is one dense kernel scan — the TPU adaptation of the
cache-friendly layout Milvus uses on CPU.

Search is a fully vectorized batched pipeline (no per-query or per-list
Python loops):

1. **probe** — ``topk_scan`` over the centroids, as before;
2. **invert + gather-scan** — ``ops.ivf_probe_schedule`` inverts the probe
   matrix into a deduplicated (list -> query-group) schedule bucketed by
   padded size, and ``ops.ivf_gather_topk`` runs one fused scan per bucket
   (FLAT/SQ: batched shared contraction; PQ: a single batched residual-LUT
   ADC over all (query, list) pairs) and pools per-probe-slot top-k;
3. **reduce** — one ``ops.merge_topk`` call replaces the per-query
   ``np.argsort`` merges, and local offsets map to row ids with one
   vectorized take.

``search_batched`` extends the same pipeline across co-located segments
sharing an index spec: one dispatch returns every unit's candidate pool so
the query node merges exactly once.  Set ``REPRO_IVF_REFERENCE=1`` to run
the scalar per-list reference path instead (the equivalence oracle).

IVF-PQ encodes residuals (x - centroid) which materially improves recall at
the same code budget.
"""

from __future__ import annotations

import os

import numpy as np

from ..core.collection import Metric
from ..kernels import ops
from .base import VectorIndex, normalize_if_cosine, scan_metric, worst_score
from .kmeans import kmeans
from .pq import adc_tables, pq_encode, train_pq_codebooks


def use_reference() -> bool:
    """True when the scalar per-list oracle path is forced via env."""
    return os.environ.get("REPRO_IVF_REFERENCE") == "1"


class IVFBase(VectorIndex):
    def __init__(
        self, metric: Metric = Metric.L2, nlist: int = 64, nprobe: int = 8, **params
    ):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, **params)
        self.nlist = nlist
        self.nprobe = nprobe
        self.centroids: np.ndarray | None = None
        self.list_offsets: np.ndarray | None = None  # [nlist+1] CSR offsets
        self.row_ids: np.ndarray | None = None  # [n] permutation: list order -> original

    def _partition(self, x: np.ndarray) -> np.ndarray:
        """Cluster and build CSR layout; returns x permuted to list order."""
        self.centroids, assign = kmeans(x, min(self.nlist, max(1, len(x))), seed=0)
        self.nlist = len(self.centroids)
        order = np.argsort(assign, kind="stable")
        counts = np.bincount(assign, minlength=self.nlist)
        self.list_offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.row_ids = order.astype(np.int64)
        return x[order]

    def _probe_lists(self, q: np.ndarray, nprobe: int) -> np.ndarray:
        """[nq, nprobe] most promising list ids per query (-1 = padded)."""
        nprobe = min(nprobe, self.nlist)
        # For IP, the best lists are by centroid similarity; for L2 by distance.
        vals, idx = ops.topk_scan(
            q, self.centroids, nprobe, metric=scan_metric(self.metric)
        )
        return idx

    def _effective_nprobe(self) -> int:
        return int(self.params.get("nprobe", self.nprobe))

    # ------------------------------------------------- batched scan pipeline
    def _bucket_scorer(self, q: np.ndarray, valid_perm, sched):
        """Return ``(score_fn, q_offset)``: ``score_fn(bucket) -> [B, G, W]``
        min-semantics scores with dead slots at +inf, and an optional
        per-query additive constant ``q_offset [nq]`` the scan defers (it
        cannot change any per-query ranking, so it is added back to the
        pooled candidates in one cheap pass instead of per scanned cell)."""
        raise NotImplementedError

    def _row_bias(self, b: ops.IVFBucket, valid_perm, base=None):
        """Per-row additive bias [B, W] for a bucket's scan: ``base`` values
        (row norms etc., or zero) with +inf folded in for padding and
        masked-invisible rows — masking costs one [B, W] pass instead of a
        [B, G, W] one.  Returns None when there is nothing to add."""
        dead = None if b.full else ~b.wmask
        if valid_perm is not None:
            bad = ~valid_perm[b.rows]
            dead = bad if dead is None else (dead | bad)
        if base is None:
            if dead is None:
                return None
            bias = np.zeros(b.rows.shape, np.float32)
        else:
            bias = base[b.rows]  # fancy gather: already a fresh f32 array
        if dead is not None:
            np.copyto(bias, np.float32(np.inf), where=dead)
        return bias

    def _pool_candidates(
        self, q: np.ndarray, k: int, valid_perm: np.ndarray | None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Probe + bucketed gather-scan; returns the candidate pool
        ``(scores [nq, nprobe*k], ids [nq, nprobe*k])`` in the metric's
        natural scale with original row ids (-1 = empty slot)."""
        probes = self._probe_lists(q, self._effective_nprobe())
        sched = ops.ivf_probe_schedule(probes, self.list_offsets)
        score_fn, q_offset = self._bucket_scorer(q, valid_perm, sched)
        pool_s, pool_rows = ops.ivf_gather_topk(sched, k, score_fn)
        if q_offset is not None:
            pool_s = pool_s + q_offset[:, None]  # fills stay +inf
        # local CSR offsets -> original row ids: one vectorized take
        ids = np.where(
            pool_rows >= 0,
            self.row_ids[np.clip(pool_rows, 0, len(self.row_ids) - 1)],
            -1,
        )
        if self.metric is not Metric.L2:  # back to descending similarity
            pool_s = np.where(ids >= 0, -pool_s, np.float32(-np.inf))
        return pool_s, ids

    def search(self, queries, k, valid=None):
        if use_reference():
            return self._search_reference(queries, k, valid)
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        valid_perm = None if valid is None else np.asarray(valid)[self.row_ids]
        pool_s, ids = self._pool_candidates(q, k, valid_perm)
        return ops.merge_topk(pool_s, ids, k, metric=scan_metric(self.metric))

    @classmethod
    def search_batched(cls, indexes, queries, k, valids=None):
        """All co-located IVF units of one spec in one dispatch: shared
        query prep, per-unit probe + bucketed gather-scan, raw candidate
        pools returned unreduced (the caller merges once)."""
        if use_reference() or not indexes:
            return super().search_batched(indexes, queries, k, valids)
        if valids is None:
            valids = [None] * len(indexes)
        q = normalize_if_cosine(
            indexes[0].metric, np.asarray(queries, np.float32)
        )
        ss, ii, splits = [], [], [0]
        for idx, v in zip(indexes, valids):  # per-segment, not per-list
            vp = None if v is None else np.asarray(v)[idx.row_ids]
            s, i = idx._pool_candidates(q, k, vp)
            ss.append(s)
            ii.append(i)
            splits.append(splits[-1] + s.shape[1])
        return np.concatenate(ss, axis=1), np.concatenate(ii, axis=1), splits

    # ------------------------------------------------- scalar reference path
    # Subclasses implement one-list scan over the permuted storage.
    def _scan_range(
        self, q: np.ndarray, lo: int, hi: int, k: int, valid_perm: np.ndarray | None
    ) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def _search_reference(self, queries, k, valid=None):
        """The pre-vectorization per-list loop, kept as the equivalence
        oracle for the batched pipeline (``REPRO_IVF_REFERENCE=1``)."""
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        nq = len(q)
        probes = self._probe_lists(q, self._effective_nprobe())  # [nq, nprobe]
        valid_perm = None
        if valid is not None:
            valid_perm = np.asarray(valid)[self.row_ids]

        # Group queries by probed list so each list is scanned once per
        # batch — the paper's request batching at the segment level.
        out_s = np.full((nq, k), worst_score(self.metric), np.float32)
        out_i = np.full((nq, k), -1, np.int64)
        unique_lists = np.unique(probes)
        per_q_scores: list[list[np.ndarray]] = [[] for _ in range(nq)]
        per_q_ids: list[list[np.ndarray]] = [[] for _ in range(nq)]
        for lst in unique_lists:
            if lst < 0:
                continue
            qmask = (probes == lst).any(axis=1)
            lo, hi = int(self.list_offsets[lst]), int(self.list_offsets[lst + 1])
            if hi <= lo or not qmask.any():
                continue
            sub_q = q[qmask]
            s, i = self._scan_range(sub_q, lo, hi, min(k, hi - lo), valid_perm)
            # map local list offsets -> original row ids
            gi = np.where(i >= 0, self.row_ids[np.clip(i + lo, 0, len(self.row_ids) - 1)], -1)
            rows = np.nonzero(qmask)[0]
            for r_local, r in enumerate(rows):
                per_q_scores[r].append(s[r_local : r_local + 1])
                per_q_ids[r].append(gi[r_local : r_local + 1])
        for r in range(nq):
            if per_q_scores[r]:
                s = np.concatenate(per_q_scores[r], axis=1)
                i = np.concatenate(per_q_ids[r], axis=1)
                ms, mi = ops.merge_topk(s, i, k, metric=scan_metric(self.metric))
                out_s[r] = ms[0]
                out_i[r] = mi[0]
        return out_s, out_i

    def _base_state(self) -> dict[str, np.ndarray]:
        return {
            "centroids": self.centroids,
            "list_offsets": self.list_offsets,
            "row_ids": self.row_ids,
        }

    def _load_base_state(self, state) -> None:
        self.centroids = state["centroids"]
        self.list_offsets = state["list_offsets"]
        self.row_ids = state["row_ids"]
        self.nlist = len(self.centroids)


class IVFFlatIndex(IVFBase):
    KIND = "ivf_flat"

    def __init__(self, metric: Metric = Metric.L2, nlist: int = 64, nprobe: int = 8, **params):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, **params)
        self.storage: np.ndarray | None = None  # permuted vectors
        self._row_norms: np.ndarray | None = None  # lazy, not serialized

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        self.storage = self._partition(x)
        self._row_norms = None
        self.num_rows = len(x)

    def _bucket_scorer(self, q, valid_perm, sched):
        # L2 distance = qn - 2 q.x + rn: the -2 folds into the query operand,
        # rn (cached per row) joins the masking bias, and qn defers to the
        # pooled candidates — the scan is one gemm + one [B, W]-bias add.
        l2 = self.metric is Metric.L2
        if l2 and self._row_norms is None:
            self._row_norms = np.einsum("ij,ij->i", self.storage, self.storage)
        qs = -2.0 * q if l2 else -q
        base = self._row_norms if l2 else None
        storage = self.storage

        def score(b: ops.IVFBucket) -> np.ndarray:
            tile = storage[b.rows]  # [B, W, d]
            s = np.matmul(qs[b.q_idx], tile.transpose(0, 2, 1))  # [B, G, W]
            bias = self._row_bias(b, valid_perm, base)
            if bias is not None:
                s += bias[:, None, :]
            return s

        q_offset = np.einsum("ij,ij->i", q, q) if l2 else None
        return score, q_offset

    def _scan_range(self, q, lo, hi, k, valid_perm):
        v = None if valid_perm is None else valid_perm[lo:hi]
        return ops.topk_scan(
            q, self.storage[lo:hi], k, metric=scan_metric(self.metric), valid=v
        )

    def _state(self):
        return {**self._base_state(), "storage": self.storage}

    def _load_state(self, state):
        self._load_base_state(state)
        self.storage = state["storage"]
        self._row_norms = None
        self.num_rows = len(self.storage)


class IVFSQIndex(IVFBase):
    KIND = "ivf_sq"

    def __init__(self, metric: Metric = Metric.L2, nlist: int = 64, nprobe: int = 8, **params):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, **params)
        self.codes: np.ndarray | None = None
        self.vmin: np.ndarray | None = None
        self.vmax: np.ndarray | None = None
        self._row_norms: np.ndarray | None = None  # decoded-row norms, lazy

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        xp = self._partition(x)
        self.vmin, self.vmax = xp.min(axis=0), xp.max(axis=0)
        self.codes = ops.sq_encode(xp, self.vmin, self.vmax)
        self._row_norms = None
        self.num_rows = len(x)

    def _decode_params(self):
        vmin = np.asarray(self.vmin, np.float32)
        return vmin, ops.sq_scale(vmin, self.vmax)

    def _decoded_norms(self) -> np.ndarray:
        """||decode(code)||^2 per row, computed once (chunked decode)."""
        if self._row_norms is None:
            vmin, scale = self._decode_params()
            out = np.empty(len(self.codes), np.float32)
            for lo in range(0, len(self.codes), 65536):
                y = self.codes[lo : lo + 65536].astype(np.float32) * scale + vmin
                out[lo : lo + 65536] = np.einsum("ij,ij->i", y, y)
            self._row_norms = out
        return self._row_norms

    def _bucket_scorer(self, q, valid_perm, sched):
        # Fused dequantization: with y = code*scale + vmin, the distance
        # q.y contraction runs directly on the CASTED codes by folding the
        # scale into the query operand and q.vmin into the deferred
        # per-query constant; decoded-row norms are a cached [n] bias.
        vmin, scale = self._decode_params()
        l2 = self.metric is Metric.L2
        qs = (-2.0 * q if l2 else -q) * scale
        base = self._decoded_norms() if l2 else None
        codes = self.codes

        def score(b: ops.IVFBucket) -> np.ndarray:
            tile = codes[b.rows].astype(np.float32)  # [B, W, d]
            s = np.matmul(qs[b.q_idx], tile.transpose(0, 2, 1))
            bias = self._row_bias(b, valid_perm, base)
            if bias is not None:
                s += bias[:, None, :]
            return s

        qv = q @ vmin
        q_offset = np.einsum("ij,ij->i", q, q) - 2.0 * qv if l2 else -qv
        return score, q_offset

    def _scan_range(self, q, lo, hi, k, valid_perm):
        v = None if valid_perm is None else valid_perm[lo:hi]
        return ops.sq_topk_scan(
            q, self.codes[lo:hi], self.vmin, self.vmax, k,
            metric=scan_metric(self.metric), valid=v,
        )

    def _state(self):
        return {
            **self._base_state(),
            "codes": self.codes,
            "vmin": self.vmin,
            "vmax": self.vmax,
        }

    def _load_state(self, state):
        self._load_base_state(state)
        self.codes, self.vmin, self.vmax = state["codes"], state["vmin"], state["vmax"]
        self._row_norms = None
        self.num_rows = len(self.codes)


class IVFPQIndex(IVFBase):
    KIND = "ivf_pq"

    def __init__(
        self,
        metric: Metric = Metric.L2,
        nlist: int = 64,
        nprobe: int = 8,
        m: int = 8,
        ksub: int = 256,
        **params,
    ):
        super().__init__(metric, nlist=nlist, nprobe=nprobe, m=m, ksub=ksub, **params)
        self.m, self.ksub = m, ksub
        self.codebooks: np.ndarray | None = None
        self.codes: np.ndarray | None = None
        self._perm_assign: np.ndarray | None = None  # list id per permuted row
        self._scan_bias: np.ndarray | None = None  # per-row scan bias, lazy
        self._cb_flat: np.ndarray | None = None  # [m*ksub, dsub] flat codebook
        self._codes_off: np.ndarray | None = None  # codes + j*ksub offsets

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        xp = self._partition(x)
        assign = np.repeat(
            np.arange(self.nlist), np.diff(self.list_offsets).astype(int)
        )
        residual = xp - self.centroids[assign]
        self.codebooks = train_pq_codebooks(residual, self.m, self.ksub)
        self.codes = pq_encode(residual, self.codebooks)
        self._perm_assign = assign.astype(np.int32)
        self._scan_bias = self._cb_flat = self._codes_off = None
        self.num_rows = len(x)

    def _decode_setup(self) -> None:
        """Flat-LUT decode state: the codebook as one [m*ksub, dsub] table
        and the stored codes pre-offset by j*ksub, so tile decode is a
        SINGLE np.take whose every looked-up element is a contiguous dsub
        block — streaming copies instead of per-float LUT probes."""
        m, ksub, _dsub = self.codebooks.shape
        self._cb_flat = np.ascontiguousarray(
            self.codebooks.reshape(m * ksub, -1), np.float32
        )
        # int32 suffices: offsets are bounded by m*ksub (and keep the
        # cached array at 4x the codes instead of 8x)
        self._codes_off = self.codes.astype(np.int32) + (
            np.arange(m, dtype=np.int32) * ksub
        )

    def _decode_rows(self, rows) -> np.ndarray:
        """Residual reconstructions for a row-index tile [...,] -> [..., d]."""
        if self._codes_off is None:
            self._decode_setup()
        dsub = self._cb_flat.shape[1]
        rec = np.take(self._cb_flat, self._codes_off[rows], axis=0)
        return rec.reshape(np.shape(rows) + (self.codes.shape[1] * dsub,))

    def _ensure_scan_bias(self) -> np.ndarray:
        """Per-row scan bias, computed once per loaded index (chunked).

        Residual ADC against list ``l`` scores, for row reconstruction
        r = decode(code): L2 -> ||(q-c_l) - r||^2 = ||q - (c_l+r)||^2 =
        qn - 2 q.(c_l+r) + ||c_l+r||^2;  IP (negated) -> -(q-c_l).r =
        -q.r + c_l.r.  Both decompose into a gemm against the
        reconstruction tile plus a bias that depends only on the ROW
        (||c_l + r||^2, resp. c_l.r) — precomputed here — plus (L2) a
        per-pair -2 q.c_l constant and the deferred qn.
        """
        if self._scan_bias is None:
            cents = self.centroids[self._perm_assign]  # [n, d]
            out = np.empty(len(self.codes), np.float32)
            l2 = self.metric is Metric.L2
            for lo in range(0, len(self.codes), 65536):
                hi = min(lo + 65536, len(self.codes))
                rec = self._decode_rows(np.arange(lo, hi))
                c = cents[lo : lo + 65536]
                if l2:
                    y = c + rec
                    out[lo : lo + 65536] = np.einsum("ij,ij->i", y, y)
                else:
                    out[lo : lo + 65536] = np.einsum("ij,ij->i", c, rec)
            self._scan_bias = out
        return self._scan_bias

    def _bucket_scorer(self, q, valid_perm, sched):
        # Batched residual ADC via the reconstruction identity (see
        # _ensure_scan_bias): every (query, probed list) pair's LUT work
        # collapses into one gemm per bucket over decoded code tiles, a
        # precomputed per-row bias, and a vectorized per-pair constant.
        l2 = self.metric is Metric.L2
        base = self._ensure_scan_bias()
        qs = -2.0 * q if l2 else -q
        pair_const = None
        if l2:
            pc = self.centroids[sched.pair_list]
            pair_const = -2.0 * np.einsum(
                "ij,ij->i", q[sched.pair_q], pc
            ).astype(np.float32)

        def score(b: ops.IVFBucket) -> np.ndarray:
            rec = self._decode_rows(b.rows)  # [B, W, d]
            s = np.matmul(qs[b.q_idx], rec.transpose(0, 2, 1))
            if pair_const is not None:
                s += pair_const[b.pair_idx][:, :, None]
            bias = self._row_bias(b, valid_perm, base)
            if bias is not None:
                s += bias[:, None, :]
            return s

        q_offset = np.einsum("ij,ij->i", q, q) if l2 else None
        return score, q_offset

    def _search_reference(self, queries, k, valid=None):
        # Residual ADC oracle: LUTs recomputed per (query, probed list) on
        # q - centroid, scanning per list with shifted queries.
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        nq = len(q)
        probes = self._probe_lists(q, self._effective_nprobe())
        valid_perm = None if valid is None else np.asarray(valid)[self.row_ids]
        pools_s: list[list[np.ndarray]] = [[] for _ in range(nq)]
        pools_i: list[list[np.ndarray]] = [[] for _ in range(nq)]
        for lst in np.unique(probes):
            if lst < 0:
                continue
            lo, hi = int(self.list_offsets[lst]), int(self.list_offsets[lst + 1])
            qmask = (probes == lst).any(axis=1)
            if hi <= lo or not qmask.any():
                continue
            sub_q = q[qmask] - self.centroids[lst][None, :]
            luts = adc_tables(sub_q, self.codebooks, self.metric)
            v = None if valid_perm is None else valid_perm[lo:hi]
            s, i = ops.pq_adc_topk(luts, self.codes[lo:hi], min(k, hi - lo), valid=v)
            if self.metric is not Metric.L2:
                s = -s
            gi = np.where(i >= 0, self.row_ids[np.clip(i + lo, 0, len(self.row_ids) - 1)], -1)
            rows = np.nonzero(qmask)[0]
            for r_local, r in enumerate(rows):
                pools_s[r].append(s[r_local : r_local + 1])
                pools_i[r].append(gi[r_local : r_local + 1])
        out_s = np.full((nq, k), worst_score(self.metric), np.float32)
        out_i = np.full((nq, k), -1, np.int64)
        for r in range(nq):
            if pools_s[r]:
                s = np.concatenate(pools_s[r], axis=1)
                i = np.concatenate(pools_i[r], axis=1)
                ms, mi = ops.merge_topk(s, i, k, metric=scan_metric(self.metric))
                out_s[r] = ms[0]
                out_i[r] = mi[0]
        return out_s, out_i

    def _state(self):
        return {
            **self._base_state(),
            "codebooks": self.codebooks,
            "codes": self.codes,
            "perm_assign": self._perm_assign,
        }

    def _load_state(self, state):
        self._load_base_state(state)
        self.codebooks = state["codebooks"]
        self.codes = state["codes"]
        self._perm_assign = state["perm_assign"]
        self._scan_bias = self._cb_flat = self._codes_off = None
        self.m, self.ksub = self.codebooks.shape[0], self.codebooks.shape[1]
        self.num_rows = len(self.codes)
