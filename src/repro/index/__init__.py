from .base import IndexSpec, VectorIndex
from .bucket import BucketIndex
from .flat import FlatIndex, SQIndex
from .hnsw import HNSWIndex
from .ivf import IVFFlatIndex, IVFPQIndex, IVFSQIndex
from .pq import OPQIndex, PQIndex
from .registry import INDEX_KINDS, create_index

__all__ = [
    "IndexSpec",
    "VectorIndex",
    "BucketIndex",
    "FlatIndex",
    "SQIndex",
    "HNSWIndex",
    "IVFFlatIndex",
    "IVFPQIndex",
    "IVFSQIndex",
    "OPQIndex",
    "PQIndex",
    "INDEX_KINDS",
    "create_index",
]
