"""Bucketed hierarchical-k-means index — the paper's SSD design (§4.4),
adapted to the TPU memory hierarchy.

The paper (winner of NeurIPS'21 big-ANN track 2, SPANN-like):

  * hierarchical k-means packs vectors into buckets sized just under one
    SSD read unit (4 KB), each bucket 4 KB-aligned on disk;
  * bucket *centers* stay in DRAM, organized by a fast in-memory index;
  * queries: (1) search centers in DRAM, (2) fetch the chosen buckets from
    SSD, (3) scan them — with SQ compression to cut fetched bytes;
  * multi-assignment: hierarchical k-means runs ``r`` times so border
    vectors are replicated into several buckets (the LSH multi-table
    trick), trading space for recall.

TPU translation (DESIGN.md §3): HBM plays the role of SSD and VMEM the role
of DRAM-page cache.  Buckets are sized to a VMEM tile quantum — a multiple
of the 128-row MXU tile — so every bucket fetch is one aligned HBM→VMEM
stream with zero read amplification.  Centers live in an in-"DRAM" index
(FLAT or IVF over centers).  Bucket payloads are SQ-compressed; distances
are computed on codes by the fused ``sq_l2_topk`` kernel.
"""

from __future__ import annotations

import numpy as np

from ..core.collection import Metric
from ..kernels import ops
from .base import VectorIndex, normalize_if_cosine, scan_metric, worst_score
from .kmeans import balanced_kmeans

#: Rows per bucket quantum — the "4 KB page" analogue: one 128-row MXU tile.
BUCKET_ROW_QUANTUM = 128


class BucketIndex(VectorIndex):
    KIND = "bucket"

    def __init__(
        self,
        metric: Metric = Metric.L2,
        target_bucket_rows: int = 96,
        replicas: int = 2,
        nprobe_buckets: int = 8,
        compress: bool = True,
        **params,
    ):
        super().__init__(
            metric,
            target_bucket_rows=target_bucket_rows,
            replicas=replicas,
            nprobe_buckets=nprobe_buckets,
            compress=compress,
            **params,
        )
        self.target_bucket_rows = target_bucket_rows
        self.replicas = replicas
        self.nprobe_buckets = nprobe_buckets
        self.compress = compress

        self.centers: np.ndarray | None = None  # [B, d] in-"DRAM"
        self.bucket_offsets: np.ndarray | None = None  # [B+1]
        self.bucket_rows: np.ndarray | None = None  # [n_slots] -> original row id
        self.storage: np.ndarray | None = None  # f32 [n_slots, d] or SQ codes
        self.vmin: np.ndarray | None = None
        self.vmax: np.ndarray | None = None

    def build(self, vectors: np.ndarray) -> None:
        x = normalize_if_cosine(self.metric, np.asarray(vectors, np.float32))
        n, d = x.shape
        self.num_rows = n
        if n == 0:
            self.centers = np.zeros((0, d), np.float32)
            self.bucket_offsets = np.zeros(1, np.int64)
            self.bucket_rows = np.zeros(0, np.int64)
            self.storage = np.zeros((0, d), np.float32)
            return

        all_centers: list[np.ndarray] = []
        slot_rows: list[np.ndarray] = []
        offsets = [0]
        # Multi-assignment: r independent hierarchical clusterings; border
        # vectors land in different buckets each run (paper's LSH trick).
        max_rows = BUCKET_ROW_QUANTUM
        for rep in range(self.replicas):
            centers, assign = balanced_kmeans(
                x,
                target_cluster_size=min(self.target_bucket_rows, max_rows),
                max_cluster_size=max_rows,
                seed=1000 + rep,
            )
            for b in range(len(centers)):
                rows = np.nonzero(assign == b)[0]
                if len(rows) == 0:
                    continue
                all_centers.append(centers[b])
                slot_rows.append(rows)
                offsets.append(offsets[-1] + len(rows))

        self.centers = np.stack(all_centers).astype(np.float32)
        self.bucket_offsets = np.asarray(offsets, np.int64)
        self.bucket_rows = np.concatenate(slot_rows).astype(np.int64)
        payload = x[self.bucket_rows]
        if self.compress:
            self.vmin = payload.min(axis=0)
            self.vmax = payload.max(axis=0)
            self.storage = ops.sq_encode(payload, self.vmin, self.vmax)
        else:
            self.storage = payload

    def _scan_slots(self, q, lo, hi, k, valid_slots):
        if self.compress:
            return ops.sq_topk_scan(
                q, self.storage[lo:hi], self.vmin, self.vmax, k,
                metric=scan_metric(self.metric), valid=valid_slots,
            )
        return ops.topk_scan(
            q, self.storage[lo:hi], k, metric=scan_metric(self.metric), valid=valid_slots
        )

    def search(self, queries, k, valid=None):
        q = normalize_if_cosine(self.metric, np.asarray(queries, np.float32))
        nq = len(q)
        out_s = np.full((nq, k), worst_score(self.metric), np.float32)
        out_i = np.full((nq, k), -1, np.int64)
        if self.num_rows == 0 or len(self.centers) == 0:
            return out_s, out_i
        nprobe = min(int(self.params.get("nprobe_buckets", self.nprobe_buckets)),
                     len(self.centers))
        # Stage 1: center search in-"DRAM".
        _cs, probes = ops.topk_scan(
            q, self.centers, nprobe, metric=scan_metric(self.metric)
        )
        valid_slots_all = None
        if valid is not None:
            valid_slots_all = np.asarray(valid)[self.bucket_rows]

        # Stage 2: stream chosen buckets from "SSD"(HBM) and scan.
        for r in range(nq):
            cand_s: list[np.ndarray] = []
            cand_i: list[np.ndarray] = []
            for b in probes[r]:
                if b < 0:
                    continue
                lo, hi = int(self.bucket_offsets[b]), int(self.bucket_offsets[b + 1])
                if hi <= lo:
                    continue
                vs = None if valid_slots_all is None else valid_slots_all[lo:hi]
                s, i = self._scan_slots(q[r : r + 1], lo, hi, min(k, hi - lo), vs)
                gi = np.where(i >= 0, self.bucket_rows[np.clip(i + lo, 0, len(self.bucket_rows) - 1)], -1)
                cand_s.append(s)
                cand_i.append(gi)
            if not cand_s:
                continue
            s = np.concatenate(cand_s, axis=1)[0]
            i = np.concatenate(cand_i, axis=1)[0]
            # Dedup multi-assigned rows (keep best), as the proxy does.
            order = np.argsort(s if self.metric is Metric.L2 else -s, kind="stable")
            seen: set[int] = set()
            slot = 0
            for j in order:
                if i[j] < 0 or int(i[j]) in seen:
                    continue
                seen.add(int(i[j]))
                out_s[r, slot] = s[j]
                out_i[r, slot] = i[j]
                slot += 1
                if slot >= k:
                    break
        return out_s, out_i

    def _state(self):
        state = {
            "centers": self.centers,
            "bucket_offsets": self.bucket_offsets,
            "bucket_rows": self.bucket_rows,
            "storage": self.storage,
            "compress": np.int64(1 if self.compress else 0),
        }
        if self.compress:
            state["vmin"] = self.vmin
            state["vmax"] = self.vmax
        return state

    def _load_state(self, state):
        self.centers = state["centers"]
        self.bucket_offsets = state["bucket_offsets"]
        self.bucket_rows = state["bucket_rows"]
        self.storage = state["storage"]
        self.compress = bool(int(state["compress"]))
        if self.compress:
            self.vmin, self.vmax = state["vmin"], state["vmax"]
        self.num_rows = int(self.bucket_rows.max()) + 1 if len(self.bucket_rows) else 0
