"""ManuSystem: wires the full architecture and exposes the PyManu-style API
(paper Table 2).

    manu = ManuSystem(ManuConfig(num_query_nodes=2))
    coll = manu.create_collection("products", dim=128)
    coll.insert({"vector": vecs})
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 64})
    res = coll.search(queries, limit=10, staleness_ms=100.0)

The declarative surface (``core/request.py``) drives the same pipeline:

    res = coll.search(SearchRequest(
        anns=[AnnsQuery("vector", q1, weight=0.7),
              AnnsQuery("img_vec", q2, weight=0.3)],
        k=10, consistency=ConsistencyLevel.BOUNDED,
        filter="price < 50", radius=120.0, output_fields=("price",),
        ranker=Ranker.rrf(),
    ))

Two driving modes:

* **cooperative** (default) — deterministic: every API call pumps the
  component state machines until quiescent; consistency waits advance the
  clock and emit time-ticks explicitly.  This is what the tests use.
* **threaded** — background threads pump components and loggers emit ticks
  on the wall clock; searches block on watermarks.  Used by the latency /
  elasticity benchmarks (Figs 9, 12).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from .collection import CollectionInfo, FieldSchema, FieldType, Metric, Schema
from .compaction import CompactionCoordinator, CompactionNode, GCReaper
from .consistency import ConsistencyLevel, GuaranteeTs
from .coordinator import (
    DataCoordinator,
    IndexCoordinator,
    QueryCoordinator,
    RootCoordinator,
)
from .data_node import DataNode
from .index_node import IndexNode
from .log import COORD_CHANNEL, EntryType, LogBroker, LogEntry, dml_channel
from .logger_node import Logger
from .meta_store import MetaStore
from .object_store import MemoryObjectStore, ObjectStore
from .proxy import BatchingProxy, Proxy, SearchResult
from .query_node import QueryNode
from .request import (
    AnnsQuery,
    ClusterState,
    DeleteRequest,
    DescribeCollection,
    HistogramRow,
    IndexDescription,
    InsertRequest,
    MetricsSnapshot,
    MutationRequest,
    MutationResult,
    NodeStatus,
    Ranker,
    SearchRequest,
    SegmentPlacement,
    UpsertRequest,
    vector_column_of,
)
from .segment import DEFAULT_PARTITION
from .telemetry import Event, EventLog, MetricsRegistry
from .time_travel import RestoredCollection, TimeTravel
from .timestamp import INFINITE_STALENESS, TSO, Clock, ManualClock


@dataclass
class ManuConfig:
    num_shards: int = 2
    num_loggers: int = 2
    num_data_nodes: int = 1
    num_index_nodes: int = 1
    num_query_nodes: int = 2
    num_compaction_nodes: int = 1
    seal_rows: int = 8_192
    slice_rows: int = 2_048
    compaction_delete_ratio: float = 0.2
    compaction_small_fraction: float = 0.5
    gc_retention_ms: float = 0.0  # 0 = horizon may advance to "now"
    tick_interval_ms: float = 50.0
    default_staleness_ms: float = INFINITE_STALENESS
    manual_clock: bool = True
    threaded: bool = False
    pump_sleep_s: float = 0.002
    # Serving-tier replication (paper §6.3 elasticity): every sealed
    # segment is loaded by this many query nodes (collections may override
    # via ``create_collection(..., replication_factor=)``).  Fewer live
    # nodes than replicas degrades gracefully: the placement record is
    # flagged under-replicated and the reconciler heals it on node join.
    replication_factor: int = 1
    heartbeat_ttl_ms: float = 5_000.0
    reconcile_interval_s: float = 0.25  # threaded-mode watchdog cadence


class ManuCollection:
    """ORM-style handle (PyManu's ``Collection``)."""

    def __init__(self, system: "ManuSystem", info: CollectionInfo):
        self.system = system
        self.info = info
        self.last_write_ts = 0

    @property
    def name(self) -> str:
        return self.info.name

    def mutate(self, request: MutationRequest) -> MutationResult:
        """Execute one typed mutation through the full pipeline
        (client -> proxy -> logger -> WAL) and return its
        :class:`MutationResult` watermark."""
        return self.system.mutate(self, request)

    def insert(
        self, rows, partition: str | None = None
    ) -> "int | MutationResult":
        """Insert a batch.

        Accepts either a typed :class:`InsertRequest` (returned value is
        its :class:`MutationResult`) or the legacy ``rows`` dict — a thin
        facade packing the dict into an ``InsertRequest`` and returning
        the bare LSN exactly as before; both run the same pipeline.
        """
        if isinstance(rows, InsertRequest):
            if partition is not None:
                raise ValueError(
                    "pass partition inside the InsertRequest, not as a kwarg"
                )
            return self.mutate(rows)
        return self.mutate(
            InsertRequest(rows, partition=partition or DEFAULT_PARTITION)
        ).watermark_ts

    def upsert(
        self, rows, partition: str | None = None
    ) -> MutationResult:
        """Insert-or-replace by primary key: ONE WAL record per shard
        carries the delete-by-pk and insert halves, so visibility flips
        atomically at ``MutationResult.watermark_ts``."""
        if isinstance(rows, UpsertRequest):
            if partition is not None:
                raise ValueError(
                    "pass partition inside the UpsertRequest, not as a kwarg"
                )
            return self.mutate(rows)
        return self.mutate(
            UpsertRequest(rows, partition=partition or DEFAULT_PARTITION)
        )

    def delete(self, pks) -> "int | MutationResult":
        """Delete by primary key.  A typed :class:`DeleteRequest` returns
        its :class:`MutationResult`; the legacy array form returns the
        bare LSN.  Empty or provably no-match deletes publish nothing and
        hand back an already-covered watermark."""
        if isinstance(pks, DeleteRequest):
            return self.mutate(pks)
        return self.mutate(DeleteRequest(np.asarray(pks))).watermark_ts

    # ------------------------------------------------------------ partitions
    def create_partition(self, partition: str) -> None:
        """Register a named partition as a placement target for writes and
        a pruning target for ``SearchRequest.partition_names``."""
        self.system.create_partition(self.name, partition)

    def drop_partition(self, partition: str) -> dict:
        """Drop a partition and release its segments everywhere; their
        binlogs are reclaimed by the next GC cycle."""
        return self.system.drop_partition(self.name, partition)

    def partitions(self) -> list[str]:
        return self.system.root_coord.partitions(self.name)

    def create_index(self, field: str, kind: str, params: dict | None = None) -> None:
        fs = self.info.schema.field(field)  # KeyError for unknown fields
        if fs.dtype is not FieldType.VECTOR:
            raise ValueError(
                f"create_index targets vector fields; '{field}' is {fs.dtype.value}"
            )
        self.system.index_coord.set_index_spec(
            self.name, field, kind, params, metric=self.info.metric,
            column=vector_column_of(self.info.schema, field),
        )
        # Handle-local mirror for introspection; the meta store
        # (index_coord.index_specs) stays the authoritative copy.
        self.info.index_specs[field] = {"kind": kind, "params": params or {}}
        # Batch indexing (paper §3.5): issue builds for already-sealed segments.
        for sid in self.system.data_coord.sealed_segments(self.name):
            self.system.index_coord.rebuild_segment(self.name, sid, fields=[field])
        if not self.system.config.threaded:
            self.system.run_until_idle()

    def flush(self) -> None:
        """Seal all growing segments and wait for archive + index builds."""
        self.system.data_coord.flush(self.name)
        if self.system.config.threaded:
            self.system.wait_idle()
        else:
            self.system.run_until_idle()

    def compact(self) -> dict:
        """Run one compaction cycle (purge deletes, merge small segments)."""
        return self.system.compact(self.name)

    def gc(self, horizon_ts: int | None = None) -> dict:
        """Advance the retention horizon and reclaim old binlog/index objects."""
        return self.system.gc(self.name, horizon_ts)

    def search(
        self,
        queries=None,
        limit: int = 10,
        staleness_ms: float | None = None,
        read_your_writes: bool = False,
        filter_expr: str | None = None,
        time_travel_ts: int | None = None,
        hedge_timeout_s: float | None = None,
        consistency: ConsistencyLevel | None = None,
        radius: float | None = None,
        range_filter: float | None = None,
        output_fields=(),
        partition_names=(),
        request: SearchRequest | None = None,
    ) -> SearchResult:
        """Search the collection.

        Accepts either a declarative :class:`SearchRequest` (as ``queries``
        or the ``request`` kwarg) or the legacy kwarg surface, which is a
        thin facade: the kwargs are packed into a single-field
        ``SearchRequest`` and executed by the exact same pipeline.
        """
        if isinstance(queries, SearchRequest):
            request = queries
        if request is not None:
            # A declarative request carries every option itself; reject
            # stray legacy kwargs instead of silently dropping them.
            stray = {
                "limit": limit != 10,
                "staleness_ms": staleness_ms is not None,
                "read_your_writes": read_your_writes,
                "filter_expr": filter_expr is not None,
                "time_travel_ts": time_travel_ts is not None,
                "consistency": consistency is not None,
                "radius": radius is not None,
                "range_filter": range_filter is not None,
                "output_fields": bool(tuple(output_fields)),
                "partition_names": bool(tuple(partition_names)),
            }
            bad = [name for name, is_set in stray.items() if is_set]
            if bad:
                raise ValueError(
                    f"pass {bad} inside the SearchRequest, not as kwargs"
                )
        session_override = None
        if request is None:
            wants_session = (
                read_your_writes or consistency is ConsistencyLevel.SESSION
            )
            request = SearchRequest.single(
                queries,
                field=self.info.schema.vector_fields()[0].name,
                k=limit,
                consistency=consistency,
                staleness_ms=staleness_ms,
                session_ts=self.last_write_ts if wants_session else 0,
                filter=filter_expr,
                radius=radius,
                range_filter=range_filter,
                output_fields=tuple(output_fields),
                partition_names=tuple(partition_names),
                time_travel_ts=time_travel_ts,
            )
        elif (
            request.consistency is ConsistencyLevel.SESSION
            and request.session_ts == 0
        ):
            # SESSION with no explicit watermark reads this handle's last
            # write; passed as an override so the caller's request object is
            # never mutated (it may be reused across later writes).
            session_override = self.last_write_ts
        return self.system.search(
            self, request,
            hedge_timeout_s=hedge_timeout_s, session_ts=session_override,
        )

    def hybrid_search(
        self,
        anns: "list[AnnsQuery]",
        limit: int = 10,
        ranker: Ranker | None = None,
        **kw,
    ) -> SearchResult:
        """Multi-vector search: one AnnsQuery per vector field, fused by
        ``ranker`` (weighted-sum by default)."""
        request = SearchRequest(
            anns=anns, k=limit, ranker=ranker or Ranker.weighted(), **kw
        )
        return self.search(request)

    def query(self, queries: np.ndarray, limit: int, expr: str, **kw) -> SearchResult:
        """PyManu ``query``: vector search with boolean filter expression."""
        return self.search(queries, limit, filter_expr=expr, **kw)

    def describe(self) -> DescribeCollection:
        """Typed description of the collection: schema fields, partitions,
        declared indexes, entity count, sharding and replication — the
        structured replacement for picking through handle attributes."""
        specs = self.system.index_coord.index_specs(self.name)
        return DescribeCollection(
            name=self.name,
            fields=tuple(self.info.schema.fields),
            partitions=tuple(self.partitions()),
            indexes=tuple(
                IndexDescription(
                    field=f,
                    kind=s["kind"],
                    params=dict(s.get("params") or {}),
                    metric=Metric(s["metric"]),
                )
                for f, s in sorted(specs.items())
            ),
            num_entities=self.num_entities(),
            num_shards=self.info.num_shards,
            metric=self.info.metric,
            replication_factor=self.system.query_coord.replication_for(self.name),
        )

    def num_entities(self) -> int:
        """Rows of THIS collection across the cluster, counting each
        segment once even when replicated on several nodes (and preferring
        the sealed copy over a node's lingering growing twin)."""
        sealed_rows: dict[int, int] = {}
        growing_rows: dict[int, int] = {}
        for qn in self.system.query_nodes.values():
            if not qn.alive:
                continue
            for (_c, sid, is_sealed), n in qn.segment_rows(self.name).items():
                (sealed_rows if is_sealed else growing_rows)[sid] = n
        total = sum(sealed_rows.values())
        total += sum(n for sid, n in growing_rows.items() if sid not in sealed_rows)
        return total


class ManuSystem:
    def __init__(self, config: ManuConfig | None = None, store: ObjectStore | None = None):
        self.config = config or ManuConfig()
        self.clock: Clock = ManualClock(1_000_000) if self.config.manual_clock else Clock()
        self.tso = TSO(self.clock)
        self.broker = LogBroker()
        self.meta = MetaStore(self.clock)
        self.store = store or MemoryObjectStore()

        # One metrics registry and one bounded control-plane event log per
        # system; every component records into the shared registry, the
        # control loops (coordinators, reconciler, GC) emit typed events.
        self.telemetry = MetricsRegistry()
        self.event_log = EventLog(self.clock)

        self.root_coord = RootCoordinator(self.broker, self.meta, self.tso)
        self.data_coord = DataCoordinator(self.broker, self.meta, self.tso, self.clock)
        self.index_coord = IndexCoordinator(
            self.broker, self.meta, self.tso, events=self.event_log
        )
        self.query_coord = QueryCoordinator(
            self.broker, self.meta, self.tso, self.data_coord,
            replication_factor=self.config.replication_factor,
            heartbeat_ttl_ms=self.config.heartbeat_ttl_ms,
            events=self.event_log,
        )

        self.loggers = [
            Logger(f"logger-{i}", self.broker, self.tso, self.data_coord, self.clock,
                   self.config.tick_interval_ms, metrics=self.telemetry)
            for i in range(self.config.num_loggers)
        ]
        self.data_nodes = [
            DataNode(f"dn-{i}", self.broker, self.store, self.tso, self.data_coord,
                     metrics=self.telemetry)
            for i in range(self.config.num_data_nodes)
        ]
        self.index_nodes = [
            IndexNode(f"in-{i}", self.broker, self.store, self.meta, self.tso,
                      metrics=self.telemetry)
            for i in range(self.config.num_index_nodes)
        ]
        self.compaction_coord = CompactionCoordinator(
            self.broker, self.meta, self.tso, self.data_coord, self.store,
            delete_ratio=self.config.compaction_delete_ratio,
            small_fraction=self.config.compaction_small_fraction,
            retention_ms=self.config.gc_retention_ms,
            events=self.event_log,
        )
        self.compaction_nodes = [
            CompactionNode(f"cn-{i}", self.broker, self.store, self.meta, self.tso,
                           metrics=self.telemetry)
            for i in range(self.config.num_compaction_nodes)
        ]
        self.gc_reaper = GCReaper(self.broker, self.store, self.meta, self.tso,
                                  metrics=self.telemetry, events=self.event_log)
        self.query_nodes: dict[str, QueryNode] = {}
        for i in range(self.config.num_query_nodes):
            self._new_query_node()

        self.proxy = Proxy(
            "proxy-0", self.meta, self.tso, self.loggers, self.query_coord,
            self.query_nodes, metrics=self.telemetry,
        )
        self.batcher = BatchingProxy(self.proxy)
        self.time_travel = TimeTravel(self.broker, self.store)
        self.collections: dict[str, ManuCollection] = {}
        self._qn_counter = self.config.num_query_nodes

        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        if self.config.threaded:
            self.start_threads()

    # ------------------------------------------------------------- topology
    def _new_query_node(self) -> QueryNode:
        node_id = f"qn-{len(self.query_nodes)}"
        # ensure unique ids even after removals
        i = len(self.query_nodes)
        while f"qn-{i}" in self.query_nodes:
            i += 1
        node_id = f"qn-{i}"
        qn = QueryNode(node_id, self.broker, self.store, self.tso,
                       slice_rows=self.config.slice_rows,
                       metrics=self.telemetry)
        self.query_nodes[node_id] = qn
        self.query_coord.register_node(node_id)
        return qn

    def add_query_node(self) -> str:
        """Scale up: register the node, then let the reconciler heal any
        under-replicated segments onto it and rebalance toward even load."""
        qn = self._new_query_node()
        self.query_coord.reconciler.reconcile()
        if not self.config.threaded:
            self.run_until_idle()
        return qn.node_id

    def remove_query_node(self, node_id: str | None = None) -> str | None:
        """Graceful scale-down: mark the node draining, reconcile so its
        replicas are shed to survivors (load-before-release — a segment's
        last copy stays on the draining node until a replacement holds it,
        so pinned MVCC reads never hit a serving gap), then retire it."""
        live = [n for n, q in self.query_nodes.items() if q.alive]
        if len(live) <= 1:
            return None
        node_id = node_id or live[-1]
        self.query_coord.start_drain(node_id)
        self.query_coord.reconciler.reconcile()
        if not self.config.threaded:
            self.run_until_idle()  # survivors load their new replicas
        self.query_coord.deregister_node(node_id)
        self.query_coord.handle_failures()
        node = self.query_nodes.get(node_id)
        if node:
            node.alive = False
        for coll in self.collections.values():
            self.query_coord.assign_channels(coll.name, coll.info.num_shards)
        if not self.config.threaded:
            self.run_until_idle()
        return node_id

    def kill_query_node(self, node_id: str) -> None:
        """Simulated crash: no dereg — the lease must expire (failover test)."""
        self.query_nodes[node_id].alive = False

    def recover_failures(self) -> list[str]:
        """Expire dead leases and reconcile (the query coordinator's
        watchdog): failed nodes' segments are CAS-reassigned to surviving
        replicas, channels re-homed, under-replication healed."""
        st = self.query_coord.nodes
        for node_id, qn in self.query_nodes.items():
            if qn.alive and node_id in st:
                self.query_coord.heartbeat(node_id)
        # force lease expiry for dead nodes
        for node_id, qn in self.query_nodes.items():
            if not qn.alive and node_id in st:
                self.meta.revoke_lease(st[node_id].lease_id)
        report = self.query_coord.reconciler.reconcile()
        if not self.config.threaded:
            self.run_until_idle()
        return report["dead"]

    # ----------------------------------------------------------------- DDL
    def create_collection(
        self,
        name: str,
        dim: int,
        metric: Metric = Metric.L2,
        num_shards: int | None = None,
        extra_fields: list[FieldSchema] | None = None,
        seal_rows: int | None = None,
        schema: Schema | None = None,
        replication_factor: int | None = None,
    ) -> ManuCollection:
        """Create a collection.  The common int-pk + one-vector case is
        built from ``dim``/``extra_fields``; pass an explicit ``schema``
        for anything else (string primary keys, custom layouts).
        ``replication_factor`` overrides ``ManuConfig.replication_factor``
        for this collection's sealed segments."""
        schema = schema or Schema.simple(dim, metric, extra=extra_fields)
        info = self.root_coord.create_collection(
            name,
            schema,
            num_shards=num_shards or self.config.num_shards,
            metric=metric,
            seal_rows=seal_rows or self.config.seal_rows,
            replication_factor=(
                self.config.replication_factor
                if replication_factor is None
                else replication_factor
            ),
        )
        coll = ManuCollection(self, info)
        self.collections[name] = coll
        # Data nodes archive the WAL: shard channels round-robin over them.
        for shard in range(info.num_shards):
            dn = self.data_nodes[shard % len(self.data_nodes)]
            dn.subscribe(dml_channel(name, shard))
        self.query_coord.assign_channels(name, info.num_shards)
        if not self.config.threaded:
            self.pump()
        return coll

    def drop_collection(self, name: str) -> None:
        self.root_coord.drop_collection(name)
        self.collections.pop(name, None)

    # ---------------------------------------------------------- partitions
    def create_partition(self, name: str, partition: str) -> None:
        self.root_coord.create_partition(name, partition)
        if not self.config.threaded:
            self.pump()

    def drop_partition(self, name: str, partition: str) -> dict:
        """Drop a partition: unregister it, retire its sealed segments
        (reclaimed by the next GC cycle), discard its growing rows, and
        broadcast ``partition_dropped`` so serving nodes release their
        copies.  Like ``drop_collection``, the drop is not MVCC-gated:
        time-travel reads into the dropped partition stop working.

        Tombstones whose pks lived ONLY in the dropped partition can never
        be folded by a future compaction (their segments are gone), so the
        drop reuses the compaction machinery: it broadcasts them as
        ``tombstones_folded`` and the query nodes prune their delta-delete
        maps at the next retention-horizon advance — same unbounded-growth
        fix as PR 2's fold pruning."""
        from .binlog import read_binlog_column
        from .compaction import prune_folded

        if not self.config.threaded:
            self.run_until_idle()  # let in-flight seals land first
        else:
            self.wait_idle()
        ts = self.root_coord.drop_partition(name, partition)
        sids = self.data_coord.drop_partition_state(name, partition, ts)

        # pk accounting BEFORE nodes release anything: which pks vanish
        # with the partition, and which survive elsewhere?
        dropped_pks: list[np.ndarray] = [
            read_binlog_column(self.store, name, sid, "pk") for sid in sids
        ]
        surviving_pks: list[np.ndarray] = [
            read_binlog_column(self.store, name, sid, "pk")
            for sid in self.data_coord.sealed_segments(name)
        ]
        for node in list(self.query_nodes.values()) + self.data_nodes:
            for (coll, _sid), item in list(node.growing.items()):
                if coll != name:
                    continue
                seg = getattr(item, "segment", item)  # GrowingState | Segment
                (dropped_pks if seg.partition == partition else surviving_pks).append(
                    seg.pks()
                )

        self.broker.publish(
            COORD_CHANNEL,
            LogEntry(
                ts=self.tso.next(),
                type=EntryType.COORD,
                payload={
                    "msg": "partition_dropped",
                    "collection": name,
                    "partition": partition,
                    "segment_ids": sids,
                    "drop_ts": ts,
                },
            ),
        )
        for dn in self.data_nodes:
            dn.drop_partition(name, partition)

        exclusive = np.empty(0, np.int64)
        if dropped_pks:
            exclusive = np.unique(np.concatenate(dropped_pks))
            if surviving_pks:
                exclusive = np.setdiff1d(
                    exclusive, np.concatenate(surviving_pks), assume_unique=False
                )
        if exclusive.size:
            self.broker.publish(
                COORD_CHANNEL,
                LogEntry(
                    ts=self.tso.next(),
                    type=EntryType.COORD,
                    payload={
                        "msg": "tombstones_folded",
                        "collection": name,
                        "folded_pks": exclusive,
                        "compact_ts": ts,
                    },
                ),
            )
            pruned = prune_folded(
                self.compaction_coord.tombstones.get(name) or {}, exclusive, ts
            )
            if pruned is not None:
                self.compaction_coord.tombstones[name] = pruned
        if not self.config.threaded:
            self.run_until_idle()
        return {"partition": partition, "segments_dropped": len(sids)}

    # ------------------------------------------------------------ mutations
    def mutate(self, coll: ManuCollection, request: MutationRequest) -> MutationResult:
        """Run one typed mutation through the proxy pipeline, remember its
        watermark for SESSION reads on this handle, and (cooperative mode)
        pump the components so subscribers observe the WAL entries."""
        result = self.proxy.mutate(coll.info, request)
        coll.last_write_ts = result.watermark_ts
        if not self.config.threaded:
            self.pump()
        return result

    # ---------------------------------------------------------------- pump
    def pump(self, rounds: int = 1) -> bool:
        """One cooperative scheduling round over every component."""
        progress = False
        for _ in range(rounds):
            # Alive nodes heartbeat every round: consistency waits advance
            # the manual clock, which must never expire a *live* lease.
            for node_id, qn in self.query_nodes.items():
                if qn.alive and node_id in self.query_coord.nodes:
                    self.query_coord.heartbeat(node_id)
            for lg in self.loggers:
                lg.tick(self.broker.channels("dml/"))
            for dn in self.data_nodes:
                progress |= dn.step()
            progress |= self.index_coord.step()
            for ix in self.index_nodes:
                progress |= ix.step()
            progress |= self.compaction_coord.step()
            for cn in self.compaction_nodes:
                progress |= cn.step()
            progress |= self.query_coord.step()
            for qn in self.query_nodes.values():
                progress |= qn.step()
        return progress

    def run_until_idle(self, max_rounds: int = 10_000) -> int:
        rounds = 0
        while self.pump() and rounds < max_rounds:
            rounds += 1
        if rounds:
            self.event_log.emit(
                "run_until_idle", "system",
                rounds=rounds, truncated=rounds >= max_rounds,
            )
        return rounds

    def wait_idle(self, timeout_s: float = 30.0) -> None:
        deadline = time.time() + timeout_s
        polls = 0
        while time.time() < deadline:
            polls += 1
            stats = self.broker.stats()
            lag = 0
            for qn in self.query_nodes.values():
                if not qn.alive:
                    continue
                for sub in qn.subscriptions.values():
                    lag += sub.lag()
            if (
                lag == 0
                and self.compaction_coord.lag() == 0
                and not self.index_coord.pending_tasks
                and not self.compaction_coord.pending
            ):
                self.event_log.emit(
                    "wait_idle", "system", polls=polls, drained=True,
                )
                return
            time.sleep(0.005)
        self.event_log.emit(
            "wait_idle", "system", polls=polls, drained=False,
        )

    # --------------------------------------------------- compaction & GC
    def compact(self, name: str) -> dict:
        """One maintenance cycle: plan rewrites, execute, hot-swap.

        Returns {"tasks", "epoch", "rows_purged"} for THIS cycle; a no-op
        when the policy finds nothing to do.
        """
        # The coordinator must see all seals/deletes before planning.
        if self.config.threaded:
            self.wait_idle()
        else:
            self.run_until_idle()
        purged_before = sum(cn.rows_purged for cn in self.compaction_nodes)
        tasks = self.compaction_coord.plan(name)
        if self.config.threaded:
            self.wait_idle()
        else:
            self.run_until_idle()
        return {
            "tasks": len(tasks),
            "epoch": self.compaction_coord.segment_map.epoch(name),
            "rows_purged": sum(cn.rows_purged for cn in self.compaction_nodes)
            - purged_before,
        }

    def gc(self, name: str | None = None, horizon_ts: int | None = None) -> dict:
        """Advance the retention horizon and reclaim unreferenced objects
        of collection ``name`` (None = every collection).

        The horizon defaults to "now minus ``gc_retention_ms``"; segments
        referenced by time-travel checkpoints survive regardless.
        """
        from .timestamp import pack

        if horizon_ts is None:
            if self.config.gc_retention_ms > 0:
                horizon_ts = pack(
                    max(0, int(self.clock.now_ms() - self.config.gc_retention_ms)), 0
                )
            else:
                horizon_ts = self.tso.next()
        self.compaction_coord.advance_horizon(horizon_ts, collection=name)
        if not self.config.threaded:
            self.run_until_idle()
        report = self.gc_reaper.reap(horizon_ts, collection=name)
        if not self.config.threaded:
            self.run_until_idle()
        return report

    # -------------------------------------------------------------- search
    def search(
        self,
        coll: ManuCollection,
        queries,
        k: int | None = None,
        staleness_ms: float | None = None,
        session_ts: int | None = None,
        filter_expr: str | None = None,
        time_travel_ts: int | None = None,
        hedge_timeout_s: float | None = None,
    ) -> SearchResult:
        """Resolve a :class:`SearchRequest`'s consistency requirement into
        a pinned :class:`GuaranteeTs` and hand it to the proxy.  The legacy
        positional form is packed into a request first.  ``session_ts``
        overrides the request's watermark without mutating the request
        (SESSION reads resolved by the collection handle)."""
        if isinstance(queries, SearchRequest):
            request = queries
        else:
            request = SearchRequest.single(
                queries,
                field=coll.info.schema.vector_fields()[0].name,
                k=k if k is not None else 10,
                staleness_ms=staleness_ms,
                session_ts=session_ts or 0,
                filter=filter_expr,
                time_travel_ts=time_travel_ts,
            )
        effective_session = (
            request.session_ts if session_ts is None else session_ts
        )
        tau = request.resolve_staleness_ms(self.config.default_staleness_ms)
        if request.time_travel_ts is not None:
            # Historical reads never wait: the data is by definition old.
            query_ts = request.time_travel_ts
            guarantee = GuaranteeTs(query_ts=query_ts, staleness_ms=INFINITE_STALENESS)
        else:
            query_ts = self.tso.next()
            guarantee = GuaranteeTs(
                query_ts=query_ts, staleness_ms=tau, session_ts=effective_session
            )
        wait_fn = self._threaded_wait if self.config.threaded else self._cooperative_wait
        return self.proxy.search(
            coll.info, request, guarantee=guarantee,
            wait_fn=wait_fn, hedge_timeout_s=hedge_timeout_s,
        )

    def _cooperative_wait(self, node: QueryNode, guarantee: GuaranteeTs) -> None:
        collections = {c for (c, _s) in list(node.sealed) + list(node.growing)}
        channels = [ch for ch in node.subscriptions if ch.startswith("dml/")]
        if not channels:
            return
        target = guarantee.wait_target_ts()
        for _ in range(100_000):
            # Re-read each round: a reconcile during the pump may re-home a
            # channel off this node (its new owner runs its own wait).
            subs = [
                node.subscriptions[ch]
                for ch in channels
                if ch in node.subscriptions
            ]
            if not subs:
                return
            wm = min(s.last_tick_seen for s in subs)
            if wm >= target or guarantee.satisfied_by(wm):
                return
            if isinstance(self.clock, ManualClock):
                self.clock.advance(max(self.config.tick_interval_ms, 1))
            for lg in self.loggers:
                lg.tick(channels)
            self.pump()
        raise TimeoutError("consistency wait did not converge")

    def _threaded_wait(self, node: QueryNode, guarantee: GuaranteeTs) -> None:
        channels = [ch for ch in node.subscriptions if ch.startswith("dml/")]
        target = guarantee.wait_target_ts()
        for ch in channels:
            self.broker.wait_for_tick(ch, target, timeout_s=10.0)
        # ensure node consumed the ticks
        deadline = time.time() + 10.0
        while time.time() < deadline:
            if all(node.subscriptions[ch].last_tick_seen >= target for ch in channels
                   if ch in node.subscriptions):
                return
            time.sleep(0.001)

    # -------------------------------------------------------- time travel
    def checkpoint_collection(self, name: str) -> None:
        coll = self.collections[name]
        ts = self.tso.last_issued()
        replay = {}
        for shard in range(coll.info.num_shards):
            ch = dml_channel(name, shard)
            replay[ch] = self.data_coord.replay_position(name, shard)
        self.time_travel.checkpoint(
            name, ts, self.data_coord.sealed_segments(name),
            coll.info.num_shards, replay,
        )

    def restore_collection(self, name: str, target_ts: int) -> RestoredCollection:
        coll = self.collections[name]
        return self.time_travel.restore(
            name, target_ts, coll.info.num_shards, coll.info.dim()
        )

    # ------------------------------------------------------------- threads
    def start_threads(self) -> None:
        self._stop.clear()
        # The pump thread owns node stepping; the proxy's failover waits
        # must sleep instead of stepping nodes themselves.
        self.proxy.pump_fn = lambda: time.sleep(self.config.pump_sleep_s)

        def pump_loop():
            while not self._stop.is_set():
                self.pump()
                time.sleep(self.config.pump_sleep_s)

        def watchdog_loop():
            last_reconcile = 0.0
            while not self._stop.is_set():
                for node_id, qn in list(self.query_nodes.items()):
                    if qn.alive and node_id in self.query_coord.nodes:
                        self.query_coord.heartbeat(node_id)
                now = time.time()
                if now - last_reconcile >= self.config.reconcile_interval_s:
                    last_reconcile = now
                    self.query_coord.reconciler.reconcile()
                time.sleep(0.05)

        for fn in (pump_loop, watchdog_loop):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            self._threads.append(t)

    def stop_threads(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=2.0)
        self._threads.clear()
        self.proxy.pump_fn = None

    # ------------------------------------------------------------ metrics
    def metrics(self) -> MetricsSnapshot:
        """Typed, JSON-serializable snapshot of the shared metrics registry:
        every counter and gauge series, plus a :class:`HistogramRow` per
        latency histogram with p50/p95/p99 estimated from the log buckets."""
        counters, gauges, hists = self.telemetry.snapshot_rows()
        return MetricsSnapshot(
            ts_ms=self.clock.now_ms(),
            counters=counters,
            gauges=gauges,
            histograms=tuple(
                HistogramRow(name=k, count=total, mean=mean,
                             p50=p50, p95=p95, p99=p99)
                for (k, total, mean, p50, p95, p99) in hists
            ),
        )

    def events(self, since_ts: float | None = None,
               kind: str | None = None) -> list[Event]:
        """Control-plane event log: typed events from the coordinators,
        reconciler, compaction, and GC.  ``since_ts`` filters on the
        emission timestamp (ms, inclusive); ``kind`` on the event kind."""
        return self.event_log.query(since_ts=since_ts, kind=kind)

    def export_metrics(self) -> str:
        """Prometheus text-format exposition of the metrics registry."""
        return self.telemetry.export()

    def cluster_state(self) -> ClusterState:
        """Typed frozen snapshot of the serving tier: node health (as the
        ``HealthMonitor`` observes it), per-node load, the committed
        segment -> replica-group placement, and how many sealed segments
        are currently below their collection's replication factor."""
        coord = self.query_coord
        statuses = coord.health.observe()
        nodes = tuple(
            NodeStatus(
                node_id=n,
                status=statuses.get(n, "dead"),
                load=len(st.segments),
                segments=tuple(sorted(st.segments)),
                channels=tuple(sorted(st.channels)),
                searches=(
                    self.query_nodes[n].search_count
                    if n in self.query_nodes
                    else 0
                ),
                searches_primary=(
                    self.query_nodes[n].searches_primary
                    if n in self.query_nodes
                    else 0
                ),
                searches_hedged=(
                    self.query_nodes[n].searches_hedged
                    if n in self.query_nodes
                    else 0
                ),
            )
            for n, st in sorted(coord.nodes.items())
        )
        placements = []
        under = 0
        for (coll, sid), reps in sorted(coord.replica_sets.items()):
            rec = self.meta.get(f"assignment/{coll}/{sid}") or {}
            ur = bool(
                rec.get(
                    "under_replicated",
                    len(reps) < coord.replication_for(coll),
                )
            )
            under += int(ur)
            placements.append(
                SegmentPlacement(
                    collection=coll,
                    segment_id=sid,
                    replicas=tuple(reps),
                    under_replicated=ur,
                    visible_from_ts=int(rec.get("visible_from_ts", 0)),
                )
            )
        # Sealed segments with no committed placement at all (total outage)
        # count as under-replicated too: the reconciler owes them replicas.
        placed = set(coord.replica_sets)
        for key in self.meta.scan("collection/"):
            coll = key.split("/", 1)[1]
            for sid in self.data_coord.sealed_segments(coll):
                if (coll, sid) not in placed:
                    under += 1
                    placements.append(
                        SegmentPlacement(coll, sid, (), True, 0)
                    )
        return ClusterState(
            nodes=nodes,
            placement=tuple(placements),
            under_replicated=under,
            replication_factor=coord.replication_factor,
        )

    def stats(self) -> dict:
        """Legacy ad-hoc counters — a thin facade now; ``cluster_state()``
        is the typed view of the serving tier."""
        cs = self.cluster_state()
        status_of = {ns.node_id: ns.status for ns in cs.nodes}
        return {
            "log": self.broker.stats(),
            "object_store_puts": getattr(self.store, "put_count", -1),
            "query_nodes": {
                n: {
                    "rows": q.memory_rows(),
                    "alive": q.alive,
                    "searches": q.search_count,
                    "status": status_of.get(n, "dead"),
                }
                for n, q in self.query_nodes.items()
            },
            "cluster": {
                "under_replicated": cs.under_replicated,
                "replication_factor": cs.replication_factor,
            },
            "index_builds": sum(ix.builds_completed for ix in self.index_nodes),
            "compactions": sum(
                cn.compactions_completed for cn in self.compaction_nodes
            ),
            "rows_purged": sum(cn.rows_purged for cn in self.compaction_nodes),
            "gc_bytes_reclaimed": self.gc_reaper.bytes_reclaimed,
            "metrics": self.metrics().to_dict(),
            "events": len(self.event_log),
        }
