"""ManuSystem: wires the full architecture and exposes the PyManu-style API
(paper Table 2).

    manu = ManuSystem(ManuConfig(num_query_nodes=2))
    coll = manu.create_collection("products", dim=128)
    coll.insert({"vector": vecs})
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 64})
    res = coll.search(queries, limit=10, staleness_ms=100.0)

The declarative surface (``core/request.py``) drives the same pipeline:

    res = coll.search(SearchRequest(
        anns=[AnnsQuery("vector", q1, weight=0.7),
              AnnsQuery("img_vec", q2, weight=0.3)],
        k=10, consistency=ConsistencyLevel.BOUNDED,
        filter="price < 50", radius=120.0, output_fields=("price",),
        ranker=Ranker.rrf(),
    ))

Two driving modes:

* **cooperative** (default) — deterministic: every API call pumps the
  component state machines until quiescent; consistency waits advance the
  clock and emit time-ticks explicitly.  This is what the tests use.
* **threaded** — background threads pump components and loggers emit ticks
  on the wall clock; searches block on watermarks.  Used by the latency /
  elasticity benchmarks (Figs 9, 12).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from .collection import CollectionInfo, FieldSchema, FieldType, Metric, Schema
from .compaction import CompactionCoordinator, CompactionNode, GCReaper
from .consistency import ConsistencyLevel, GuaranteeTs
from .coordinator import (
    DataCoordinator,
    IndexCoordinator,
    QueryCoordinator,
    RootCoordinator,
)
from .data_node import DataNode
from .faults import (
    Crash,
    FaultInjector,
    FaultyLogBroker,
    FaultyMetaStore,
    FaultyObjectStore,
)
from .index_node import IndexNode
from .log import COORD_CHANNEL, EntryType, LogBroker, LogEntry, dml_channel
from .logger_node import Logger
from .meta_store import MetaStore
from .object_store import MemoryObjectStore, ObjectStore
from .retry import (
    RetryPolicy,
    RetryingLogBroker,
    RetryingMetaStore,
    RetryingObjectStore,
    default_sleep,
)
from .proxy import Proxy, SearchResult
from .query_node import QueryNode
from .scheduler import (
    AdmissionRejected,  # noqa: F401 — re-exported API surface
    BatchingProxy,
    MutationTicket,
    RequestScheduler,
)
from .request import (
    AnnsQuery,
    ClusterState,
    DeleteRequest,
    DescribeCollection,
    HistogramRow,
    IndexDescription,
    InsertRequest,
    MetricsSnapshot,
    MutationRequest,
    MutationResult,
    NodeStatus,
    Ranker,
    SearchRequest,
    SegmentPlacement,
    UpsertRequest,
    vector_column_of,
)
from .segment import DEFAULT_PARTITION
from .telemetry import Event, EventLog, MetricsRegistry
from .time_travel import RestoredCollection, TimeTravel
from .timestamp import INFINITE_STALENESS, TSO, Clock, ManualClock


@dataclass
class ManuConfig:
    num_shards: int = 2
    num_loggers: int = 2
    num_data_nodes: int = 1
    num_index_nodes: int = 1
    num_query_nodes: int = 2
    num_compaction_nodes: int = 1
    seal_rows: int = 8_192
    slice_rows: int = 2_048
    compaction_delete_ratio: float = 0.2
    compaction_small_fraction: float = 0.5
    gc_retention_ms: float = 0.0  # 0 = horizon may advance to "now"
    tick_interval_ms: float = 50.0
    default_staleness_ms: float = INFINITE_STALENESS
    # BOUNDED consistency's staleness window (ms): how far behind "now" a
    # ConsistencyLevel.BOUNDED read may observe.  Threaded through the
    # proxy and request resolution so the named level is deployment-tunable.
    bounded_staleness_ms: float = 2_000.0
    # Serving-tier ingest scheduler (paper §3.6 request batching): each
    # (collection, shard) write queue holds at most ``ingest_queue_rows``
    # rows (credit-based backpressure — AdmissionRejected beyond that),
    # flushes one micro-batched WAL crossing at ``ingest_flush_rows``
    # accumulated rows, and never holds an admitted request longer than
    # ``ingest_flush_ms`` (age trigger, checked by the pump).
    ingest_queue_rows: int = 8_192
    ingest_flush_rows: int = 1_024
    ingest_flush_ms: float = 20.0
    manual_clock: bool = True
    threaded: bool = False
    pump_sleep_s: float = 0.002
    # Serving-tier replication (paper §6.3 elasticity): every sealed
    # segment is loaded by this many query nodes (collections may override
    # via ``create_collection(..., replication_factor=)``).  Fewer live
    # nodes than replicas degrades gracefully: the placement record is
    # flagged under-replicated and the reconciler heals it on node join.
    replication_factor: int = 1
    heartbeat_ttl_ms: float = 5_000.0
    reconcile_interval_s: float = 0.25  # threaded-mode watchdog cadence
    # Typed retry/backoff for object-store, meta-store and log-broker I/O
    # (None = default policy).  Deterministic: the policy's seed drives the
    # backoff jitter.
    retry_policy: "RetryPolicy | None" = None


class ManuCollection:
    """ORM-style handle (PyManu's ``Collection``)."""

    def __init__(self, system: "ManuSystem", info: CollectionInfo):
        self.system = system
        self.info = info
        self.last_write_ts = 0

    @property
    def name(self) -> str:
        return self.info.name

    def mutate(self, request: MutationRequest) -> MutationResult:
        """Execute one typed mutation through the full pipeline
        (client -> proxy -> logger -> WAL) and return its
        :class:`MutationResult` watermark."""
        return self.system.mutate(self, request)

    def insert(
        self, rows, partition: str | None = None
    ) -> "int | MutationResult":
        """Insert a batch.

        Accepts either a typed :class:`InsertRequest` (returned value is
        its :class:`MutationResult`) or the legacy ``rows`` dict — a thin
        facade packing the dict into an ``InsertRequest`` and returning
        the bare LSN exactly as before; both run the same pipeline.
        """
        if isinstance(rows, InsertRequest):
            if partition is not None:
                raise ValueError(
                    "pass partition inside the InsertRequest, not as a kwarg"
                )
            return self.mutate(rows)
        return self.mutate(
            InsertRequest(rows, partition=partition or DEFAULT_PARTITION)
        ).watermark_ts

    def upsert(
        self, rows, partition: str | None = None
    ) -> MutationResult:
        """Insert-or-replace by primary key: ONE WAL record per shard
        carries the delete-by-pk and insert halves, so visibility flips
        atomically at ``MutationResult.watermark_ts``."""
        if isinstance(rows, UpsertRequest):
            if partition is not None:
                raise ValueError(
                    "pass partition inside the UpsertRequest, not as a kwarg"
                )
            return self.mutate(rows)
        return self.mutate(
            UpsertRequest(rows, partition=partition or DEFAULT_PARTITION)
        )

    def delete(self, pks) -> "int | MutationResult":
        """Delete by primary key.  A typed :class:`DeleteRequest` returns
        its :class:`MutationResult`; the legacy array form returns the
        bare LSN.  Empty or provably no-match deletes publish nothing and
        hand back an already-covered watermark."""
        if isinstance(pks, DeleteRequest):
            return self.mutate(pks)
        return self.mutate(DeleteRequest(np.asarray(pks))).watermark_ts

    # ----------------------------------------------------- async mutations
    def mutate_async(self, request: MutationRequest) -> MutationTicket:
        """Admit one typed mutation into the ingest scheduler's bounded
        write queue; returns a :class:`MutationTicket` immediately.  The
        WAL crossing happens at the next micro-batch flush (queue depth,
        age, or an explicit ``ticket.result()`` / ``system.flush_ingest()``).
        Raises :class:`AdmissionRejected` under backpressure."""
        return self.system.mutate_async(self, request)

    def insert_async(
        self, rows, partition: str | None = None
    ) -> MutationTicket:
        if isinstance(rows, InsertRequest):
            if partition is not None:
                raise ValueError(
                    "pass partition inside the InsertRequest, not as a kwarg"
                )
            return self.mutate_async(rows)
        return self.mutate_async(
            InsertRequest(rows, partition=partition or DEFAULT_PARTITION)
        )

    def upsert_async(
        self, rows, partition: str | None = None
    ) -> MutationTicket:
        if isinstance(rows, UpsertRequest):
            if partition is not None:
                raise ValueError(
                    "pass partition inside the UpsertRequest, not as a kwarg"
                )
            return self.mutate_async(rows)
        return self.mutate_async(
            UpsertRequest(rows, partition=partition or DEFAULT_PARTITION)
        )

    def delete_async(self, pks) -> MutationTicket:
        if isinstance(pks, DeleteRequest):
            return self.mutate_async(pks)
        return self.mutate_async(DeleteRequest(np.asarray(pks)))

    # ------------------------------------------------------------ partitions
    def create_partition(self, partition: str) -> None:
        """Register a named partition as a placement target for writes and
        a pruning target for ``SearchRequest.partition_names``."""
        self.system.create_partition(self.name, partition)

    def drop_partition(self, partition: str) -> dict:
        """Drop a partition and release its segments everywhere; their
        binlogs are reclaimed by the next GC cycle."""
        return self.system.drop_partition(self.name, partition)

    def partitions(self) -> list[str]:
        return self.system.root_coord.partitions(self.name)

    def create_index(self, field: str, kind: str, params: dict | None = None) -> None:
        fs = self.info.schema.field(field)  # KeyError for unknown fields
        if fs.dtype is not FieldType.VECTOR:
            raise ValueError(
                f"create_index targets vector fields; '{field}' is {fs.dtype.value}"
            )
        self.system.index_coord.set_index_spec(
            self.name, field, kind, params, metric=self.info.metric,
            column=vector_column_of(self.info.schema, field),
        )
        # Handle-local mirror for introspection; the meta store
        # (index_coord.index_specs) stays the authoritative copy.
        self.info.index_specs[field] = {"kind": kind, "params": params or {}}
        # Batch indexing (paper §3.5): issue builds for already-sealed segments.
        for sid in self.system.data_coord.sealed_segments(self.name):
            self.system.index_coord.rebuild_segment(self.name, sid, fields=[field])
        if not self.system.config.threaded:
            self.system.run_until_idle()

    def flush(self) -> None:
        """Seal all growing segments and wait for archive + index builds.
        Queued async writes drain into the WAL first, so a flush covers
        everything admitted before it."""
        self.system.scheduler.flush_writes(collection=self.name)
        self.system.data_coord.flush(self.name)
        if self.system.config.threaded:
            self.system.wait_idle()
        else:
            self.system.run_until_idle()

    def compact(self) -> dict:
        """Run one compaction cycle (purge deletes, merge small segments)."""
        return self.system.compact(self.name)

    def gc(self, horizon_ts: int | None = None) -> dict:
        """Advance the retention horizon and reclaim old binlog/index objects."""
        return self.system.gc(self.name, horizon_ts)

    def search(
        self,
        queries=None,
        limit: int = 10,
        staleness_ms: float | None = None,
        read_your_writes: bool = False,
        filter_expr: str | None = None,
        time_travel_ts: int | None = None,
        hedge_timeout_s: float | None = None,
        consistency: ConsistencyLevel | None = None,
        radius: float | None = None,
        range_filter: float | None = None,
        output_fields=(),
        partition_names=(),
        request: SearchRequest | None = None,
    ) -> SearchResult:
        """Search the collection.

        Accepts either a declarative :class:`SearchRequest` (as ``queries``
        or the ``request`` kwarg) or the legacy kwarg surface, which is a
        thin facade: the kwargs are packed into a single-field
        ``SearchRequest`` and executed by the exact same pipeline.
        """
        if isinstance(queries, SearchRequest):
            request = queries
        if request is not None:
            # A declarative request carries every option itself; reject
            # stray legacy kwargs instead of silently dropping them.
            stray = {
                "limit": limit != 10,
                "staleness_ms": staleness_ms is not None,
                "read_your_writes": read_your_writes,
                "filter_expr": filter_expr is not None,
                "time_travel_ts": time_travel_ts is not None,
                "consistency": consistency is not None,
                "radius": radius is not None,
                "range_filter": range_filter is not None,
                "output_fields": bool(tuple(output_fields)),
                "partition_names": bool(tuple(partition_names)),
            }
            bad = [name for name, is_set in stray.items() if is_set]
            if bad:
                raise ValueError(
                    f"pass {bad} inside the SearchRequest, not as kwargs"
                )
        session_override = None
        if request is None:
            wants_session = (
                read_your_writes or consistency is ConsistencyLevel.SESSION
            )
            request = SearchRequest.single(
                queries,
                field=self.info.schema.vector_fields()[0].name,
                k=limit,
                consistency=consistency,
                staleness_ms=staleness_ms,
                session_ts=self.last_write_ts if wants_session else 0,
                filter=filter_expr,
                radius=radius,
                range_filter=range_filter,
                output_fields=tuple(output_fields),
                partition_names=tuple(partition_names),
                time_travel_ts=time_travel_ts,
            )
        elif (
            request.consistency is ConsistencyLevel.SESSION
            and request.session_ts == 0
        ):
            # SESSION with no explicit watermark reads this handle's last
            # write; passed as an override so the caller's request object is
            # never mutated (it may be reused across later writes).
            session_override = self.last_write_ts
        return self.system.search(
            self, request,
            hedge_timeout_s=hedge_timeout_s, session_ts=session_override,
        )

    def hybrid_search(
        self,
        anns: "list[AnnsQuery]",
        limit: int = 10,
        ranker: Ranker | None = None,
        **kw,
    ) -> SearchResult:
        """Multi-vector search: one AnnsQuery per vector field, fused by
        ``ranker`` (weighted-sum by default)."""
        request = SearchRequest(
            anns=anns, k=limit, ranker=ranker or Ranker.weighted(), **kw
        )
        return self.search(request)

    def query(self, queries: np.ndarray, limit: int, expr: str, **kw) -> SearchResult:
        """PyManu ``query``: vector search with boolean filter expression."""
        return self.search(queries, limit, filter_expr=expr, **kw)

    def describe(self) -> DescribeCollection:
        """Typed description of the collection: schema fields, partitions,
        declared indexes, entity count, sharding and replication — the
        structured replacement for picking through handle attributes."""
        specs = self.system.index_coord.index_specs(self.name)
        return DescribeCollection(
            name=self.name,
            fields=tuple(self.info.schema.fields),
            partitions=tuple(self.partitions()),
            indexes=tuple(
                IndexDescription(
                    field=f,
                    kind=s["kind"],
                    params=dict(s.get("params") or {}),
                    metric=Metric(s["metric"]),
                )
                for f, s in sorted(specs.items())
            ),
            num_entities=self.num_entities(),
            num_shards=self.info.num_shards,
            metric=self.info.metric,
            replication_factor=self.system.query_coord.replication_for(self.name),
        )

    def num_entities(self) -> int:
        """Rows of THIS collection across the cluster, counting each
        segment once even when replicated on several nodes (and preferring
        the sealed copy over a node's lingering growing twin)."""
        sealed_rows: dict[int, int] = {}
        growing_rows: dict[int, int] = {}
        for qn in self.system.query_nodes.values():
            if not qn.alive:
                continue
            for (_c, sid, is_sealed), n in qn.segment_rows(self.name).items():
                (sealed_rows if is_sealed else growing_rows)[sid] = n
        total = sum(sealed_rows.values())
        total += sum(n for sid, n in growing_rows.items() if sid not in sealed_rows)
        return total


class ManuSystem:
    def __init__(
        self,
        config: ManuConfig | None = None,
        store: ObjectStore | None = None,
        injector: FaultInjector | None = None,
    ):
        self.config = config or ManuConfig()
        self.clock: Clock = ManualClock(1_000_000) if self.config.manual_clock else Clock()
        self.tso = TSO(self.clock)

        # One metrics registry and one bounded control-plane event log per
        # system; every component records into the shared registry, the
        # control loops (coordinators, reconciler, GC) emit typed events.
        self.telemetry = MetricsRegistry()
        self.event_log = EventLog(self.clock)

        # Durable substrates, composed as Retrying(Faulty(real)): the fault
        # plane injects at the infrastructure boundary (S3 / etcd / Kafka
        # stand-ins), the typed retry plane absorbs exactly the transients it
        # is specified to absorb.  These three — plus the clock — are the
        # only things that survive ``restart()``; every Manu *process* is
        # rebuilt from them.
        self.injector = injector
        raw_store: ObjectStore = store or MemoryObjectStore()
        raw_meta = MetaStore(self.clock)
        raw_broker = LogBroker()
        if injector is not None:
            injector.bind(
                metrics=self.telemetry, event_log=self.event_log, clock=self.clock
            )
            raw_store = FaultyObjectStore(raw_store, injector)
            raw_meta = FaultyMetaStore(raw_meta, injector)
            raw_broker = FaultyLogBroker(raw_broker, injector)
        policy = self.config.retry_policy or RetryPolicy()
        sleep = default_sleep(self.config.threaded)
        self.store: ObjectStore = RetryingObjectStore(
            raw_store, policy,
            metrics=self.telemetry, event_log=self.event_log, sleep=sleep,
        )
        self.meta = RetryingMetaStore(
            raw_meta, policy,
            metrics=self.telemetry, event_log=self.event_log, sleep=sleep,
        )
        self.broker = RetryingLogBroker(
            raw_broker, policy,
            metrics=self.telemetry, event_log=self.event_log, sleep=sleep,
        )

        self._build_processes()
        self.collections: dict[str, ManuCollection] = {}

        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        if self.config.threaded:
            self.start_threads()

    def _build_processes(self) -> None:
        """Construct every Manu *process* — coordinators, worker nodes, the
        proxy — on top of the durable substrates.  Called at boot and again
        by ``restart()``: processes hold only soft state, reconstructible
        from the meta store, object store and log backbone."""
        self.root_coord = RootCoordinator(self.broker, self.meta, self.tso)
        self.data_coord = DataCoordinator(self.broker, self.meta, self.tso, self.clock)
        self.index_coord = IndexCoordinator(
            self.broker, self.meta, self.tso, events=self.event_log
        )
        self.query_coord = QueryCoordinator(
            self.broker, self.meta, self.tso, self.data_coord,
            replication_factor=self.config.replication_factor,
            heartbeat_ttl_ms=self.config.heartbeat_ttl_ms,
            events=self.event_log,
        )

        self.loggers = [
            Logger(f"logger-{i}", self.broker, self.tso, self.data_coord, self.clock,
                   self.config.tick_interval_ms, metrics=self.telemetry)
            for i in range(self.config.num_loggers)
        ]
        self.data_nodes = [
            DataNode(f"dn-{i}", self.broker, self.store, self.tso, self.data_coord,
                     metrics=self.telemetry)
            for i in range(self.config.num_data_nodes)
        ]
        self.index_nodes = [
            IndexNode(f"in-{i}", self.broker, self.store, self.meta, self.tso,
                      metrics=self.telemetry)
            for i in range(self.config.num_index_nodes)
        ]
        self.compaction_coord = CompactionCoordinator(
            self.broker, self.meta, self.tso, self.data_coord, self.store,
            delete_ratio=self.config.compaction_delete_ratio,
            small_fraction=self.config.compaction_small_fraction,
            retention_ms=self.config.gc_retention_ms,
            events=self.event_log,
        )
        self.compaction_nodes = [
            CompactionNode(f"cn-{i}", self.broker, self.store, self.meta, self.tso,
                           metrics=self.telemetry)
            for i in range(self.config.num_compaction_nodes)
        ]
        self.gc_reaper = GCReaper(self.broker, self.store, self.meta, self.tso,
                                  metrics=self.telemetry, events=self.event_log)
        self.query_nodes: dict[str, QueryNode] = {}
        for i in range(self.config.num_query_nodes):
            self._new_query_node()

        self.proxy = Proxy(
            "proxy-0", self.meta, self.tso, self.loggers, self.query_coord,
            self.query_nodes, metrics=self.telemetry,
        )
        self.proxy.bounded_staleness_ms = self.config.bounded_staleness_ms
        # The serving-tier request scheduler: async micro-batched ingest
        # with backpressure plus the read micro-batching stage the batcher
        # fronts.  Rebuilt by restart() like every process — tickets still
        # queued at a crash are lost with it (clients re-submit), exactly
        # like unacknowledged requests against a dead proxy.
        self.scheduler = RequestScheduler(
            self.proxy,
            clock=self.clock,
            queue_rows=self.config.ingest_queue_rows,
            flush_rows=self.config.ingest_flush_rows,
            flush_interval_ms=self.config.ingest_flush_ms,
            metrics=self.telemetry,
            guarantee_fn=lambda _info, req: self._resolve_guarantee(req),
            on_flush=self._after_ingest_flush,
        )
        self.batcher = BatchingProxy(self.proxy, scheduler=self.scheduler)
        self.time_travel = TimeTravel(self.broker, self.store)
        self._qn_counter = self.config.num_query_nodes

    # ------------------------------------------------------------- topology
    def _new_query_node(self) -> QueryNode:
        node_id = f"qn-{len(self.query_nodes)}"
        # ensure unique ids even after removals
        i = len(self.query_nodes)
        while f"qn-{i}" in self.query_nodes:
            i += 1
        node_id = f"qn-{i}"
        qn = QueryNode(node_id, self.broker, self.store, self.tso,
                       slice_rows=self.config.slice_rows,
                       metrics=self.telemetry)
        self.query_nodes[node_id] = qn
        self.query_coord.register_node(node_id)
        return qn

    def add_query_node(self) -> str:
        """Scale up: register the node, then let the reconciler heal any
        under-replicated segments onto it and rebalance toward even load."""
        qn = self._new_query_node()
        self.query_coord.reconciler.reconcile()
        if not self.config.threaded:
            self.run_until_idle()
        return qn.node_id

    def remove_query_node(self, node_id: str | None = None) -> str | None:
        """Graceful scale-down: mark the node draining, reconcile so its
        replicas are shed to survivors (load-before-release — a segment's
        last copy stays on the draining node until a replacement holds it,
        so pinned MVCC reads never hit a serving gap), then retire it."""
        live = [n for n, q in self.query_nodes.items() if q.alive]
        if len(live) <= 1:
            return None
        node_id = node_id or live[-1]
        self.query_coord.start_drain(node_id)
        self.query_coord.reconciler.reconcile()
        if not self.config.threaded:
            self.run_until_idle()  # survivors load their new replicas
        self.query_coord.deregister_node(node_id)
        self.query_coord.handle_failures()
        node = self.query_nodes.get(node_id)
        if node:
            node.alive = False
        for coll in self.collections.values():
            self.query_coord.assign_channels(coll.name, coll.info.num_shards)
        if not self.config.threaded:
            self.run_until_idle()
        return node_id

    def kill_query_node(self, node_id: str) -> None:
        """Simulated crash: no dereg — the lease must expire (failover test)."""
        self.query_nodes[node_id].alive = False

    # ----------------------------------------------- crash/restart (chaos)
    @staticmethod
    def _locate(nodes: list, node_id: str) -> int:
        for i, n in enumerate(nodes):
            if getattr(n, "node_id", getattr(n, "logger_id", None)) == node_id:
                return i
        raise KeyError(f"no such node: {node_id}")

    def _emit_lifecycle(self, what: str, kind: str, node_id: str) -> None:
        self.telemetry.inc(f"node_{what}_total", labels={"kind": kind})
        self.event_log.emit(f"node_{what}", "system", node_kind=kind, node=node_id)

    def kill_logger(self, logger_id: str) -> None:
        self.loggers[self._locate(self.loggers, logger_id)].alive = False
        self._emit_lifecycle("killed", "logger", logger_id)

    def restart_logger(self, logger_id: str) -> None:
        """Replace a dead logger with a fresh process.  Loggers hold no
        recoverable state: PK allocation watermarks are checkpointed in the
        meta store (``id_alloc/``), so the replacement allocates fresh pks
        and rejects no-match deletes correctly from its first request."""
        i = self._locate(self.loggers, logger_id)
        # replaced in place: the proxy routes over this exact list object
        self.loggers[i] = Logger(
            logger_id, self.broker, self.tso, self.data_coord, self.clock,
            self.config.tick_interval_ms, metrics=self.telemetry,
        )
        self._emit_lifecycle("restarted", "logger", logger_id)

    def kill_data_node(self, node_id: str) -> None:
        self.data_nodes[self._locate(self.data_nodes, node_id)].alive = False
        self._emit_lifecycle("killed", "data", node_id)

    def restart_data_node(self, node_id: str) -> None:
        """Rebuild a data node purely from the log backbone: re-subscribe
        its DML channels from position 0.  Replay skips the insert halves of
        segments already archived to binlog and rebuilds the growing ones
        (delete halves always re-apply).  Afterwards, re-announce any binlog
        whose ``segment_sealed`` message died with the old process."""
        i = self._locate(self.data_nodes, node_id)
        old = self.data_nodes[i]
        dn = DataNode(node_id, self.broker, self.store, self.tso,
                      self.data_coord, metrics=self.telemetry)
        for ch in old.subscriptions:
            dn.subscribe(ch, 0)
        self.data_nodes[i] = dn
        self._emit_lifecycle("restarted", "data", node_id)
        self.reconcile_sealed()
        if not self.config.threaded:
            self.run_until_idle()

    def kill_index_node(self, node_id: str) -> None:
        self.index_nodes[self._locate(self.index_nodes, node_id)].alive = False
        self._emit_lifecycle("killed", "index", node_id)

    def restart_index_node(self, node_id: str) -> None:
        """Fresh index node re-reading the coord channel from 0: finished
        builds are skipped by their surviving CAS claims; claims the dead
        process leaked mid-build (no ``index/`` meta behind them) are
        released so the tasks become takeable again."""
        i = self._locate(self.index_nodes, node_id)
        for key, claim in list(self.meta.scan("index_claim/").items()):
            if (claim or {}).get("owner") != node_id:
                continue
            _, coll, sid, field_name, _kind = key.split("/")
            if self.meta.get(f"index/{coll}/{sid}/{field_name}") is None:
                self.meta.delete(key)
        self.index_nodes[i] = IndexNode(
            node_id, self.broker, self.store, self.meta, self.tso,
            metrics=self.telemetry,
        )
        self._emit_lifecycle("restarted", "index", node_id)
        if not self.config.threaded:
            self.run_until_idle()

    def kill_compaction_node(self, node_id: str) -> None:
        self.compaction_nodes[self._locate(self.compaction_nodes, node_id)].alive = False
        self._emit_lifecycle("killed", "compaction", node_id)

    def restart_compaction_node(self, node_id: str) -> None:
        """Fresh compaction node replaying the coord channel (the durable
        task queue) from 0: done-markers keep finished tasks finished, and
        clearing the dead owner's stale claims lets it re-execute whatever
        the crash interrupted — the rewrite is deterministic and its binlog
        writes are atomic, so re-execution simply overwrites."""
        i = self._locate(self.compaction_nodes, node_id)
        self.compaction_coord.clear_stale_claims(owner=node_id)
        self.compaction_nodes[i] = CompactionNode(
            node_id, self.broker, self.store, self.meta, self.tso,
            metrics=self.telemetry,
        )
        self._emit_lifecycle("restarted", "compaction", node_id)
        if not self.config.threaded:
            self.run_until_idle()

    def restart_query_node(self, node_id: str) -> str:
        """Crash-restart one query node: expire the dead incarnation's
        lease and reassign its replicas to survivors, then register a fresh
        process under the same id and let the reconciler move work back."""
        self.query_nodes.pop(node_id, None)
        st = self.query_coord.nodes.get(node_id)
        if st is not None:
            self.meta.revoke_lease(st.lease_id)
            self.query_coord.handle_failures()
        qn = QueryNode(node_id, self.broker, self.store, self.tso,
                       slice_rows=self.config.slice_rows,
                       metrics=self.telemetry)
        self.query_nodes[node_id] = qn
        self.query_coord.register_node(node_id)
        self.query_coord.reconciler.reconcile()
        self._emit_lifecycle("restarted", "query", node_id)
        if not self.config.threaded:
            self.run_until_idle()
        return node_id

    def recover_failures(self) -> list[str]:
        """Expire dead leases and reconcile (the query coordinator's
        watchdog): failed nodes' segments are CAS-reassigned to surviving
        replicas, channels re-homed, under-replication healed."""
        st = self.query_coord.nodes
        for node_id, qn in self.query_nodes.items():
            if qn.alive and node_id in st:
                self.query_coord.heartbeat(node_id)
        # force lease expiry for dead nodes
        for node_id, qn in self.query_nodes.items():
            if not qn.alive and node_id in st:
                self.meta.revoke_lease(st[node_id].lease_id)
        report = self.query_coord.reconciler.reconcile()
        if not self.config.threaded:
            self.run_until_idle()
        return report["dead"]

    # ------------------------------------------------------ crash recovery
    def reconcile_sealed(self) -> int:
        """Re-announce sealed binlogs the metadata plane never learned
        about: a data node crashing between the binlog flush and its
        ``segment_sealed`` publish leaves a fully durable segment invisible.
        The binlog's meta object is written *last*, so its presence proves
        the flush completed; a segment with binlog meta but no ``segment/``
        record owes the system a seal announcement.  Pre-allocated targets
        of still-pending compaction tasks are excluded — those binlogs are
        half-finished rewrite output that re-execution will overwrite.

        Attribute-index satellites ride the same window: they are written
        *after* the binlog meta, so an orphaned seal may have none (or only
        some) of them.  Reconciliation rebuilds the full satellite set from
        the binlog columns before re-announcing."""
        from .binlog import read_binlog_meta, rebuild_attr_satellites

        pending_targets = {
            (t["collection"], sid)
            for t in self.compaction_coord.pending.values()
            for sid in t.get("targets", ())
        }
        healed = 0
        for m in self.store.list("binlog/"):
            parts = m.key.split("/")
            if len(parts) != 4 or parts[3] != "meta":
                continue
            coll, sid = parts[1], int(parts[2])
            if (coll, sid) in pending_targets:
                continue
            if self.meta.get(f"collection/{coll}") is None:
                continue  # dropped collections stay dropped
            if self.meta.get(f"segment/{coll}/{sid}") is not None:
                continue  # already known (sealed or retired)
            bm = read_binlog_meta(self.store, coll, sid)
            part = bm.get("partition", DEFAULT_PARTITION)
            if self.meta.get(f"partition/{coll}/{part}") is None:
                continue  # dropped partitions stay dropped
            attr_fields = sorted(rebuild_attr_satellites(self.store, coll, sid))
            self.broker.publish(
                COORD_CHANNEL,
                LogEntry(
                    ts=self.tso.next(),
                    type=EntryType.COORD,
                    payload={
                        "msg": "segment_sealed",
                        "collection": coll,
                        "segment_id": sid,
                        "shard": bm.get("shard", 0),
                        "partition": part,
                        "num_rows": bm["num_rows"],
                        "binlog_keys": {},
                        "checkpoint_pos": bm["checkpoint_pos"],
                        "min_ts": bm.get("min_ts", 0),
                        "max_ts": bm.get("max_ts", 0),
                    },
                ),
            )
            self.data_coord.on_sealed(
                coll, sid, bm["num_rows"], part, shard=bm.get("shard", 0),
                attr_fields=attr_fields,
            )
            self.telemetry.inc("recovery_seals_reconciled_total")
            self.event_log.emit(
                "seal_reconciled", "system", collection=coll, segment_id=sid
            )
            healed += 1
        return healed

    def heal_attr_satellites(self) -> int:
        """Rebuild missing/partial attribute-index satellites for segments
        the metadata plane already knows.  A crash cannot leave a *stale*
        satellite (segments are immutable once sealed and satellites are
        rebuilt wholesale), but it can leave an announced segment from
        before the attr subsystem existed, or a partially-written satellite
        set whose meta records never landed.  Returns segments healed."""
        from .binlog import attr_key, rebuild_attr_satellites

        healed = 0
        for key, rec in sorted(self.meta.scan("segment/").items()):
            if rec.get("state") != "sealed":
                continue
            _, coll, sid_s = key.split("/")
            sid = int(sid_s)
            if not self.store.exists(f"binlog/{coll}/{sid}/meta"):
                continue
            recorded = [
                k.rsplit("/", 1)[1]
                for k in self.meta.scan(f"attr_index/{coll}/{sid}/")
            ]
            intact = recorded and all(
                self.store.exists(attr_key(coll, sid, f)) for f in recorded
            )
            if intact:
                continue
            fields = sorted(rebuild_attr_satellites(self.store, coll, sid))
            self.data_coord._record_attr_fields(
                coll, sid, int(rec.get("rows", 0)), fields
            )
            self.telemetry.inc("recovery_attr_satellites_rebuilt_total")
            self.event_log.emit(
                "attr_satellites_healed", "system", collection=coll, segment_id=sid
            )
            healed += 1
        return healed

    def restart(self) -> dict:
        """Cold-restart the whole system: every process — coordinators,
        worker nodes, the proxy — is discarded and rebuilt purely from the
        durable substrates (meta store, object store, log backbone).  This
        is the paper's central recovery claim made executable: everything is
        a log subscriber, so everything recovers by re-reading its durable
        inputs (§3.3).

        What carries over: the clock, the three substrates, the metrics
        registry and event log (so recovery counters remain visible).  What
        is reconstructed: collections (from ``collection/`` meta, schema
        included), segment lifecycle state (meta + WAL scan), index state,
        serving placement (soft state, re-placed via the reconciler's CAS
        path), pending compactions (coord-channel replay + stale-claim
        release), growing rows (WAL replay on data and query nodes), and
        pinned time-travel windows (retired segments re-loaded +
        re-retired).  Session watermarks (``last_write_ts``) do not survive
        — a restart ends client sessions."""
        was_threaded = bool(self._threads)
        if was_threaded:
            self.stop_threads()
        # The dead proxy's meta watches must stop firing into it.
        for cancel in (
            self.proxy._cancel_watch, self.proxy._cancel_partition_watch,
        ):
            try:
                cancel()
            except Exception:
                pass
        # Fresh TSO floored at the durable log frontier: timestamps stay
        # strictly increasing across the restart even under a frozen
        # manual clock.
        self.tso = TSO(self.clock)
        frontier = 0
        for ch in self.broker.channels():
            end = self.broker.end_position(ch)
            if end:
                frontier = max(frontier, self.broker.read(ch, end - 1)[0].ts)
        self.tso.advance_to(frontier)
        # Serving placement is soft state.  Stale assignment records would
        # make the reconciler believe the fresh (same-named) query nodes
        # already hold their segments and skip the loads; drop them and let
        # ``heal()`` re-place everything through the normal CAS path.
        for key in list(self.meta.scan("assignment/")):
            self.meta.delete(key)

        self._build_processes()

        # Collections come back from the meta store alone.
        self.collections = {}
        for key, rec in sorted(self.meta.scan("collection/").items()):
            name = key.split("/", 1)[1]
            info = CollectionInfo(
                name=name,
                schema=Schema.from_dict(rec["schema"]),
                num_shards=int(rec["num_shards"]),
                metric=Metric(rec["metric"]),
                created_ts=int(rec.get("created_ts", 0)),
                replication_factor=int(rec.get("replication_factor", 1)),
            )
            for f, s in self.index_coord.index_specs(name).items():
                info.index_specs[f] = {
                    "kind": s["kind"], "params": dict(s.get("params") or {}),
                }
            self.collections[name] = ManuCollection(self, info)
            # Data nodes re-archive from scratch: replay from position 0,
            # skipping inserts whose segments are already durable in binlog.
            for shard in range(info.num_shards):
                dn = self.data_nodes[shard % len(self.data_nodes)]
                dn.subscribe(dml_channel(name, shard), 0)

        report: dict = {"tso_frontier": frontier}
        report["data"] = self.data_coord.recover_state(store=self.store)
        report["index"] = self.index_coord.recover_state()
        report["query"] = self.query_coord.recover_state()
        # The compaction coordinator's durable queue IS the coord channel:
        # one replaying step rebuilds pending tasks (done-markers keep
        # finished ones finished); clearing stale claims un-wedges whatever
        # a dead node held mid-rewrite.
        self.compaction_coord.step()
        report["claims_cleared"] = self.compaction_coord.clear_stale_claims()
        report["seals_reconciled"] = self.reconcile_sealed()
        report["attr_healed"] = self.heal_attr_satellites()
        self.query_coord.reconciler.reconcile()
        self.run_until_idle()
        # Pinned time-travel windows: retired-but-unreclaimed segments are
        # re-loaded and immediately re-retired so reads pinned before their
        # hot-swap still see the MVCC window [visible_from, retired_at).
        report["retired_reloaded"] = self.query_coord.recover_retired(self.store)
        self.run_until_idle()
        self.telemetry.inc("system_restarts_total")
        self.event_log.emit(
            "system_restarted", "system",
            **{k: v for k, v in report.items() if isinstance(v, (int, float))},
        )
        if was_threaded or self.config.threaded:
            self.start_threads()
        return report

    # ----------------------------------------------------------------- DDL
    def create_collection(
        self,
        name: str,
        dim: int,
        metric: Metric = Metric.L2,
        num_shards: int | None = None,
        extra_fields: list[FieldSchema] | None = None,
        seal_rows: int | None = None,
        schema: Schema | None = None,
        replication_factor: int | None = None,
    ) -> ManuCollection:
        """Create a collection.  The common int-pk + one-vector case is
        built from ``dim``/``extra_fields``; pass an explicit ``schema``
        for anything else (string primary keys, custom layouts).
        ``replication_factor`` overrides ``ManuConfig.replication_factor``
        for this collection's sealed segments."""
        schema = schema or Schema.simple(dim, metric, extra=extra_fields)
        info = self.root_coord.create_collection(
            name,
            schema,
            num_shards=num_shards or self.config.num_shards,
            metric=metric,
            seal_rows=seal_rows or self.config.seal_rows,
            replication_factor=(
                self.config.replication_factor
                if replication_factor is None
                else replication_factor
            ),
        )
        coll = ManuCollection(self, info)
        self.collections[name] = coll
        # Data nodes archive the WAL: shard channels round-robin over them.
        for shard in range(info.num_shards):
            dn = self.data_nodes[shard % len(self.data_nodes)]
            dn.subscribe(dml_channel(name, shard))
        self.query_coord.assign_channels(name, info.num_shards)
        if not self.config.threaded:
            self.pump()
        return coll

    def drop_collection(self, name: str) -> None:
        self.root_coord.drop_collection(name)
        self.collections.pop(name, None)

    # ---------------------------------------------------------- partitions
    def create_partition(self, name: str, partition: str) -> None:
        self.root_coord.create_partition(name, partition)
        if not self.config.threaded:
            self.pump()

    def drop_partition(self, name: str, partition: str) -> dict:
        """Drop a partition: unregister it, retire its sealed segments
        (reclaimed by the next GC cycle), discard its growing rows, and
        broadcast ``partition_dropped`` so serving nodes release their
        copies.  Like ``drop_collection``, the drop is not MVCC-gated:
        time-travel reads into the dropped partition stop working.

        Tombstones whose pks lived ONLY in the dropped partition can never
        be folded by a future compaction (their segments are gone), so the
        drop reuses the compaction machinery: it broadcasts them as
        ``tombstones_folded`` and the query nodes prune their delta-delete
        maps at the next retention-horizon advance — same unbounded-growth
        fix as PR 2's fold pruning."""
        from .binlog import read_binlog_column
        from .compaction import prune_folded

        if not self.config.threaded:
            self.run_until_idle()  # let in-flight seals land first
        else:
            self.wait_idle()
        ts = self.root_coord.drop_partition(name, partition)
        sids = self.data_coord.drop_partition_state(name, partition, ts)

        # pk accounting BEFORE nodes release anything: which pks vanish
        # with the partition, and which survive elsewhere?
        dropped_pks: list[np.ndarray] = [
            read_binlog_column(self.store, name, sid, "pk") for sid in sids
        ]
        surviving_pks: list[np.ndarray] = [
            read_binlog_column(self.store, name, sid, "pk")
            for sid in self.data_coord.sealed_segments(name)
        ]
        for node in list(self.query_nodes.values()) + self.data_nodes:
            for (coll, _sid), item in list(node.growing.items()):
                if coll != name:
                    continue
                seg = getattr(item, "segment", item)  # GrowingState | Segment
                (dropped_pks if seg.partition == partition else surviving_pks).append(
                    seg.pks()
                )

        self.broker.publish(
            COORD_CHANNEL,
            LogEntry(
                ts=self.tso.next(),
                type=EntryType.COORD,
                payload={
                    "msg": "partition_dropped",
                    "collection": name,
                    "partition": partition,
                    "segment_ids": sids,
                    "drop_ts": ts,
                },
            ),
        )
        for dn in self.data_nodes:
            dn.drop_partition(name, partition)

        exclusive = np.empty(0, np.int64)
        if dropped_pks:
            exclusive = np.unique(np.concatenate(dropped_pks))
            if surviving_pks:
                exclusive = np.setdiff1d(
                    exclusive, np.concatenate(surviving_pks), assume_unique=False
                )
        if exclusive.size:
            self.broker.publish(
                COORD_CHANNEL,
                LogEntry(
                    ts=self.tso.next(),
                    type=EntryType.COORD,
                    payload={
                        "msg": "tombstones_folded",
                        "collection": name,
                        "folded_pks": exclusive,
                        "compact_ts": ts,
                    },
                ),
            )
            pruned = prune_folded(
                self.compaction_coord.tombstones.get(name) or {}, exclusive, ts
            )
            if pruned is not None:
                self.compaction_coord.tombstones[name] = pruned
        if not self.config.threaded:
            self.run_until_idle()
        return {"partition": partition, "segments_dropped": len(sids)}

    # ------------------------------------------------------------ mutations
    def mutate(self, coll: ManuCollection, request: MutationRequest) -> MutationResult:
        """Run one typed mutation through the proxy pipeline, remember its
        watermark for SESSION reads on this handle, and (cooperative mode)
        pump the components so subscribers observe the WAL entries.

        Pending async mutations to the same collection are flushed first:
        a sync mutation must not overtake requests admitted earlier —
        ``insert_async(pk)`` followed by a sync ``delete(pk)`` has to
        reach the WAL in admission order or the delete would apply before
        the insert and resurrect the row."""
        if self.scheduler.pending_write_rows(coll.info.name):
            self.scheduler.flush_writes(coll.info.name)
        result = self.proxy.mutate(coll.info, request)
        coll.last_write_ts = result.watermark_ts
        if not self.config.threaded:
            self.pump()
        return result

    def mutate_async(
        self, coll: ManuCollection, request: MutationRequest
    ) -> MutationTicket:
        """Admit one typed mutation into the ingest scheduler and return
        its :class:`MutationTicket` immediately.  The WAL crossing happens
        at the next flush (depth / age / explicit); the ticket resolves
        with the request's own :class:`MutationResult` then, advancing the
        handle's SESSION watermark.  Raises :class:`AdmissionRejected`
        when the target write queue is out of credits (backpressure)."""
        ticket = self.scheduler.submit_mutation(coll.info, request)

        def _note(result: MutationResult, c=coll) -> None:
            c.last_write_ts = max(c.last_write_ts, result.watermark_ts)

        ticket.on_resolve(_note)
        return ticket

    def flush_ingest(self) -> int:
        """Flush every pending ingest queue now; returns requests flushed."""
        return self.scheduler.flush_writes()

    def _after_ingest_flush(self) -> None:
        """Post-flush hook: cooperative runtimes pump so subscribers
        observe the just-published WAL entries (threaded runtimes have the
        pump thread doing this continuously)."""
        if not self.config.threaded:
            self.pump()

    # ---------------------------------------------------------------- pump
    def pump(self, rounds: int = 1) -> bool:
        """One cooperative scheduling round over every component."""
        progress = False
        for _ in range(rounds):
            # Alive nodes heartbeat every round: consistency waits advance
            # the manual clock, which must never expire a *live* lease.
            for node_id, qn in self.query_nodes.items():
                if qn.alive and node_id in self.query_coord.nodes:
                    self.query_coord.heartbeat(node_id)
            for lg in self.loggers:
                if not lg.alive:
                    continue
                try:
                    lg.tick(self.broker.channels("dml/"))
                except Crash as c:
                    self._mark_crashed("logger", lg, c)
            for dn in self.data_nodes:
                progress |= self._crashable_step("data", dn)
            progress |= self.index_coord.step()
            for ix in self.index_nodes:
                progress |= self._crashable_step("index", ix)
            progress |= self.compaction_coord.step()
            for cn in self.compaction_nodes:
                progress |= self._crashable_step("compaction", cn)
            progress |= self.query_coord.step()
            for qn in self.query_nodes.values():
                progress |= self._crashable_step("query", qn)
            # Ingest scheduler age trigger: admitted-but-unflushed writes
            # never outlive ``ingest_flush_ms`` of pump activity.
            progress |= self.scheduler.step()
        return progress

    def _crashable_step(self, kind: str, node) -> bool:
        """Step one worker node, converting an injected ``Crash`` into that
        node's death.  Like a real kill -9 the exception runs no cleanup in
        the node (``Crash`` is a BaseException); whatever it leaked — claims,
        half-applied batches — is the recovery path's problem.  Coordinator
        steps are deliberately NOT guarded: a coordinator crash takes the
        control plane down and the remedy is a full ``restart()``."""
        try:
            return bool(node.step())
        except Crash as c:
            self._mark_crashed(kind, node, c)
            return True

    def _mark_crashed(self, kind: str, node, crash: Crash) -> None:
        node.alive = False
        node_id = getattr(node, "node_id", getattr(node, "logger_id", "?"))
        self.telemetry.inc("node_crashes_total", labels={"kind": kind})
        self.event_log.emit(
            "node_crashed", "system", node_kind=kind, node=node_id,
            site=crash.site, key=crash.key,
        )

    def run_until_idle(self, max_rounds: int = 10_000) -> int:
        rounds = 0
        while self.pump() and rounds < max_rounds:
            rounds += 1
        if rounds:
            self.event_log.emit(
                "run_until_idle", "system",
                rounds=rounds, truncated=rounds >= max_rounds,
            )
        return rounds

    def wait_idle(self, timeout_s: float = 30.0) -> None:
        deadline = time.time() + timeout_s
        polls = 0
        while time.time() < deadline:
            polls += 1
            stats = self.broker.stats()
            lag = 0
            for qn in self.query_nodes.values():
                if not qn.alive:
                    continue
                for sub in qn.subscriptions.values():
                    lag += sub.lag()
            if (
                lag == 0
                and self.compaction_coord.lag() == 0
                and not self.index_coord.pending_tasks
                and not self.compaction_coord.pending
            ):
                self.event_log.emit(
                    "wait_idle", "system", polls=polls, drained=True,
                )
                return
            time.sleep(0.005)
        self.event_log.emit(
            "wait_idle", "system", polls=polls, drained=False,
        )
        raise TimeoutError(
            self._diagnostic_dump(f"wait_idle timed out after {timeout_s}s")
        )

    def _diagnostic_dump(self, reason: str) -> str:
        """One-stop timeout diagnosis: which channels still hold entries,
        which subscribers lag, what work is pending, and the last few
        control-plane events — so a hung wait points at its culprit instead
        of just saying 'timed out'."""
        lines = [reason]
        stats = self.broker.stats()
        lines.append(
            "channel entries: "
            + str({ch: s["entries"] for ch, s in sorted(stats.items())})
        )
        for node_id, qn in sorted(self.query_nodes.items()):
            lags = {
                ch: sub.lag()
                for ch, sub in qn.subscriptions.items()
                if sub.lag()
            }
            if lags or not qn.alive:
                state = "alive" if qn.alive else "dead"
                lines.append(f"query node {node_id} [{state}] lag: {lags}")
        lines.append(
            f"pending: index_tasks={len(self.index_coord.pending_tasks)}"
            f" compactions={len(self.compaction_coord.pending)}"
            f" compaction_lag={self.compaction_coord.lag()}"
        )
        for ev in self.event_log.query()[-10:]:
            lines.append(f"event {ev.kind} src={ev.source} {ev.detail}")
        return "\n  ".join(lines)

    # --------------------------------------------------- compaction & GC
    def compact(self, name: str) -> dict:
        """One maintenance cycle: plan rewrites, execute, hot-swap.

        Returns {"tasks", "epoch", "rows_purged"} for THIS cycle; a no-op
        when the policy finds nothing to do.
        """
        # The coordinator must see all seals/deletes before planning.
        if self.config.threaded:
            self.wait_idle()
        else:
            self.run_until_idle()
        purged_before = sum(cn.rows_purged for cn in self.compaction_nodes)
        tasks = self.compaction_coord.plan(name)
        if self.config.threaded:
            self.wait_idle()
        else:
            self.run_until_idle()
        return {
            "tasks": len(tasks),
            "epoch": self.compaction_coord.segment_map.epoch(name),
            "rows_purged": sum(cn.rows_purged for cn in self.compaction_nodes)
            - purged_before,
        }

    def gc(self, name: str | None = None, horizon_ts: int | None = None) -> dict:
        """Advance the retention horizon and reclaim unreferenced objects
        of collection ``name`` (None = every collection).

        The horizon defaults to "now minus ``gc_retention_ms``"; segments
        referenced by time-travel checkpoints survive regardless.
        """
        from .timestamp import pack

        if horizon_ts is None:
            if self.config.gc_retention_ms > 0:
                horizon_ts = pack(
                    max(0, int(self.clock.now_ms() - self.config.gc_retention_ms)), 0
                )
            else:
                horizon_ts = self.tso.next()
        self.compaction_coord.advance_horizon(horizon_ts, collection=name)
        if not self.config.threaded:
            self.run_until_idle()
        report = self.gc_reaper.reap(horizon_ts, collection=name)
        if not self.config.threaded:
            self.run_until_idle()
        return report

    # -------------------------------------------------------------- search
    def search(
        self,
        coll: ManuCollection,
        queries,
        k: int | None = None,
        staleness_ms: float | None = None,
        session_ts: int | None = None,
        filter_expr: str | None = None,
        time_travel_ts: int | None = None,
        hedge_timeout_s: float | None = None,
    ) -> SearchResult:
        """Resolve a :class:`SearchRequest`'s consistency requirement into
        a pinned :class:`GuaranteeTs` and hand it to the proxy.  The legacy
        positional form is packed into a request first.  ``session_ts``
        overrides the request's watermark without mutating the request
        (SESSION reads resolved by the collection handle)."""
        if isinstance(queries, SearchRequest):
            request = queries
        else:
            request = SearchRequest.single(
                queries,
                field=coll.info.schema.vector_fields()[0].name,
                k=k if k is not None else 10,
                staleness_ms=staleness_ms,
                session_ts=session_ts or 0,
                filter=filter_expr,
                time_travel_ts=time_travel_ts,
            )
        guarantee = self._resolve_guarantee(request, session_ts=session_ts)
        wait_fn = self._threaded_wait if self.config.threaded else self._cooperative_wait
        return self.proxy.search(
            coll.info, request, guarantee=guarantee,
            wait_fn=wait_fn, hedge_timeout_s=hedge_timeout_s,
        )

    def _resolve_guarantee(
        self, request: SearchRequest, session_ts: int | None = None
    ) -> GuaranteeTs:
        """Resolve a request's consistency fields against this system's
        configuration: explicit tau > named level (BOUNDED uses
        ``bounded_staleness_ms``) > ``default_staleness_ms``.  Also the
        ingest scheduler's guarantee resolver for queued reads."""
        effective_session = (
            request.session_ts if session_ts is None else session_ts
        )
        tau = request.resolve_staleness_ms(
            self.config.default_staleness_ms,
            bounded_ms=self.config.bounded_staleness_ms,
        )
        if request.time_travel_ts is not None:
            # Historical reads never wait: the data is by definition old.
            return GuaranteeTs(
                query_ts=request.time_travel_ts, staleness_ms=INFINITE_STALENESS
            )
        return GuaranteeTs(
            query_ts=self.tso.next(), staleness_ms=tau,
            session_ts=effective_session,
        )

    def _cooperative_wait(
        self, node: QueryNode, guarantee: GuaranteeTs, channels=None
    ) -> None:
        """Pump until the node's consumed watermark covers the guarantee.

        ``channels`` scopes the wait (watermark-aware routing passes
        exactly the channels whose picked server still lags); None keeps
        the legacy behavior of waiting on every DML channel the node
        serves."""
        if channels is None:
            channels = [ch for ch in node.subscriptions if ch.startswith("dml/")]
        else:
            channels = list(channels)
        if not channels:
            return
        target = guarantee.wait_target_ts()
        seen_sub = False
        for _ in range(100_000):
            # Re-read each round: a reconcile during the pump may re-home a
            # channel off this node (its new owner runs its own wait).
            subs = [
                node.subscriptions[ch]
                for ch in channels
                if ch in node.subscriptions
            ]
            if subs:
                seen_sub = True
                wm = min(s.last_tick_seen for s in subs)
                if wm >= target or guarantee.satisfied_by(wm):
                    return
            elif seen_sub:
                # The channel moved off this node mid-wait; its new owner
                # runs its own wait.
                return
            else:
                # Never saw a subscription: the subscribe may still be in
                # flight — but only while the coordinator still assigns a
                # scoped channel here.  If ownership moved (or the node was
                # dropped) between plan and wait, no subscribe will ever
                # land: return instead of pumping to the round limit; the
                # new owner runs its own wait.
                st = self.query_coord.nodes.get(node.node_id)
                followers = getattr(self.query_coord, "channel_followers", {})
                if not any(
                    (st is not None and ch in st.channels)
                    or node.node_id in followers.get(ch, ())
                    for ch in channels
                ):
                    return
            # No subscription yet: a scoped wait may start before the node
            # applied its subscribe message — pump until it lands.
            if isinstance(self.clock, ManualClock):
                self.clock.advance(max(self.config.tick_interval_ms, 1))
            for lg in self.loggers:
                if lg.alive:  # a killed logger emits no ticks
                    lg.tick(channels)
            self.pump()
        raise TimeoutError(
            self._diagnostic_dump("consistency wait did not converge")
        )

    def _threaded_wait(
        self, node: QueryNode, guarantee: GuaranteeTs, channels=None
    ) -> None:
        if channels is None:
            channels = [ch for ch in node.subscriptions if ch.startswith("dml/")]
        else:
            channels = list(channels)
        target = guarantee.wait_target_ts()
        for ch in channels:
            self.broker.wait_for_tick(ch, target, timeout_s=10.0)
        # ensure node consumed the ticks
        deadline = time.time() + 10.0
        while time.time() < deadline:
            if all(node.subscriptions[ch].last_tick_seen >= target for ch in channels
                   if ch in node.subscriptions):
                return
            time.sleep(0.001)

    # -------------------------------------------------------- time travel
    def checkpoint_collection(self, name: str) -> None:
        coll = self.collections[name]
        ts = self.tso.last_issued()
        replay = {}
        for shard in range(coll.info.num_shards):
            ch = dml_channel(name, shard)
            replay[ch] = self.data_coord.replay_position(name, shard)
        self.time_travel.checkpoint(
            name, ts, self.data_coord.sealed_segments(name),
            coll.info.num_shards, replay,
        )

    def restore_collection(self, name: str, target_ts: int) -> RestoredCollection:
        coll = self.collections[name]
        return self.time_travel.restore(
            name, target_ts, coll.info.num_shards, coll.info.dim()
        )

    # ------------------------------------------------------------- threads
    def start_threads(self) -> None:
        self._stop.clear()
        # The pump thread owns node stepping; the proxy's failover waits
        # must sleep instead of stepping nodes themselves.
        self.proxy.pump_fn = lambda: time.sleep(self.config.pump_sleep_s)

        def pump_loop():
            while not self._stop.is_set():
                self.pump()
                time.sleep(self.config.pump_sleep_s)

        def watchdog_loop():
            last_reconcile = 0.0
            while not self._stop.is_set():
                for node_id, qn in list(self.query_nodes.items()):
                    if qn.alive and node_id in self.query_coord.nodes:
                        self.query_coord.heartbeat(node_id)
                now = time.time()
                if now - last_reconcile >= self.config.reconcile_interval_s:
                    last_reconcile = now
                    self.query_coord.reconciler.reconcile()
                time.sleep(0.05)

        for fn in (pump_loop, watchdog_loop):
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            self._threads.append(t)

    def stop_threads(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=2.0)
        self._threads.clear()
        self.proxy.pump_fn = None

    # ------------------------------------------------------------ metrics
    def metrics(self) -> MetricsSnapshot:
        """Typed, JSON-serializable snapshot of the shared metrics registry:
        every counter and gauge series, plus a :class:`HistogramRow` per
        latency histogram with p50/p95/p99 estimated from the log buckets."""
        counters, gauges, hists = self.telemetry.snapshot_rows()
        return MetricsSnapshot(
            ts_ms=self.clock.now_ms(),
            counters=counters,
            gauges=gauges,
            histograms=tuple(
                HistogramRow(name=k, count=total, mean=mean,
                             p50=p50, p95=p95, p99=p99)
                for (k, total, mean, p50, p95, p99) in hists
            ),
        )

    def events(self, since_ts: float | None = None,
               kind: str | None = None) -> list[Event]:
        """Control-plane event log: typed events from the coordinators,
        reconciler, compaction, and GC.  ``since_ts`` filters on the
        emission timestamp (ms, inclusive); ``kind`` on the event kind."""
        return self.event_log.query(since_ts=since_ts, kind=kind)

    def export_metrics(self) -> str:
        """Prometheus text-format exposition of the metrics registry."""
        return self.telemetry.export()

    def cluster_state(self) -> ClusterState:
        """Typed frozen snapshot of the serving tier: node health (as the
        ``HealthMonitor`` observes it), per-node load, the committed
        segment -> replica-group placement, and how many sealed segments
        are currently below their collection's replication factor."""
        coord = self.query_coord
        statuses = coord.health.observe()
        nodes = tuple(
            NodeStatus(
                node_id=n,
                status=statuses.get(n, "dead"),
                load=len(st.segments),
                segments=tuple(sorted(st.segments)),
                channels=tuple(sorted(st.channels)),
                searches=(
                    self.query_nodes[n].search_count
                    if n in self.query_nodes
                    else 0
                ),
                searches_primary=(
                    self.query_nodes[n].searches_primary
                    if n in self.query_nodes
                    else 0
                ),
                searches_hedged=(
                    self.query_nodes[n].searches_hedged
                    if n in self.query_nodes
                    else 0
                ),
            )
            for n, st in sorted(coord.nodes.items())
        )
        placements = []
        under = 0
        for (coll, sid), reps in sorted(coord.replica_sets.items()):
            rec = self.meta.get(f"assignment/{coll}/{sid}") or {}
            ur = bool(
                rec.get(
                    "under_replicated",
                    len(reps) < coord.replication_for(coll),
                )
            )
            under += int(ur)
            placements.append(
                SegmentPlacement(
                    collection=coll,
                    segment_id=sid,
                    replicas=tuple(reps),
                    under_replicated=ur,
                    visible_from_ts=int(rec.get("visible_from_ts", 0)),
                )
            )
        # Sealed segments with no committed placement at all (total outage)
        # count as under-replicated too: the reconciler owes them replicas.
        placed = set(coord.replica_sets)
        for key in self.meta.scan("collection/"):
            coll = key.split("/", 1)[1]
            for sid in self.data_coord.sealed_segments(coll):
                if (coll, sid) not in placed:
                    under += 1
                    placements.append(
                        SegmentPlacement(coll, sid, (), True, 0)
                    )
        return ClusterState(
            nodes=nodes,
            placement=tuple(placements),
            under_replicated=under,
            replication_factor=coord.replication_factor,
        )

    def stats(self) -> dict:
        """Legacy ad-hoc counters — a thin facade now; ``cluster_state()``
        is the typed view of the serving tier."""
        cs = self.cluster_state()
        status_of = {ns.node_id: ns.status for ns in cs.nodes}
        return {
            "log": self.broker.stats(),
            "object_store_puts": getattr(self.store, "put_count", -1),
            "query_nodes": {
                n: {
                    "rows": q.memory_rows(),
                    "alive": q.alive,
                    "searches": q.search_count,
                    "status": status_of.get(n, "dead"),
                }
                for n, q in self.query_nodes.items()
            },
            "cluster": {
                "under_replicated": cs.under_replicated,
                "replication_factor": cs.replication_factor,
            },
            "index_builds": sum(ix.builds_completed for ix in self.index_nodes),
            "compactions": sum(
                cn.compactions_completed for cn in self.compaction_nodes
            ),
            "rows_purged": sum(cn.rows_purged for cn in self.compaction_nodes),
            "gc_bytes_reclaimed": self.gc_reaper.bytes_reclaimed,
            "metrics": self.metrics().to_dict(),
            "events": len(self.event_log),
        }
