"""etcd-like metadata store.

The coordinators keep system status and collection metadata in a
highly-available transactional KV (etcd in the paper).  We reproduce the
etcd feature subset Manu relies on:

* versioned get/put/delete with a global revision counter,
* compare-and-swap (the primitive behind etcd transactions),
* prefix scans,
* watches (callbacks on key/prefix changes) — used to synchronize
  coordinator caches,
* leases with TTL — used for worker liveness (a node that stops renewing
  its lease is declared dead and its work reassigned).

Values are JSON-serializable dicts; we store deep copies to avoid aliasing.
"""

from __future__ import annotations

import copy
import threading
from dataclasses import dataclass, field
from typing import Any, Callable

from .timestamp import Clock


@dataclass
class KV:
    value: Any
    create_rev: int
    mod_rev: int


@dataclass
class Lease:
    lease_id: int
    ttl_ms: int
    expires_at_ms: int
    keys: set[str] = field(default_factory=set)


WatchFn = Callable[[str, Any | None], None]  # (key, new_value|None-on-delete)


class MetaStore:
    def __init__(self, clock: Clock | None = None):
        self._kv: dict[str, KV] = {}
        self._rev = 0
        self._lock = threading.RLock()
        self._watches: list[tuple[str, WatchFn]] = []
        self._leases: dict[int, Lease] = {}
        self._next_lease = 1
        self._clock = clock or Clock()

    # ------------------------------------------------------------------ kv
    def put(self, key: str, value: Any, lease_id: int | None = None) -> int:
        with self._lock:
            self._rev += 1
            prev = self._kv.get(key)
            self._kv[key] = KV(
                value=copy.deepcopy(value),
                create_rev=prev.create_rev if prev else self._rev,
                mod_rev=self._rev,
            )
            if lease_id is not None:
                lease = self._leases.get(lease_id)
                if lease is None:
                    raise KeyError(f"unknown lease {lease_id}")
                lease.keys.add(key)
            rev = self._rev
        self._notify(key, value)
        return rev

    def get(self, key: str, default: Any = None) -> Any:
        with self._lock:
            self._expire_leases()
            kv = self._kv.get(key)
            return copy.deepcopy(kv.value) if kv else default

    def get_rev(self, key: str) -> int | None:
        with self._lock:
            kv = self._kv.get(key)
            return kv.mod_rev if kv else None

    def delete(self, key: str) -> bool:
        with self._lock:
            existed = key in self._kv
            if existed:
                self._rev += 1
                del self._kv[key]
        if existed:
            self._notify(key, None)
        return existed

    def cas(self, key: str, expected_rev: int | None, value: Any) -> bool:
        """Compare-and-swap on mod revision (None = key must not exist)."""
        with self._lock:
            kv = self._kv.get(key)
            current = kv.mod_rev if kv else None
            if current != expected_rev:
                return False
            self._rev += 1
            self._kv[key] = KV(
                value=copy.deepcopy(value),
                create_rev=kv.create_rev if kv else self._rev,
                mod_rev=self._rev,
            )
        self._notify(key, value)
        return True

    def scan(self, prefix: str) -> dict[str, Any]:
        with self._lock:
            self._expire_leases()
            return {
                k: copy.deepcopy(v.value)
                for k, v in sorted(self._kv.items())
                if k.startswith(prefix)
            }

    def revision(self) -> int:
        with self._lock:
            return self._rev

    # -------------------------------------------------------------- watches
    def watch(self, prefix: str, fn: WatchFn) -> Callable[[], None]:
        entry = (prefix, fn)
        with self._lock:
            self._watches.append(entry)

        def cancel() -> None:
            with self._lock:
                try:
                    self._watches.remove(entry)
                except ValueError:
                    pass

        return cancel

    def _notify(self, key: str, value: Any | None) -> None:
        with self._lock:
            targets = [fn for prefix, fn in self._watches if key.startswith(prefix)]
        for fn in targets:
            fn(key, copy.deepcopy(value))

    # --------------------------------------------------------------- leases
    def grant_lease(self, ttl_ms: int) -> int:
        with self._lock:
            lease_id = self._next_lease
            self._next_lease += 1
            self._leases[lease_id] = Lease(
                lease_id=lease_id,
                ttl_ms=ttl_ms,
                expires_at_ms=self._clock.now_ms() + ttl_ms,
            )
            return lease_id

    def keepalive(self, lease_id: int) -> bool:
        with self._lock:
            lease = self._leases.get(lease_id)
            if lease is None:
                return False
            lease.expires_at_ms = self._clock.now_ms() + lease.ttl_ms
            return True

    def revoke_lease(self, lease_id: int) -> None:
        with self._lock:
            lease = self._leases.pop(lease_id, None)
            keys = list(lease.keys) if lease else []
        for key in keys:
            self.delete(key)

    def _expire_leases(self) -> None:
        # Caller holds the lock.
        now = self._clock.now_ms()
        expired = [l for l in self._leases.values() if l.expires_at_ms <= now]
        for lease in expired:
            del self._leases[lease.lease_id]
        # Delete outside lock is unsafe here; inline minimal delete + defer notify.
        doomed: list[str] = []
        for lease in expired:
            for key in lease.keys:
                if key in self._kv:
                    self._rev += 1
                    del self._kv[key]
                    doomed.append(key)
        if doomed:
            # Fire watches after mutation; best-effort ordering.
            threading.Thread(
                target=lambda: [self._notify(k, None) for k in doomed], daemon=True
            ).start()

    def segment_map(self) -> "SegmentMap":
        return SegmentMap(self)

    def expire_now(self) -> list[str]:
        """Force lease expiry sweep (deterministic variant for tests)."""
        with self._lock:
            now = self._clock.now_ms()
            expired = [l for l in self._leases.values() if l.expires_at_ms <= now]
            doomed: list[str] = []
            for lease in expired:
                del self._leases[lease.lease_id]
                for key in lease.keys:
                    if key in self._kv:
                        self._rev += 1
                        del self._kv[key]
                        doomed.append(key)
        for k in doomed:
            self._notify(k, None)
        return doomed


# ---------------------------------------------------------------------------
# Versioned segment mapping
# ---------------------------------------------------------------------------

SEGMENT_MAP_HISTORY = 16


class SegmentMap:
    """Versioned segment-mapping epochs, stored under ``segment_map/<coll>``.

    The value is the authoritative answer to "which sealed segments make up
    this collection right now":

        {"epoch": E, "live": [segment ids], "updated_ts": HLC,
         "history": [{"epoch", "ts", "added", "removed"}, ...]}

    Every seal and every compaction swap bumps the epoch through a CAS, so
    concurrent coordinators serialize on the revision and an epoch number
    uniquely identifies one mapping.  Query nodes are *driven* by coord-
    channel messages (load/retire), but recovery and audit read this map:
    a query pinned at ``ts`` corresponds to the newest epoch with
    ``updated_ts <= ts``.
    """

    def __init__(self, meta: MetaStore):
        self.meta = meta

    def key(self, collection: str) -> str:
        return f"segment_map/{collection}"

    def get(self, collection: str) -> dict:
        return self.meta.get(self.key(collection)) or {
            "epoch": 0,
            "live": [],
            "updated_ts": 0,
            "history": [],
        }

    def epoch(self, collection: str) -> int:
        return int(self.get(collection)["epoch"])

    def live(self, collection: str) -> list[int]:
        return list(self.get(collection)["live"])

    def apply(self, collection: str, add=(), remove=(), ts: int = 0) -> dict:
        """CAS-bump the epoch, adding/removing segment ids atomically."""
        key = self.key(collection)
        while True:
            rev = self.meta.get_rev(key)
            cur = self.get(collection)
            live = (set(cur["live"]) - set(remove)) | set(add)
            entry = {
                "epoch": cur["epoch"] + 1,
                "ts": ts,
                "added": sorted(add),
                "removed": sorted(remove),
            }
            new = {
                "epoch": cur["epoch"] + 1,
                "live": sorted(live),
                "updated_ts": ts,
                "history": (cur.get("history") or [])[-(SEGMENT_MAP_HISTORY - 1):]
                + [entry],
            }
            if self.meta.cas(key, rev, new):
                return new
