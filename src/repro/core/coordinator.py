"""The coordinator layer (paper §3.2): root, data, query, index.

Coordinators keep all authoritative state in the meta store (etcd role) and
communicate with workers exclusively through the coordination log channel —
"the log system provides a simple and reliable mechanism for broadcasting
system events" (§3.3).  Each coordinator is a deterministic state machine
with ``step()``; multiple instances could run main+backup off the meta
store, which we model with a single instance plus full state recovery from
the meta store (see ``QueryCoordinator.recover_state``).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable

from .collection import CollectionInfo, Metric, Schema
from .log import (
    COORD_CHANNEL,
    DDL_CHANNEL,
    EntryType,
    LogBroker,
    LogEntry,
    Subscription,
    dml_channel,
)
from .meta_store import MetaStore, SegmentMap
from .segment import DEFAULT_PARTITION
from .telemetry import EventLog
from .timestamp import TSO, Clock

DEFAULT_SEAL_ROWS = 8_192


class IdAllocator:
    """Typed auto-ID allocator (paper §3.2: the root coordinator assigns
    entity IDs).  Hands out dense per-collection int64 ranges and tracks a
    high watermark across *explicit* user keys too, so the write path can
    cheaply reject deletes of never-allocated pks (the no-match no-op).

    The watermark is checkpointed to the meta store (``id_alloc/{coll}``)
    so a restarted system never re-issues an id and no-match rejection
    stays sound across crashes."""

    def __init__(self, meta: "MetaStore | None" = None) -> None:
        self._next: dict[str, int] = {}
        self.meta = meta

    def _persist(self, collection: str) -> None:
        if self.meta is not None:
            self.meta.put(
                f"id_alloc/{collection}", {"next": self._next[collection]}
            )

    def allocate(self, collection: str, n: int) -> "np.ndarray":
        import numpy as np

        start = self._next.get(collection, 0)
        self._next[collection] = start + n
        self._persist(collection)
        return np.arange(start, start + n, dtype=np.int64)

    def note_explicit(self, collection: str, pks) -> None:
        """Bump the watermark past user-supplied integer keys."""
        import numpy as np

        pks = np.asarray(pks)
        if pks.size and pks.dtype.kind in "iu":
            cur = self._next.get(collection, 0)
            new = max(cur, int(pks.max()) + 1)
            if new != cur:
                self._next[collection] = new
                self._persist(collection)

    def high(self, collection: str) -> int:
        """Exclusive upper bound of every pk ever seen for the collection."""
        return self._next.get(collection, 0)

    def forget(self, collection: str) -> None:
        self._next.pop(collection, None)
        if self.meta is not None:
            self.meta.delete(f"id_alloc/{collection}")

    def recover(self) -> None:
        """Reload watermarks from the meta-store checkpoints."""
        if self.meta is None:
            return
        for key, rec in self.meta.scan("id_alloc/").items():
            coll = key.split("/", 1)[1]
            self._next[coll] = max(self._next.get(coll, 0), int(rec.get("next", 0)))


# ---------------------------------------------------------------------------
# Root coordinator: DDL
# ---------------------------------------------------------------------------


class RootCoordinator:
    def __init__(self, broker: LogBroker, meta: MetaStore, tso: TSO):
        self.broker = broker
        self.meta = meta
        self.tso = tso
        self.broker.create_channel(DDL_CHANNEL)
        self.broker.create_channel(COORD_CHANNEL)

    def create_collection(
        self,
        name: str,
        schema: Schema,
        num_shards: int = 2,
        metric: Metric = Metric.L2,
        seal_rows: int = DEFAULT_SEAL_ROWS,
        replication_factor: int = 1,
    ) -> CollectionInfo:
        if self.meta.get(f"collection/{name}") is not None:
            raise ValueError(f"collection '{name}' already exists")
        if not isinstance(replication_factor, int) or replication_factor < 1:
            raise ValueError(
                f"replication_factor must be an int >= 1, got {replication_factor!r}"
            )
        ts = self.tso.next()
        info = CollectionInfo(
            name=name, schema=schema, num_shards=num_shards, metric=metric,
            created_ts=ts, replication_factor=replication_factor,
        )
        for shard in range(num_shards):
            self.broker.create_channel(dml_channel(name, shard))
        self.meta.put(
            f"collection/{name}",
            {
                "name": name,
                "num_shards": num_shards,
                "metric": metric.value,
                "created_ts": ts,
                "seal_rows": seal_rows,
                "dim": info.schema.vector_fields()[0].dim,
                "replication_factor": replication_factor,
                # full schema so a restarted system can reconstruct the
                # CollectionInfo without any in-memory survivor
                "schema": schema.to_dict(),
            },
        )
        # Every collection starts with the implicit default partition.
        self.meta.put(
            f"partition/{name}/{DEFAULT_PARTITION}",
            {"name": DEFAULT_PARTITION, "created_ts": ts},
        )
        self.broker.publish(
            DDL_CHANNEL,
            LogEntry(ts=ts, type=EntryType.DDL,
                     payload={"msg": "create_collection", "name": name}),
        )
        return info

    def drop_collection(self, name: str) -> None:
        ts = self.tso.next()
        self.meta.delete(f"collection/{name}")
        for key in self.meta.scan(f"partition/{name}/"):
            self.meta.delete(key)
        self.broker.publish(
            DDL_CHANNEL,
            LogEntry(ts=ts, type=EntryType.DDL,
                     payload={"msg": "drop_collection", "name": name}),
        )

    # ------------------------------------------------------------ partitions
    def create_partition(self, collection: str, partition: str) -> None:
        """Register a named partition (paper §3.1: collection → shard →
        partition → segment).  The meta store is the authoritative list;
        proxies watch the prefix to verify placement early."""
        if self.meta.get(f"collection/{collection}") is None:
            raise KeyError(f"collection '{collection}' does not exist")
        if not partition or "/" in partition:
            raise ValueError(f"invalid partition name '{partition}'")
        key = f"partition/{collection}/{partition}"
        if self.meta.get(key) is not None:
            raise ValueError(
                f"partition '{partition}' already exists in '{collection}'"
            )
        ts = self.tso.next()
        self.meta.put(key, {"name": partition, "created_ts": ts})
        self.broker.publish(
            DDL_CHANNEL,
            LogEntry(ts=ts, type=EntryType.DDL,
                     payload={"msg": "create_partition", "name": collection,
                              "partition": partition}),
        )

    def drop_partition(self, collection: str, partition: str) -> int:
        """Unregister a partition; returns the drop timestamp.  The system
        facade broadcasts the matching ``partition_dropped`` coordination
        message so serving nodes release the partition's segments."""
        if partition == DEFAULT_PARTITION:
            raise ValueError("the default partition cannot be dropped")
        key = f"partition/{collection}/{partition}"
        if self.meta.get(key) is None:
            raise KeyError(f"no partition '{partition}' in '{collection}'")
        ts = self.tso.next()
        self.meta.delete(key)
        self.broker.publish(
            DDL_CHANNEL,
            LogEntry(ts=ts, type=EntryType.DDL,
                     payload={"msg": "drop_partition", "name": collection,
                              "partition": partition}),
        )
        return ts

    def partitions(self, collection: str) -> list[str]:
        return sorted(
            key.rsplit("/", 1)[1]
            for key in self.meta.scan(f"partition/{collection}/")
        )


# ---------------------------------------------------------------------------
# Data coordinator: segment allocation, sealing policy, compaction triggers
# ---------------------------------------------------------------------------


@dataclass
class SegmentAlloc:
    segment_id: int
    rows: int = 0
    last_alloc_ms: float = 0.0


class DataCoordinator:
    def __init__(self, broker: LogBroker, meta: MetaStore, tso: TSO, clock: Clock):
        self.broker = broker
        self.meta = meta
        self.tso = tso
        self.clock = clock
        self._next_segment = 1
        self.id_alloc = IdAllocator(meta)
        # (collection, shard, partition) -> current growing allocation;
        # partitions are a placement surface, so each gets its own growing
        # segment per shard and sealed segments never mix partitions.
        self._growing: dict[tuple[str, int, str], SegmentAlloc] = {}
        self._to_seal: set[tuple[str, int]] = set()  # (collection, segment_id)
        self._sealed_rows: dict[tuple[str, int], int] = {}
        self._sealed_upto_pos: dict[tuple[str, int], int] = {}  # per channel shard
        self.segment_map = SegmentMap(meta)

    # ------------------------------------------------------------ allocation
    def allocate_pks(self, collection: str, n: int):
        return self.id_alloc.allocate(collection, n)

    def _alloc_sid(self) -> int:
        """Allocate a segment id; the sequence is checkpointed to the meta
        store so a restarted coordinator never reuses one."""
        sid = self._next_segment
        self._next_segment += 1
        self.meta.put("segment_seq", {"next": self._next_segment})
        return sid

    def seal_rows_for(self, collection: str) -> int:
        info = self.meta.get(f"collection/{collection}") or {}
        return int(info.get("seal_rows", DEFAULT_SEAL_ROWS))

    def assign_segment(
        self,
        collection: str,
        shard: int,
        n_rows: int,
        partition: str = DEFAULT_PARTITION,
    ) -> int:
        key = (collection, shard, partition)
        alloc = self._growing.get(key)
        if alloc is None:
            alloc = SegmentAlloc(self._alloc_sid())
            self._growing[key] = alloc
        alloc.rows += n_rows
        alloc.last_alloc_ms = self.clock.now_ms()
        if alloc.rows >= self.seal_rows_for(collection):
            self._to_seal.add((collection, alloc.segment_id))
            self._growing[key] = SegmentAlloc(self._alloc_sid())
        return alloc.segment_id

    # --------------------------------------------------------------- sealing
    def should_seal(self, collection: str, segment_id: int) -> bool:
        return (collection, segment_id) in self._to_seal

    def on_sealed(
        self,
        collection: str,
        segment_id: int,
        rows: int,
        partition: str = DEFAULT_PARTITION,
        shard: int = 0,
        attr_fields=None,
    ) -> None:
        self._to_seal.discard((collection, segment_id))
        self._sealed_rows[(collection, segment_id)] = rows
        self.meta.put(
            f"segment/{collection}/{segment_id}",
            {
                "rows": rows,
                "state": "sealed",
                "partition": partition,
                "shard": shard,
                "visible_from_ts": 0,
            },
        )
        self._record_attr_fields(collection, segment_id, rows, attr_fields)
        self.segment_map.apply(
            collection, add=[segment_id], ts=self.tso.last_issued()
        )

    def _record_attr_fields(
        self, collection: str, segment_id: int, rows: int, attr_fields
    ) -> None:
        """Meta-key the segment's attribute-index satellites (mirrors the
        per-field vector index records) so GC and recovery can enumerate
        them without listing the object store."""
        for f in attr_fields or ():
            self.meta.put(
                f"attr_index/{collection}/{segment_id}/{f}",
                {"field": f, "rows": rows, "state": "ready"},
            )

    def allocate_segment_id(self) -> int:
        """Reserve a fresh segment id (compaction rewrite targets)."""
        return self._alloc_sid()

    def on_compacted(
        self,
        collection: str,
        sources: list[int],
        targets: list[dict],
        partition: str = DEFAULT_PARTITION,
        shard: int = 0,
        compact_ts: int = 0,
        attr_fields=None,
    ) -> None:
        """Swap segment identity after a compaction rewrite completed.

        ``targets`` is the rewrite output: [{"segment_id", "num_rows"}, ...].
        """
        target_ids = [t["segment_id"] for t in targets]
        for sid in sources:
            self._sealed_rows.pop((collection, sid), None)
            old = self.meta.get(f"segment/{collection}/{sid}") or {}
            self.meta.put(
                f"segment/{collection}/{sid}",
                {
                    "rows": 0,
                    "state": "retired",
                    "compacted_into": target_ids,
                    "partition": old.get("partition", partition),
                    "shard": old.get("shard", shard),
                    # keep the source's MVCC window so a restart can still
                    # serve reads pinned before the swap
                    "visible_from_ts": int(old.get("visible_from_ts", 0)),
                    "retired_at_ts": compact_ts,
                },
            )
        for t in targets:
            self._sealed_rows[(collection, t["segment_id"])] = t["num_rows"]
            self.meta.put(
                f"segment/{collection}/{t['segment_id']}",
                {
                    "rows": t["num_rows"],
                    "state": "sealed",
                    "partition": partition,
                    "shard": shard,
                    "visible_from_ts": compact_ts,
                },
            )
            self._record_attr_fields(
                collection, t["segment_id"], t["num_rows"], attr_fields
            )

    def flush(self, collection: str) -> list[int]:
        """Force-seal every growing segment of a collection."""
        sealed = []
        for (coll, shard, part), alloc in list(self._growing.items()):
            if coll != collection or alloc.rows == 0:
                continue
            self._to_seal.add((coll, alloc.segment_id))
            sealed.append(alloc.segment_id)
            self._growing[(coll, shard, part)] = SegmentAlloc(self._alloc_sid())
        return sealed

    def seal_idle(self, max_idle_ms: float) -> list[int]:
        """Time-based sealing (paper: seal after a period without inserts)."""
        now = self.clock.now_ms()
        sealed = []
        for (coll, shard, part), alloc in list(self._growing.items()):
            if alloc.rows > 0 and (now - alloc.last_alloc_ms) >= max_idle_ms:
                self._to_seal.add((coll, alloc.segment_id))
                sealed.append(alloc.segment_id)
                self._growing[(coll, shard, part)] = SegmentAlloc(self._alloc_sid())
        return sealed

    def sealed_segments(self, collection: str) -> list[int]:
        return sorted(sid for (c, sid) in self._sealed_rows if c == collection)

    def segment_partition(self, collection: str, segment_id: int) -> str:
        info = self.meta.get(f"segment/{collection}/{segment_id}") or {}
        return info.get("partition", DEFAULT_PARTITION)

    def partition_segments(self, collection: str, partition: str) -> list[int]:
        """Sealed segments currently placed under ``partition``."""
        return sorted(
            sid
            for (c, sid) in self._sealed_rows
            if c == collection
            and self.segment_partition(collection, sid) == partition
        )

    def drop_partition_state(self, collection: str, partition: str, ts: int) -> list[int]:
        """Forget a dropped partition's placement: clear its growing
        allocations and retire its sealed segments (marked for GC).
        Returns the retired sealed segment ids."""
        for key in [k for k in self._growing if k[0] == collection and k[2] == partition]:
            self._to_seal.discard((collection, self._growing[key].segment_id))
            del self._growing[key]
        sids = self.partition_segments(collection, partition)
        for sid in sids:
            self._sealed_rows.pop((collection, sid), None)
            self.meta.put(
                f"segment/{collection}/{sid}",
                {"rows": 0, "state": "retired", "partition": partition},
            )
            self.meta.put(
                f"retired_segment/{collection}/{sid}",
                {"retired_at_ts": ts, "compacted_into": []},
            )
        if sids:
            self.segment_map.apply(collection, remove=sids, ts=ts)
        return sids

    def record_sealed_position(self, collection: str, shard: int, pos: int) -> None:
        key = (collection, shard)
        cur = self.replay_position(collection, shard)
        new = max(cur, pos)
        self._sealed_upto_pos[key] = new
        if new != cur:
            # durable checkpoint: a restarted system replays from here
            self.meta.put(f"replay/{collection}/{shard}", {"pos": new})

    def replay_position(self, collection: str, shard: int) -> int:
        """WAL position from which a recovering node must replay."""
        key = (collection, shard)
        pos = self._sealed_upto_pos.get(key)
        if pos is None:
            rec = self.meta.get(f"replay/{collection}/{shard}") or {}
            pos = int(rec.get("pos", 0))
            self._sealed_upto_pos[key] = pos
        return pos

    # -------------------------------------------------------------- recovery
    def recover_state(self, store=None) -> dict:
        """Rebuild allocator + sealing state after a full restart.

        Sealed/retired segments come from the ``segment/`` meta records; the
        growing allocations are reconstructed by replaying the WAL and
        counting rows of every segment that never reached a binlog — exactly
        the rows the data nodes themselves rebuild.  ``store`` (optional)
        lets the scan also skip segments whose binlog survived a crash that
        lost the ``segment_sealed`` announcement; the system-level
        reconciliation re-announces those.
        """
        self.id_alloc.recover()
        seq = self.meta.get("segment_seq") or {}
        self._next_segment = max(self._next_segment, int(seq.get("next", 1)))
        sealed = 0
        for key, rec in self.meta.scan("segment/").items():
            _, coll, sid_s = key.split("/")
            sid = int(sid_s)
            self._next_segment = max(self._next_segment, sid + 1)
            if rec.get("state") == "sealed":
                self._sealed_rows[(coll, sid)] = int(rec["rows"])
                sealed += 1
        counts: dict[tuple[str, int, str], dict[int, int]] = {}
        for ckey, info in self.meta.scan("collection/").items():
            coll = ckey.split("/", 1)[1]
            for shard in range(int(info["num_shards"])):
                try:
                    entries = self.broker.read(dml_channel(coll, shard), 0)
                except KeyError:
                    continue
                for e in entries:
                    if e.type not in (EntryType.INSERT, EntryType.UPSERT):
                        continue
                    p = e.payload
                    sid = p["segment_id"]
                    if (coll, sid) in self._sealed_rows:
                        continue
                    if self.meta.get(f"segment/{coll}/{sid}") is not None:
                        continue  # retired: durable, not growing
                    if store is not None and store.exists(f"binlog/{coll}/{sid}/meta"):
                        continue  # archived; announcement reconciled elsewhere
                    gkey = (coll, shard, p.get("partition", DEFAULT_PARTITION))
                    per = counts.setdefault(gkey, {})
                    per[sid] = per.get(sid, 0) + len(p["pk"])
        growing = 0
        for gkey, per_sid in counts.items():
            coll = gkey[0]
            sids = sorted(per_sid)
            # every sid but the newest had a successor allocated pre-crash,
            # which only happens once the sid was marked for sealing
            for sid in sids[:-1]:
                self._to_seal.add((coll, sid))
            last = sids[-1]
            alloc = SegmentAlloc(
                last, rows=per_sid[last], last_alloc_ms=self.clock.now_ms()
            )
            if per_sid[last] >= self.seal_rows_for(coll):
                self._to_seal.add((coll, last))
                alloc = SegmentAlloc(self._alloc_sid())
            self._growing[gkey] = alloc
            growing += 1
        return {"sealed": sealed, "growing": growing, "to_seal": len(self._to_seal)}


# ---------------------------------------------------------------------------
# Index coordinator: build-task fan-out, idle-node shutdown
# ---------------------------------------------------------------------------


class IndexCoordinator:
    """Per-vector-field index specs: ``index_spec/{collection}/{field}``
    in the meta store, one build task per (segment, field)."""

    def __init__(
        self,
        broker: LogBroker,
        meta: MetaStore,
        tso: TSO,
        events: EventLog | None = None,
    ):
        self.broker = broker
        self.meta = meta
        self.tso = tso
        self.events = events
        self.sub = Subscription(broker, COORD_CHANNEL)
        # (collection, segment_id, field) -> task / index_built payload
        self.pending_tasks: dict[tuple[str, int, str], dict] = {}
        self.built: dict[tuple[str, int, str], dict] = {}

    def set_index_spec(
        self,
        collection: str,
        field: str,
        kind: str,
        params: dict[str, Any] | None = None,
        metric: Metric = Metric.L2,
        column: str | None = None,
    ) -> None:
        """Declare the index of one vector field.  ``column`` is the
        segment column backing the field (the first vector field is stored
        as the primary "vector" column); defaults to the field name."""
        self.meta.put(
            f"index_spec/{collection}/{field}",
            {
                "field": field,
                "column": column or field,
                "kind": kind,
                "params": params or {},
                "metric": metric.value,
            },
        )

    def index_spec(self, collection: str, field: str = "vector") -> dict | None:
        return self.meta.get(f"index_spec/{collection}/{field}")

    def index_specs(self, collection: str) -> dict[str, dict]:
        """All field specs of a collection: field name -> spec."""
        return {
            key.rsplit("/", 1)[1]: spec
            for key, spec in self.meta.scan(f"index_spec/{collection}/").items()
        }

    def _task_of(self, collection: str, segment_id: int, spec: dict) -> dict:
        return {
            "msg": "index_build_task",
            "collection": collection,
            "segment_id": segment_id,
            "field": spec["field"],
            "column": spec.get("column", spec["field"]),
            "index_kind": spec["kind"],
            "params": spec["params"],
            "metric": spec["metric"],
        }

    def step(self) -> bool:
        progress = False
        for entry in self.sub.poll():
            if entry.type is not EntryType.COORD:
                continue
            p = entry.payload
            if p.get("msg") == "segment_sealed":
                for field, spec in self.index_specs(p["collection"]).items():
                    key = (p["collection"], p["segment_id"], field)
                    if key in self.pending_tasks or key in self.built:
                        continue
                    task = self._task_of(p["collection"], p["segment_id"], spec)
                    self.pending_tasks[key] = task
                    self.broker.publish(
                        COORD_CHANNEL,
                        LogEntry(ts=self.tso.next(), type=EntryType.COORD, payload=task),
                    )
                    if self.events is not None:
                        self.events.emit(
                            "index_task", "index_coord",
                            collection=p["collection"],
                            segment_id=p["segment_id"],
                            field=field, index_kind=spec["kind"],
                        )
                    progress = True
            elif p.get("msg") == "index_built":
                field = p.get("field", "vector")
                key = (p["collection"], p["segment_id"], field)
                self.pending_tasks.pop(key, None)
                self.built[key] = p
                self.meta.put(
                    f"index/{p['collection']}/{p['segment_id']}/{field}",
                    {
                        "kind": p["index_kind"],
                        "key": p["index_key"],
                        "column": p.get("column", field),
                    },
                )
                if self.events is not None:
                    self.events.emit(
                        "index_built", "index_coord",
                        collection=p["collection"],
                        segment_id=p["segment_id"],
                        field=field, index_kind=p["index_kind"],
                        built_by=p.get("built_by"),
                    )
                progress = True
            elif p.get("msg") == "segment_compacted":
                # The rewrite produced fresh segments: index them, and forget
                # build state of the sources they replaced.
                for sid in p.get("sources", ()):
                    for key in [
                        k for k in self.pending_tasks
                        if k[:2] == (p["collection"], sid)
                    ]:
                        self.pending_tasks.pop(key, None)
                    for key in [
                        k for k in self.built if k[:2] == (p["collection"], sid)
                    ]:
                        self.built.pop(key, None)
                for t in p["segments"]:
                    if t["num_rows"]:
                        self.rebuild_segment(p["collection"], t["segment_id"])
                progress = True
            elif p.get("msg") == "segment_gc":
                coll, sid = p["collection"], p["segment_id"]
                for key in [k for k in self.pending_tasks if k[:2] == (coll, sid)]:
                    self.pending_tasks.pop(key, None)
                for key in [k for k in self.built if k[:2] == (coll, sid)]:
                    self.built.pop(key, None)
                for ikey in self.meta.scan(f"index/{coll}/{sid}/"):
                    self.meta.delete(ikey)
                for claim in self.meta.scan(f"index_claim/{coll}/{sid}/"):
                    self.meta.delete(claim)
                progress = True
        return progress

    # -------------------------------------------------------------- recovery
    def recover_state(self) -> dict:
        """Rebuild build-state after a restart: adopt finished builds from
        the ``index/`` meta records, clear claims whose builder died before
        finishing, and re-issue tasks for sealed segments missing an index.
        Fast-forwards past the pre-crash coordination history — its durable
        effects were just adopted."""
        adopted = 0
        for key, rec in self.meta.scan("index/").items():
            _, coll, sid_s, field = key.split("/")
            self.built[(coll, int(sid_s), field)] = {
                "msg": "index_built",
                "collection": coll,
                "segment_id": int(sid_s),
                "field": field,
                "column": rec.get("column", field),
                "index_kind": rec["kind"],
                "index_key": rec["key"],
            }
            adopted += 1
        cleared = 0
        for claim in list(self.meta.scan("index_claim/")):
            _, coll, sid_s, field, _kind = claim.split("/")
            if (coll, int(sid_s), field) not in self.built:
                # claimed but never finished: the builder died mid-build
                self.meta.delete(claim)
                cleared += 1
        self.sub.seek(self.broker.end_position(COORD_CHANNEL))
        reissued = 0
        for key, seg in self.meta.scan("segment/").items():
            if seg.get("state") != "sealed":
                continue
            _, coll, sid_s = key.split("/")
            sid = int(sid_s)
            for field, spec in self.index_specs(coll).items():
                k = (coll, sid, field)
                if k in self.built or k in self.pending_tasks:
                    continue
                task = self._task_of(coll, sid, spec)
                self.pending_tasks[k] = task
                self.broker.publish(
                    COORD_CHANNEL,
                    LogEntry(ts=self.tso.next(), type=EntryType.COORD, payload=task),
                )
                reissued += 1
        return {"built": adopted, "claims_cleared": cleared, "tasks_reissued": reissued}

    def rebuild_segment(
        self, collection: str, segment_id: int, fields: "list[str] | None" = None
    ) -> None:
        """Re-issue builds (after compaction, heavy deletes, or a new
        field spec); ``fields=None`` rebuilds every spec'd field."""
        specs = self.index_specs(collection)
        for field, spec in specs.items():
            if fields is not None and field not in fields:
                continue
            self.built.pop((collection, segment_id, field), None)
            self.meta.delete(
                f"index_claim/{collection}/{segment_id}/{field}/{spec['kind']}"
            )
            task = self._task_of(collection, segment_id, spec)
            self.pending_tasks[(collection, segment_id, field)] = task
            self.broker.publish(
                COORD_CHANNEL,
                LogEntry(ts=self.tso.next(), type=EntryType.COORD, payload=task),
            )


# ---------------------------------------------------------------------------
# Query coordinator: replica groups, load balance, failover, scaling
# ---------------------------------------------------------------------------


@dataclass
class QueryNodeState:
    node_id: str
    lease_id: int
    segments: set[tuple[str, int]] = field(default_factory=set)
    channels: set[str] = field(default_factory=set)
    draining: bool = False
    last_beat_ms: float = 0.0


class QueryCoordinator:
    """Single-leader query coordinator (paper §3.2, §3.6).

    Every sealed segment is owned by a **replica group** — an ordered list
    of query nodes (index 0 is the primary).  The authoritative placement
    record lives in the meta store at ``assignment/{coll}/{sid}`` and every
    mutation goes through the CAS-safe ``update_placement`` primitive, so a
    failover racing a rebalance converges on the committed winner instead
    of clobbering it.  Health observation (``HealthMonitor``) and
    convergence (``StateReconciler``) are split per the single-writer
    control-loop idiom: the monitor only observes, the reconciler acts.
    """

    HEARTBEAT_TTL_MS = 5_000

    def __init__(
        self,
        broker: LogBroker,
        meta: MetaStore,
        tso: TSO,
        data_coord: DataCoordinator,
        replication_factor: int = 1,
        heartbeat_ttl_ms: float | None = None,
        events: EventLog | None = None,
    ):
        self.broker = broker
        self.meta = meta
        self.tso = tso
        self.data_coord = data_coord
        self.clock = data_coord.clock
        self.events = events
        self.sub = Subscription(broker, COORD_CHANNEL)
        self.nodes: dict[str, QueryNodeState] = {}
        # (collection, segment_id) -> ordered replica group (node ids);
        # in-memory mirror of the committed ``assignment/`` meta records.
        self.replica_sets: dict[tuple[str, int], list[str]] = {}
        self.replication_factor = max(1, int(replication_factor))
        self.heartbeat_ttl_ms = float(
            heartbeat_ttl_ms if heartbeat_ttl_ms is not None else self.HEARTBEAT_TTL_MS
        )
        # DML channel -> standby follower node ids: replicas that consume
        # the channel (rf > 1) WITHOUT owning it.  Kept out of
        # ``QueryNodeState.channels`` (the ownership/committed surface that
        # failover, drain and cluster_state reason about) — followers are
        # a read-routing surface: the proxy serves bounded-staleness reads
        # from whichever candidate's watermark already covers the request.
        self.channel_followers: dict[str, set[str]] = {}
        # (collection, segment_id) -> {field: index_built payload}
        self._known_indexes: dict[tuple[str, int], dict[str, dict]] = {}
        # (collection, segment_id) -> visible_from_ts MVCC gate of compacted
        # rewrites; must survive failover/rebalance reloads or a pinned
        # query would see both the rewrite and its retired sources.
        self._visible_from: dict[tuple[str, int], int] = {}
        # Serializes control-loop passes against coordination-log consumption
        # when a threaded watchdog reconciles concurrently with the pump.
        self._mutex = threading.RLock()
        self.health = HealthMonitor(self)
        self.reconciler = StateReconciler(self)

    # ------------------------------------------------------------ membership
    def register_node(self, node_id: str) -> int:
        lease = self.meta.grant_lease(self.heartbeat_ttl_ms)
        self.meta.put(f"querynode/{node_id}", {"node_id": node_id}, lease_id=lease)
        self.nodes[node_id] = QueryNodeState(
            node_id, lease, last_beat_ms=self.clock.now_ms()
        )
        if self.events is not None:
            self.events.emit("node_join", "query_coord", node=node_id)
        return lease

    def heartbeat(self, node_id: str) -> None:
        st = self.nodes.get(node_id)
        if st:
            self.meta.keepalive(st.lease_id)
            st.last_beat_ms = self.clock.now_ms()

    def deregister_node(self, node_id: str) -> None:
        # Revoke the lease only; the node stays in ``self.nodes`` until
        # ``handle_failures`` reassigns its segments/channels (popping it
        # here would orphan its assignments).
        st = self.nodes.get(node_id)
        if st:
            self.meta.revoke_lease(st.lease_id)

    def start_drain(self, node_id: str) -> None:
        """Mark a node for graceful scale-down: it keeps serving, but the
        reconciler sheds its replicas (load-before-release) and it stops
        receiving new placements."""
        st = self.nodes.get(node_id)
        if st:
            st.draining = True
            if self.events is not None:
                self.events.emit(
                    "drain_start", "query_coord",
                    node=node_id, replicas=len(st.segments),
                )

    def live_nodes(self) -> list[str]:
        alive = set(self.meta.scan("querynode/"))
        return sorted(
            n for n in self.nodes if f"querynode/{n}" in alive
        )

    def on_node_down(self, node_id: str) -> None:
        """Immediate failure report from the dispatch path (a request found
        the node dead): revoke its lease and reconcile now rather than
        waiting out the heartbeat TTL."""
        st = self.nodes.get(node_id)
        if st is not None:
            self.meta.revoke_lease(st.lease_id)
        if self.events is not None:
            self.events.emit("node_down_reported", "query_coord", node=node_id)
        self.reconciler.reconcile()

    # ------------------------------------------------------------ placement
    @property
    def assignment(self) -> dict[tuple[str, int], str]:
        """Legacy single-owner view: segment -> primary replica."""
        return {key: nodes[0] for key, nodes in self.replica_sets.items() if nodes}

    def replication_for(self, collection: str) -> int:
        """Desired replica count: per-collection override, else config."""
        info = self.meta.get(f"collection/{collection}") or {}
        return max(1, int(info.get("replication_factor", self.replication_factor)))

    def placement_for(self, collection: str) -> dict[int, list[str]]:
        """segment_id -> replica group, for the proxy's dispatch planner."""
        return {
            sid: list(nodes)
            for (coll, sid), nodes in self.replica_sets.items()
            if coll == collection
        }

    def _placement_candidates(self, exclude: set[str] | None = None) -> list[str]:
        """Live, non-draining nodes eligible to receive new replicas."""
        exclude = exclude or set()
        return [
            n for n in self.live_nodes()
            if n not in exclude and not self.nodes[n].draining
        ]

    def _least_loaded(self, exclude: set[str] | None = None) -> str | None:
        nodes = self._placement_candidates(exclude)
        if not nodes:
            return None
        return min(nodes, key=lambda n: (len(self.nodes[n].segments), n))

    def update_placement(
        self,
        collection: str,
        segment_id: int,
        fn: Callable[[list[str]], "list[str] | None"],
    ) -> list[str]:
        """CAS-safe read-modify-write of one segment's replica group.

        ``fn(current_nodes) -> new_nodes | None`` computes the new replica
        list from the value *actually committed* in the meta store (None
        aborts).  The write is retried until the compare-and-swap lands, so
        a reassignment racing a concurrent rebalance recomputes from the
        winner's committed record instead of overwriting it.  Load/release
        messages and in-memory mirrors are applied only for the committed
        value.  Returns the committed replica list (the pre-existing one on
        abort).
        """
        with self._mutex:
            key = (collection, segment_id)
            mkey = f"assignment/{collection}/{segment_id}"
            desired = self.replication_for(collection)
            while True:
                rev = self.meta.get_rev(mkey)
                cur = self.meta.get(mkey) or {}
                cur_nodes = list(cur.get("nodes") or ())
                if not cur_nodes and cur.get("node"):
                    cur_nodes = [cur["node"]]
                new_nodes = fn(list(cur_nodes))
                if new_nodes is None:
                    return cur_nodes
                new_nodes = list(dict.fromkeys(new_nodes))
                record = {
                    "nodes": new_nodes,
                    "node": new_nodes[0] if new_nodes else None,
                    "visible_from_ts": self._visible_from.get(key, 0),
                    "under_replicated": len(new_nodes) < desired,
                }
                if not self.meta.cas(mkey, rev, record):
                    if self.events is not None:
                        self.events.emit(
                            "placement_cas_retry", "query_coord",
                            collection=collection, segment_id=segment_id,
                        )
                    continue  # lost the race: recompute from the winner
                self._apply_committed(key, new_nodes)
                return new_nodes

    def _apply_committed(self, key: tuple[str, int], new_nodes: list[str]) -> None:
        """Sync mirrors and publish load/release for a committed placement.
        Loads are published before releases, so a segment may briefly live
        on both nodes (the proxy dedups) but never on neither."""
        coll, sid = key
        old = self.replica_sets.get(key, [])
        added = [n for n in new_nodes if n not in old]
        removed = [n for n in old if n not in new_nodes]
        if new_nodes:
            self.replica_sets[key] = list(new_nodes)
        else:
            self.replica_sets.pop(key, None)
            self.meta.delete(f"assignment/{coll}/{sid}")
        for n in added:
            if n not in self.nodes:
                continue
            self.nodes[n].segments.add(key)
            self._publish(
                {
                    "msg": "load_segment",
                    "node_id": n,
                    "collection": coll,
                    "segment_id": sid,
                    "visible_from_ts": self._visible_from.get(key, 0),
                }
            )
            for idx in self._known_indexes.get(key, {}).values():
                self._publish(self._load_index_payload(n, idx))
        for n in removed:
            if n not in self.nodes:
                continue
            self.nodes[n].segments.discard(key)
            self._publish(
                {
                    "msg": "release_segment",
                    "node_id": n,
                    "collection": coll,
                    "segment_id": sid,
                }
            )

    def _fill_replicas(self, nodes: list[str], desired: int) -> list[str]:
        """Top a replica list up to ``desired`` with least-loaded candidates;
        degrades gracefully (shorter list) when the cluster is too small —
        the committed record then carries ``under_replicated: True``."""
        nodes = [n for n in nodes if n in self.nodes and not self.nodes[n].draining]
        while len(nodes) < desired:
            pick = self._least_loaded(exclude=set(nodes))
            if pick is None:
                break
            nodes.append(pick)
        return nodes

    def _publish(self, payload: dict) -> None:
        self.broker.publish(
            COORD_CHANNEL,
            LogEntry(ts=self.tso.next(), type=EntryType.COORD, payload=payload),
        )

    def step(self) -> bool:
        with self._mutex:
            return self._step_locked()

    def _step_locked(self) -> bool:
        progress = False
        for entry in self.sub.poll():
            if entry.type is not EntryType.COORD:
                continue
            p = entry.payload
            msg = p.get("msg")
            if msg == "segment_sealed":
                self.data_coord.record_sealed_position(
                    p["collection"], p["shard"], p["checkpoint_pos"] + 1
                )
                progress |= self._assign_segment(p["collection"], p["segment_id"])
            elif msg == "index_built":
                key = (p["collection"], p["segment_id"])
                self._known_indexes.setdefault(key, {})[p.get("field", "vector")] = p
                for node in self.replica_sets.get(key, ()):
                    if node in self.nodes:
                        self._publish(self._load_index_payload(node, p))
                progress = True
            elif msg == "segment_compacted":
                progress |= self._handle_compacted(p)
            elif msg == "partition_dropped":
                progress |= self._handle_partition_dropped(p)
        return progress

    def _handle_partition_dropped(self, p: dict) -> bool:
        """Release every replica of a dropped partition's segments."""
        coll = p["collection"]
        changed = False
        for sid in p.get("segment_ids", ()):
            key = (coll, sid)
            owners = self.replica_sets.pop(key, [])
            self._known_indexes.pop(key, None)
            self._visible_from.pop(key, None)
            self.meta.delete(f"assignment/{coll}/{sid}")
            for owner in owners:
                if owner in self.nodes:
                    self.nodes[owner].segments.discard(key)
                    self._publish(
                        {
                            "msg": "release_segment",
                            "node_id": owner,
                            "collection": coll,
                            "segment_id": sid,
                        }
                    )
            changed = True
        return changed

    def _handle_compacted(self, p: dict) -> bool:
        """Hot-swap a compacted rewrite for its source segments.

        The new segments are loaded (gated at ``compact_ts``) before the
        sources are retired, so there is never a serving gap; the sources
        keep answering queries pinned before the swap until the retention
        horizon releases them.
        """
        coll = p["collection"]
        sources = list(p["sources"])
        live = set(self.live_nodes())
        # The primary stays aligned with the shard's DML channel subscriber
        # so future delta deletes keep reaching the node serving the rows.
        ch = dml_channel(coll, p["shard"])
        anchor = next(
            (n for n in sorted(live) if ch in self.nodes[n].channels), None
        )
        if anchor is None:
            owners = [
                n
                for sid in sources
                for n in self.replica_sets.get((coll, sid), ())
                if n in live
            ]
            anchor = (
                max(set(owners), key=owners.count) if owners else self._least_loaded()
            )
        if anchor is None:
            return False
        desired = self.replication_for(coll)
        for t in p["segments"]:
            new_sid = t["segment_id"]
            key = (coll, new_sid)
            if key in self.replica_sets or t["num_rows"] == 0:
                continue
            self._visible_from[key] = p["compact_ts"]

            def place(cur: list[str], anchor: str = anchor) -> list[str]:
                nodes = [n for n in cur if n in self.nodes]
                if anchor not in nodes and anchor in self.nodes:
                    nodes.insert(0, anchor)
                return self._fill_replicas(nodes, desired) or nodes

            self.update_placement(coll, new_sid, place)
        # Broadcast the folded tombstones: every node prunes its
        # delta-delete map once the retention horizon passes the swap.
        self._publish(
            {
                "msg": "tombstones_folded",
                "collection": coll,
                "folded_pks": p["folded_pks"],
                "compact_ts": p["compact_ts"],
            }
        )
        if self.events is not None:
            self.events.emit(
                "segment_hot_swap", "query_coord",
                collection=coll, sources=sources,
                targets=[t["segment_id"] for t in p["segments"]],
                compact_ts=p["compact_ts"],
            )
        for sid in sources:
            skey = (coll, sid)
            owners = self.replica_sets.pop(skey, [])
            self._known_indexes.pop(skey, None)
            self._visible_from.pop(skey, None)
            for owner in owners:
                if owner in self.nodes:
                    self.nodes[owner].segments.discard(skey)
                    self._publish(
                        {
                            "msg": "retire_segment",
                            "node_id": owner,
                            "collection": coll,
                            "segment_id": sid,
                            "retired_at_ts": p["compact_ts"],
                        }
                    )
            self.meta.delete(f"assignment/{coll}/{sid}")
        return True

    def _assign_segment(self, collection: str, segment_id: int) -> bool:
        """Least-loaded placement of a fresh sealed segment's replica group."""
        key = (collection, segment_id)
        if key in self.replica_sets:
            return False
        desired = self.replication_for(collection)

        def place(cur: list[str]) -> "list[str] | None":
            return self._fill_replicas(cur, desired) or None

        return bool(self.update_placement(collection, segment_id, place))

    def _load_index_payload(self, node: str, built: dict) -> dict:
        return {
            "msg": "load_index",
            "node_id": node,
            "collection": built["collection"],
            "segment_id": built["segment_id"],
            "field": built.get("field", "vector"),
            "column": built.get("column", built.get("field", "vector")),
            "index_kind": built["index_kind"],
            "index_key": built["index_key"],
        }

    # -------------------------------------------------------------- recovery
    def recover_state(self) -> dict:
        """Adopt committed placement inputs from the meta store after a full
        restart: MVCC visibility pins (``segment/*.visible_from_ts``) and
        finished index builds (``index/``).  The coordination-log history is
        fast-forwarded — its committed effects live in the meta store — and
        the reconciler then re-places every sealed segment onto whatever
        nodes are registered now."""
        with self._mutex:
            pins = indexes = 0
            for key, rec in self.meta.scan("segment/").items():
                _, coll, sid_s = key.split("/")
                vts = int(rec.get("visible_from_ts", 0) or 0)
                if vts:
                    self._visible_from[(coll, int(sid_s))] = vts
                    pins += 1
            for key, rec in self.meta.scan("index/").items():
                _, coll, sid_s, field = key.split("/")
                skey = (coll, int(sid_s))
                self._known_indexes.setdefault(skey, {})[field] = {
                    "msg": "index_built",
                    "collection": coll,
                    "segment_id": int(sid_s),
                    "field": field,
                    "column": rec.get("column", field),
                    "index_kind": rec["kind"],
                    "index_key": rec["key"],
                }
                indexes += 1
            self.sub.seek(self.broker.end_position(COORD_CHANNEL))
            return {"visible_pins": pins, "indexes": indexes}

    def recover_retired(self, store) -> int:
        """Reload retired-but-not-GC'd segments so reads pinned before their
        hot-swap keep answering after a restart.  Each is loaded onto a live
        node and immediately re-retired, restoring the bounded MVCC window
        ``[visible_from_ts, retired_at_ts)`` the handle had before the crash."""
        with self._mutex:
            count = 0
            for key, rec in self.meta.scan("retired_segment/").items():
                _, coll, sid_s = key.split("/")
                sid = int(sid_s)
                if self.meta.get(f"collection/{coll}") is None:
                    continue
                if not store.exists(f"binlog/{coll}/{sid}/meta"):
                    continue  # GC already reclaimed it
                seg = self.meta.get(f"segment/{coll}/{sid}") or {}
                part = seg.get("partition", DEFAULT_PARTITION)
                if self.meta.get(f"partition/{coll}/{part}") is None:
                    continue  # dropped partitions stay dropped
                node = self._least_loaded()
                if node is None:
                    break
                self._publish(
                    {
                        "msg": "load_segment",
                        "node_id": node,
                        "collection": coll,
                        "segment_id": sid,
                        "visible_from_ts": int(seg.get("visible_from_ts", 0)),
                    }
                )
                for idx in self._known_indexes.get((coll, sid), {}).values():
                    self._publish(self._load_index_payload(node, idx))
                self._publish(
                    {
                        "msg": "retire_segment",
                        "node_id": node,
                        "collection": coll,
                        "segment_id": sid,
                        "retired_at_ts": int(rec.get("retired_at_ts", 0)),
                    }
                )
                count += 1
            return count

    # ------------------------------------------------------ channel coverage
    def assign_channels(self, collection: str, num_shards: int) -> None:
        """Distribute DML channel subscriptions over live nodes (draining
        nodes shed channel ownership so scale-down leaves them idle).

        With replication factor > 1, the next rf-1 candidates consume each
        channel as standby *followers* (``channel_followers``): same WAL
        replay, no ownership.  Their consumed watermarks give the proxy
        routing choices for bounded-staleness reads and a warm takeover
        target on failover.  Idempotent — the reconciler re-runs this
        every pass, so only membership diffs publish messages."""
        nodes = self._placement_candidates() or self.live_nodes()
        if not nodes:
            return
        all_nodes = self.live_nodes()
        for shard in range(num_shards):
            ch = dml_channel(collection, shard)
            owner = nodes[shard % len(nodes)]
            for n in all_nodes:
                st = self.nodes[n]
                if n == owner and ch not in st.channels:
                    st.channels.add(ch)
                    self._publish(
                        {
                            "msg": "subscribe_channel",
                            "node_id": n,
                            "channel": ch,
                            "from_position": self.data_coord.replay_position(collection, shard),
                        }
                    )
                elif n != owner and ch in st.channels:
                    st.channels.discard(ch)
                    self._publish(
                        {"msg": "unsubscribe_channel", "node_id": n, "channel": ch}
                    )
            # ---- standby followers (rf - 1 of the remaining candidates)
            desired = self.replication_for(collection) - 1
            cands = [n for n in nodes if n != owner]
            want = set(cands[:desired]) if desired > 0 else set()
            have = self.channel_followers.setdefault(ch, set())
            have.discard(owner)  # promoted by a re-home: owner, not follower
            for n in sorted(want - have):
                have.add(n)
                # The node-side subscribe is idempotent (an existing
                # subscription keeps its position), so an owner->follower
                # transition re-publishing here is harmless.
                self._publish(
                    {
                        "msg": "subscribe_channel",
                        "node_id": n,
                        "channel": ch,
                        "from_position": self.data_coord.replay_position(collection, shard),
                    }
                )
            for n in sorted(have - want):
                have.discard(n)
                if n in self.nodes and ch not in self.nodes[n].channels:
                    self._publish(
                        {"msg": "unsubscribe_channel", "node_id": n, "channel": ch}
                    )

    # -------------------------------------------------------------- failover
    def handle_failures(self) -> list[str]:
        """Detect dead nodes (lease expiry) and reassign their replicas to
        under-replicated survivors, one CAS-committed record at a time."""
        with self._mutex:
            self.meta.expire_now()
            live = set(self.live_nodes())
            dead = [n for n in self.nodes if n not in live]
            for node_id in dead:
                st = self.nodes.pop(node_id)
                for fs in self.channel_followers.values():
                    fs.discard(node_id)
                if self.events is not None:
                    self.events.emit(
                        "node_dead", "query_coord",
                        node=node_id, replicas=len(st.segments),
                        channels=sorted(st.channels),
                    )
                for key in sorted(st.segments):
                    coll, sid = key
                    desired = self.replication_for(coll)

                    def heal(cur: list[str], dead_id: str = node_id,
                             desired: int = desired) -> "list[str] | None":
                        survivors = [n for n in cur if n != dead_id]
                        return self._fill_replicas(survivors, desired)

                    # The dead node is already out of self.nodes, so the
                    # committed diff only loads onto survivors.
                    if key in self.replica_sets:
                        self.replica_sets[key] = [
                            n for n in self.replica_sets[key] if n != node_id
                        ]
                    self.update_placement(coll, sid, heal)
                # re-home channels: a live standby follower is the warm
                # takeover target (its subscription — kept by the
                # idempotent node-side subscribe — already consumed the
                # channel, so no replay gap); else least-loaded cold start.
                live_now = set(self.live_nodes())
                for ch in sorted(st.channels):
                    parts = ch.split("/")
                    coll, shard = parts[1], int(parts[2])
                    warm = sorted(
                        self.channel_followers.get(ch, ()) & live_now
                    )
                    target = warm[0] if warm else self._least_loaded()
                    if target:
                        self.channel_followers.get(ch, set()).discard(target)
                        self.nodes[target].channels.add(ch)
                        self._publish(
                            {
                                "msg": "subscribe_channel",
                                "node_id": target,
                                "channel": ch,
                                "from_position": self.data_coord.replay_position(coll, shard),
                            }
                        )
            return dead

    # -------------------------------------------------------------- balance
    def rebalance(self) -> int:
        """Move replicas from the most- to least-loaded node (paper §3.6),
        never co-locating two replicas of one segment on the same node."""
        with self._mutex:
            moved = 0
            while True:
                nodes = self._placement_candidates()
                if len(nodes) < 2:
                    return moved
                counts = {n: len(self.nodes[n].segments) for n in nodes}
                hi = max(nodes, key=lambda n: (counts[n], n))
                lo = min(nodes, key=lambda n: (counts[n], n))
                if counts[hi] - counts[lo] <= 1:
                    return moved
                key = next(
                    (
                        k
                        for k in sorted(self.nodes[hi].segments)
                        if lo not in self.replica_sets.get(k, ())
                    ),
                    None,
                )
                if key is None:
                    return moved
                coll, sid = key

                def move(cur: list[str], hi: str = hi, lo: str = lo) -> "list[str] | None":
                    if hi not in cur or lo in cur:
                        return None  # placement changed under us: abort
                    return [lo if n == hi else n for n in cur]

                self.update_placement(coll, sid, move)
                if lo not in self.replica_sets.get(key, ()):
                    return moved  # aborted: stop rather than spin
                moved += 1

    def nodes_for_collection(self, collection: str) -> list[str]:
        """All nodes holding segments or channels of the collection."""
        out = set()
        for (coll, _sid), nodes in self.replica_sets.items():
            if coll == collection:
                out.update(nodes)
        for n, st in self.nodes.items():
            if any(ch.startswith(f"dml/{collection}/") for ch in st.channels):
                out.add(n)
        return sorted(out & set(self.live_nodes()))


# ---------------------------------------------------------------------------
# Health + reconciliation control loop
# ---------------------------------------------------------------------------


class HealthMonitor:
    """Missed-heartbeat detector.  Observation only — it never mutates
    placement; the ``StateReconciler`` acts on what it reports (the
    observe/act split of the single-writer control-loop idiom)."""

    def __init__(self, coord: QueryCoordinator):
        self.coord = coord
        self._last: dict[str, str] = {}

    def observe(self) -> dict[str, str]:
        """Status per registered node: ``healthy`` / ``suspect`` (more than
        half a TTL since the last heartbeat) / ``dead`` (lease expired) /
        ``draining`` (graceful scale-down in progress)."""
        c = self.coord
        c.meta.expire_now()
        now = c.clock.now_ms()
        alive = set(c.live_nodes())
        out: dict[str, str] = {}
        for node_id, st in c.nodes.items():
            if node_id not in alive:
                out[node_id] = "dead"
            elif st.draining:
                out[node_id] = "draining"
            elif now - st.last_beat_ms > c.heartbeat_ttl_ms / 2:
                out[node_id] = "suspect"
            else:
                out[node_id] = "healthy"
        if c.events is not None:
            for node_id, status in out.items():
                if self._last.get(node_id, "healthy") != status:
                    c.events.emit(
                        "node_status_change", "health_monitor",
                        node=node_id, status=status,
                        was=self._last.get(node_id, "healthy"),
                    )
        self._last = dict(out)
        return out


class StateReconciler:
    """Converges desired placement with observed cluster state.  One pass:

    1. **failures** — expired leases: every replica the dead node held is
       CAS-reassigned to under-replicated survivors,
    2. **heal** — any sealed segment below its collection's replication
       factor (node join, prior total outage) gains replicas on the
       least-loaded candidates,
    3. **drain** — draining nodes shed replicas load-before-release (a
       replica leaves the draining node only once a replacement exists),
    4. **channels** — DML channel ownership re-converges over candidates,
    5. **rebalance** — replica counts converge toward even load.

    MVCC epoch pins ride along automatically: ``update_placement`` stamps
    every committed record with the segment's ``visible_from_ts``.
    """

    def __init__(self, coord: QueryCoordinator):
        self.coord = coord

    def reconcile(self) -> dict:
        c = self.coord
        with c._mutex:
            report = {
                "statuses": c.health.observe(),
                "dead": c.handle_failures(),
            }
            report["healed"] = self.heal()
            report["drained"] = self.drain()
            for key, info in c.meta.scan("collection/").items():
                c.assign_channels(key.split("/", 1)[1], info["num_shards"])
            report["moved"] = c.rebalance()
            if c.events is not None and (
                report["dead"] or report["healed"] or report["drained"]
                or report["moved"]
            ):
                c.events.emit(
                    "reconcile", "reconciler",
                    dead=list(report["dead"]), healed=report["healed"],
                    drained=report["drained"], moved=report["moved"],
                )
            return report

    def heal(self) -> int:
        """Top every under-replicated sealed segment back up to its desired
        replica count; returns the number of replicas added."""
        c = self.coord
        healed = 0
        for key in c.meta.scan("collection/"):
            coll = key.split("/", 1)[1]
            desired = c.replication_for(coll)
            capacity = len(c._placement_candidates())
            for sid in c.data_coord.sealed_segments(coll):
                skey = (coll, sid)
                cur = c.replica_sets.get(skey, [])
                have = [
                    n for n in cur if n in c.nodes and not c.nodes[n].draining
                ]
                if len(have) >= min(desired, capacity):
                    continue

                def grow(nodes_in: list[str], desired: int = desired) -> "list[str] | None":
                    grown = self.coord._fill_replicas(list(nodes_in), desired)
                    return grown if grown != nodes_in else None

                before = len(cur)
                new = c.update_placement(coll, sid, grow)
                healed += max(0, len(new) - before)
        return healed

    def drain(self) -> int:
        """Shed replicas off draining nodes; a replica is only released once
        a replacement node carries it (or another replica already does), so
        pinned MVCC reads keep a serving copy throughout."""
        c = self.coord
        shed = 0
        for node_id, st in list(c.nodes.items()):
            if not st.draining:
                continue
            for key in sorted(st.segments):
                coll, sid = key

                def shed_one(cur: list[str], node_id: str = node_id) -> "list[str] | None":
                    if node_id not in cur:
                        return None
                    rest = [n for n in cur if n != node_id]
                    repl = c._least_loaded(exclude=set(cur))
                    if repl is not None:
                        rest.append(repl)
                    # Keep the last copy on the draining node until some
                    # other node can take it: no serving gap, ever.
                    return rest if rest else None

                new = c.update_placement(coll, sid, shed_one)
                if node_id not in new:
                    shed += 1
                    if c.events is not None:
                        c.events.emit(
                            "drain_step", "reconciler",
                            node=node_id, collection=coll, segment_id=sid,
                            moved_to=[n for n in new if n not in (node_id,)],
                        )
        return shed
