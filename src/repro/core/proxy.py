"""Access layer: stateless proxies (paper §3.2, §3.6).

Proxies verify requests against cached metadata (early rejection), route
inserts/deletes to the owning loggers via the hash ring, fan search
requests out to the query nodes holding the collection's segments, and
aggregate node-wise top-k into the global top-k — removing duplicate
result vectors (a segment may briefly live on two nodes during
redistribution, and a row may exist both in a growing copy and the sealed
segment).

Straggler mitigation: ``search`` takes a ``hedge_timeout_s``; if a query
node does not answer in time and another live node can cover the same
segments, the scan is re-dispatched (hedged request).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from ..kernels import ops
from .collection import CollectionInfo, Metric
from .consistency import GuaranteeTs
from .coordinator import QueryCoordinator
from .log import shard_of_pk
from .logger_node import Logger
from .meta_store import MetaStore
from .query_node import QueryNode
from .timestamp import TSO, INFINITE_STALENESS


@dataclass
class SearchResult:
    scores: np.ndarray  # [nq, k]
    pks: np.ndarray  # [nq, k], -1 = empty slot
    query_ts: int
    waited_ms: float = 0.0


class Proxy:
    def __init__(
        self,
        proxy_id: str,
        meta: MetaStore,
        tso: TSO,
        loggers: list[Logger],
        query_coord: QueryCoordinator,
        query_nodes: dict[str, QueryNode],
    ):
        self.proxy_id = proxy_id
        self.meta = meta
        self.tso = tso
        self.loggers = loggers
        self.query_coord = query_coord
        self.query_nodes = query_nodes
        # Metadata cache, refreshed via meta-store watch (paper: proxies
        # cache a copy of the metadata for verifying legitimacy).
        self._meta_cache: dict[str, dict] = {}
        self._cancel_watch = meta.watch("collection/", self._on_meta)
        for key, value in meta.scan("collection/").items():
            self._meta_cache[key.split("/", 1)[1]] = value

    def _on_meta(self, key: str, value) -> None:
        name = key.split("/", 1)[1]
        if value is None:
            self._meta_cache.pop(name, None)
        else:
            self._meta_cache[name] = value

    # ------------------------------------------------------------- routing
    def _verify(self, collection: str) -> dict:
        info = self._meta_cache.get(collection)
        if info is None:
            raise KeyError(f"collection '{collection}' does not exist")
        return info

    def _logger_for(self, shard: int) -> Logger:
        live = [lg for lg in self.loggers if lg.alive]
        if not live:
            raise RuntimeError("no live loggers")
        return live[shard % len(live)]

    def insert(self, info: CollectionInfo, rows: dict[str, np.ndarray]) -> tuple[int, int]:
        self._verify(info.name)
        # Hash-ring: the logger owning shard 0 of this batch handles the
        # request (batches span shards; each logger writes its shards).
        shard0 = 0
        if info.schema.primary() and info.schema.primary().name in rows:
            shard0 = shard_of_pk(int(np.asarray(rows[info.schema.primary().name])[0]),
                                 info.num_shards)
        return self._logger_for(shard0).insert(info, rows)

    def delete(self, info: CollectionInfo, pks: np.ndarray) -> int:
        self._verify(info.name)
        return self._logger_for(0).delete(info, pks)

    # -------------------------------------------------------------- search
    def search(
        self,
        info: CollectionInfo,
        queries: np.ndarray,
        k: int,
        guarantee: GuaranteeTs,
        wait_fn=None,
        hedge_timeout_s: float | None = None,
        filter_expr=None,
    ) -> SearchResult:
        """Two-phase reduce over the query nodes holding the collection.

        ``wait_fn(node, guarantee) -> None`` implements the consistency wait
        (cooperative runtimes pump the system; threaded runtimes block).
        """
        self._verify(info.name)
        metric = info.metric
        nodes = self.query_coord.nodes_for_collection(info.name)
        target_nodes = [
            self.query_nodes[n] for n in nodes if self.query_nodes[n].alive
        ]
        t0 = time.perf_counter()
        partials: list[tuple[np.ndarray, np.ndarray]] = []
        pending = list(target_nodes)
        for node in pending:
            if wait_fn is not None:
                wait_fn(node, guarantee)
            try:
                if hedge_timeout_s is not None:
                    res = _run_with_timeout(
                        lambda: node.search(info.name, queries, k, metric, guarantee,
                                            filter_masks=self._filters(node, info, filter_expr)),
                        hedge_timeout_s,
                    )
                    if res is None:  # straggler: hedge to any other live node
                        others = [n for n in target_nodes if n is not node and n.alive]
                        if others:
                            res = others[0].search(
                                info.name, queries, k, metric, guarantee,
                                filter_masks=self._filters(others[0], info, filter_expr),
                            )
                        else:
                            res = node.search(info.name, queries, k, metric, guarantee,
                                              filter_masks=self._filters(node, info, filter_expr))
                else:
                    res = node.search(info.name, queries, k, metric, guarantee,
                                      filter_masks=self._filters(node, info, filter_expr))
            except RuntimeError:
                continue  # dead node; coordinator failover will cover its data
            partials.append(res)
        waited_ms = (time.perf_counter() - t0) * 1e3

        nq = len(queries)
        if not partials:
            fill = np.inf if metric is Metric.L2 else -np.inf
            return SearchResult(
                np.full((nq, k), fill, np.float32),
                np.full((nq, k), -1, np.int64),
                guarantee.query_ts,
                waited_ms,
            )
        # Global reduce: segmented k-way merge of the node-wise partials
        # with pk-dedup (a segment may surface from two nodes during
        # redistribution) — vectorized in the merge_topk kernel.
        out_s, out_p = ops.merge_topk(
            np.concatenate([p[0] for p in partials], axis=1),
            np.concatenate([p[1] for p in partials], axis=1),
            k,
            metric="l2" if metric is Metric.L2 else "ip",
        )
        return SearchResult(out_s, out_p, guarantee.query_ts, waited_ms)

    def _filters(self, node: QueryNode, info: CollectionInfo, filter_expr):
        """Resolve an attribute filter to per-segment row masks on a node."""
        if filter_expr is None:
            return None
        from ..index.attribute import FilterExpr

        expr = filter_expr if isinstance(filter_expr, FilterExpr) else FilterExpr(filter_expr)
        masks: dict[int, np.ndarray] = {}
        attr_fields = [f.name for f in info.schema.attribute_fields()]
        for (coll, sid), handle in list(node.sealed.items()):
            if coll != info.name:
                continue
            seg = handle.segment
            cols = {f: seg.extra(f) for f in attr_fields if f in seg.extra_fields}
            cols["pk"] = seg.pks()
            masks[sid] = expr.evaluate(cols, seg.num_rows)
        for (coll, sid), gs in list(node.growing.items()):
            if coll != info.name:
                continue
            seg = gs.segment
            cols = {f: seg.extra(f) for f in attr_fields if f in seg.extra_fields}
            cols["pk"] = seg.pks()
            masks[sid] = expr.evaluate(cols, seg.num_rows)
        return masks


def _run_with_timeout(fn, timeout_s: float):
    """Run fn in a worker thread; None on timeout (hedged-request helper)."""
    result: list = []

    def target():
        result.append(fn())

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(timeout_s)
    return result[0] if result else None


class BatchingProxy:
    """Request batching (paper §3.6): requests of the same type are grouped
    into one batch and handled together.  Each flushed group runs through
    ``Proxy.search`` and therefore the same fused-scan + ``merge_topk``
    global reduce as single requests."""

    def __init__(self, proxy: Proxy, max_batch: int = 64):
        self.proxy = proxy
        self.max_batch = max_batch
        self._queue: list[tuple[CollectionInfo, np.ndarray, int, GuaranteeTs]] = []

    def submit(self, info, query: np.ndarray, k: int, guarantee: GuaranteeTs) -> int:
        self._queue.append((info, query, k, guarantee))
        return len(self._queue) - 1

    def flush(self, wait_fn=None) -> list[SearchResult]:
        """Group by (collection, k) and run each group as one batch."""
        results: list[SearchResult | None] = [None] * len(self._queue)
        groups: dict[tuple[str, int], list[int]] = {}
        for i, (info, _q, k, _g) in enumerate(self._queue):
            groups.setdefault((info.name, k), []).append(i)
        for (name, k), idxs in groups.items():
            info = self._queue[idxs[0]][0]
            qs = np.concatenate([self._queue[i][1] for i in idxs], axis=0)
            # the batch executes under the *strictest* guarantee in the group
            guarantee = max(
                (self._queue[i][3] for i in idxs), key=lambda g: g.wait_target_ts()
            )
            batch_res = self.proxy.search(info, qs, k, guarantee, wait_fn=wait_fn)
            row = 0
            for i in idxs:
                n_i = len(self._queue[i][1])
                results[i] = SearchResult(
                    batch_res.scores[row : row + n_i],
                    batch_res.pks[row : row + n_i],
                    batch_res.query_ts,
                    batch_res.waited_ms,
                )
                row += n_i
        self._queue.clear()
        return results
