"""Access layer: stateless proxies (paper §3.2, §3.6).

Proxies verify requests against cached metadata (early rejection), route
inserts/deletes to the owning loggers via the hash ring, and drive the
read path with **replica-aware dispatch**: each live sealed segment is
routed to the least-loaded live replica of its group (plus the DML
channel owners for growing rows), and the per-node partials reduce into
the global top-k with pk-dedup (a segment may briefly live on two nodes
during redistribution, and a row may exist both in a growing copy and
the sealed segment).

Straggler mitigation: ``search`` takes a ``hedge_timeout_s``; a plan
unit that does not answer in time is re-dispatched to a *different*
replica of the same segment (blocking fallback on the original node only
for units with no alternative copy).  If a node dies between planning
and scan, the proxy reports it to the coordinator's control loop and
re-dispatches the failed units to surviving replicas mid-request.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from ..kernels import ops
from .collection import CollectionInfo, FieldType, Metric
from .consistency import GuaranteeTs
from .coordinator import QueryCoordinator
from .log import shard_of_channel, shard_of_pk
from .logger_node import Logger
from .meta_store import MetaStore
from .query_node import QueryNode, StalePlanError
from .request import (
    DeleteRequest,
    InsertRequest,
    MutationRequest,
    MutationResult,
    NodeSearchRequest,
    SearchRequest,
    UpsertRequest,
    vector_column_of,
)
from .segment import DEFAULT_PARTITION
from .telemetry import MetricsRegistry, TraceContext
from .timestamp import TSO, INFINITE_STALENESS


@dataclass
class SearchResult:
    scores: np.ndarray  # [nq, k]; raw metric scores, or fused sims (hybrid)
    pks: np.ndarray  # [nq, k], -1 = empty slot
    query_ts: int
    waited_ms: float = 0.0
    # Output-field hydration: field name -> [nq, k] (or [nq, k, dim] for
    # vector fields) aligned with ``pks``; empty slots carry NaN/0 fills.
    fields: dict[str, np.ndarray] | None = None
    # Span tree (telemetry.RequestTrace) when SearchRequest(trace=True).
    trace: object | None = None


class Proxy:
    def __init__(
        self,
        proxy_id: str,
        meta: MetaStore,
        tso: TSO,
        loggers: list[Logger],
        query_coord: QueryCoordinator,
        query_nodes: dict[str, QueryNode],
        metrics: MetricsRegistry | None = None,
    ):
        self.proxy_id = proxy_id
        self.meta = meta
        self.tso = tso
        self.loggers = loggers
        self.query_coord = query_coord
        self.query_nodes = query_nodes
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # BOUNDED staleness window (ms) for named-level resolution; the
        # system facade threads ``ManuConfig.bounded_staleness_ms`` here.
        self.bounded_staleness_ms = 2_000.0
        # How to advance message delivery while waiting on a placement
        # change mid-request (failover / slow load).  None = step the live
        # query nodes directly (cooperative default); the threaded runtime
        # installs a short sleep so its pump thread does the stepping.
        self.pump_fn = None
        # Metadata cache, refreshed via meta-store watch (paper: proxies
        # cache a copy of the metadata for verifying legitimacy).
        self._meta_cache: dict[str, dict] = {}
        self._cancel_watch = meta.watch("collection/", self._on_meta)
        for key, value in meta.scan("collection/").items():
            self._meta_cache[key.split("/", 1)[1]] = value
        # Partition cache: collection -> live partition names, kept fresh
        # the same way so placement/pruning verify without a meta round-trip.
        self._partition_cache: dict[str, set[str]] = {}
        self._cancel_partition_watch = meta.watch("partition/", self._on_partition)
        for key in meta.scan("partition/"):
            self._on_partition(key, True)
        # Compiled-filter LRU: (collection, expr string) -> FilterExpr.
        # Filters repeat heavily across requests (dashboards, paginated
        # clients), so parse+validate once and ship the compiled tree.
        self._filter_cache: "OrderedDict[tuple[str, str], object]" = OrderedDict()

    def _on_meta(self, key: str, value) -> None:
        name = key.split("/", 1)[1]
        if value is None:
            self._meta_cache.pop(name, None)
        else:
            self._meta_cache[name] = value

    def _on_partition(self, key: str, value) -> None:
        _, coll, name = key.split("/", 2)
        parts = self._partition_cache.setdefault(coll, set())
        if value is None:
            parts.discard(name)
        else:
            parts.add(name)

    # ------------------------------------------------------------- routing
    def _verify(self, collection: str) -> dict:
        info = self._meta_cache.get(collection)
        if info is None:
            raise KeyError(f"collection '{collection}' does not exist")
        return info

    def _logger_for(self, shard: int) -> Logger:
        live = [lg for lg in self.loggers if lg.alive]
        if not live:
            raise RuntimeError("no live loggers")
        return live[shard % len(live)]

    def partitions_of(self, collection: str) -> set[str]:
        parts = self._partition_cache.get(collection)
        # A collection created before any partition watch fired still owns
        # the implicit default partition.
        return parts if parts else {DEFAULT_PARTITION}

    def _verify_partition(self, collection: str, partition: str) -> None:
        if partition not in self.partitions_of(collection):
            raise KeyError(
                f"no partition '{partition}' in collection '{collection}'"
            )

    def mutate(self, info: CollectionInfo, request: MutationRequest) -> MutationResult:
        """Execute one typed mutation: verify against cached metadata
        (early rejection, paper §3.2), then route to the owning logger on
        the hash ring (the logger owning the batch's first shard handles
        the request; batches span shards and each shard gets its own WAL
        record)."""
        trace_ctx = (
            TraceContext("mutation") if getattr(request, "trace", False) else None
        )
        t0 = time.perf_counter()
        self._verify(info.name)
        request.validate(info.schema)
        shard0 = 0
        if isinstance(request, (InsertRequest, UpsertRequest)):
            self._verify_partition(info.name, request.partition)
            pk_field = info.schema.primary()
            if pk_field is not None and pk_field.name in request.rows:
                first = np.asarray(request.rows[pk_field.name])[:1]
                if first.size:
                    shard0 = shard_of_pk(first.tolist()[0], info.num_shards)
        elif isinstance(request, DeleteRequest) and len(request.pks):
            shard0 = shard_of_pk(request.pks.tolist()[0], info.num_shards)
        logger = self._logger_for(shard0)
        if trace_ctx is not None:
            span = trace_ctx.span(
                "logger_dispatch", node_id=logger.logger_id,
                detail=f"op={request.op};shard0={shard0}",
            )
            with trace_ctx.timed(span):
                res = logger.mutate(info, request, trace=(trace_ctx, span))
        else:
            res = logger.mutate(info, request)
        elapsed_us = (time.perf_counter() - t0) * 1e6
        self.metrics.inc("proxy_mutations_total", labels={"op": request.op})
        self.metrics.observe("proxy_mutation_latency_us", elapsed_us)
        if trace_ctx is not None:
            res.trace = trace_ctx.finish(elapsed_us)
        return res

    def mutate_batch(
        self,
        info: CollectionInfo,
        requests: "list[MutationRequest]",
        shard: int = 0,
        traces: "list[tuple | None] | None" = None,
        prevalidated: bool = False,
    ) -> "list[MutationResult | Exception]":
        """Scheduler flush path: one logger crossing for a micro-batch of
        already-admitted requests sharing a routing shard.  Verification
        happened at admission; ``prevalidated`` additionally skips the
        logger's per-request schema validation (admission already ran it).
        Each slot answers with its own result (or its own exception)."""
        self._verify(info.name)
        logger = self._logger_for(shard)
        results = logger.mutate_batch(
            info, requests, traces=traces, prevalidated=prevalidated
        )
        for request, res in zip(requests, results):
            if isinstance(res, MutationResult):
                self.metrics.inc(
                    "proxy_mutations_total", labels={"op": request.op}
                )
        return results

    def resolve_guarantee(self, request: SearchRequest) -> GuaranteeTs:
        """Pin the request's consistency fields to a :class:`GuaranteeTs`.

        Standalone-proxy rules: named levels resolve against this proxy's
        ``bounded_staleness_ms``; unset consistency falls back to INFINITE
        staleness (eventual — any watermark satisfies, session_ts still
        honored).  The system facade substitutes its own configured
        default instead."""
        if request.time_travel_ts is not None:
            return GuaranteeTs(
                query_ts=request.time_travel_ts,
                staleness_ms=INFINITE_STALENESS,
            )
        return GuaranteeTs(
            query_ts=self.tso.next(),
            staleness_ms=request.resolve_staleness_ms(
                INFINITE_STALENESS, bounded_ms=self.bounded_staleness_ms
            ),
            session_ts=request.session_ts,
        )

    # ------------------------------------------------------ legacy facades
    def insert(self, info: CollectionInfo, rows: dict[str, np.ndarray]) -> tuple[int, int]:
        """Legacy surface: (lsn, row_count) via the typed pipeline."""
        res = self.mutate(info, InsertRequest(rows))
        return res.watermark_ts, res.row_count

    def delete(self, info: CollectionInfo, pks: np.ndarray) -> int:
        """Legacy surface: bare LSN via the typed pipeline."""
        return self.mutate(info, DeleteRequest(np.asarray(pks))).watermark_ts

    # -------------------------------------------------------------- search
    def search(
        self,
        info: CollectionInfo,
        queries,
        k: int | None = None,
        guarantee: GuaranteeTs | None = None,
        wait_fn=None,
        hedge_timeout_s: float | None = None,
        filter_expr=None,
    ) -> SearchResult:
        """Execute one declarative :class:`SearchRequest` (or the legacy
        positional ``(queries, k)`` form, which is packed into a
        single-field request) with a two-phase reduce over the query nodes
        holding the collection.

        Per sub-request: node-wise top-k partials -> global ``merge_topk``
        reduce with pk-dedup (a segment may surface from two nodes during
        redistribution) — vectorized in the merge_topk kernel.  Hybrid
        requests then fuse the per-field global lists with the request's
        ranker; ``output_fields`` hydrate from node-held segment columns.

        ``wait_fn(node, guarantee) -> None`` implements the consistency
        wait (cooperative runtimes pump the system; threaded runtimes
        block).
        """
        if isinstance(queries, SearchRequest):
            request = queries
        else:
            request = SearchRequest.single(
                np.asarray(queries, np.float32),
                field=info.schema.vector_fields()[0].name,
                k=k if k is not None else 10,
                filter=filter_expr,
            )
        # Never mutate the caller's request object — it may be reused.
        active_filter = request.filter if request.filter is not None else filter_expr
        active_fexpr = self._compile_filter(info.name, active_filter)
        self._verify(info.name)
        request.validate(info.schema)
        if request.partition_names:
            known = self.partitions_of(info.name)
            unknown = sorted(set(request.partition_names) - known)
            if unknown:
                raise ValueError(
                    f"unknown partition(s) {unknown} in collection '{info.name}'"
                )
        self._check_range_bounds(info.metric, request)
        if guarantee is None:
            # Standalone proxy use: honor the request's own consistency
            # fields (the system facade resolves these with its configured
            # default staleness and wait machinery instead).
            guarantee = self.resolve_guarantee(request)
        metric = info.metric
        n_fields = len(request.anns)
        trace_ctx = TraceContext("search") if request.trace else None
        t0 = time.perf_counter()

        def dispatch(
            node: QueryNode, sids: "frozenset[int] | None", hedged: bool = False
        ):
            node_trace = None
            if trace_ctx is not None:
                span = trace_ctx.span(
                    "hedge_dispatch" if hedged else "dispatch",
                    node_id=node.node_id,
                    segment_ids=sorted(sids) if sids is not None else (),
                    detail="" if sids is not None else "full-fanout",
                )
                node_trace = (trace_ctx, span)
            node_req = NodeSearchRequest.from_request(
                info.schema, info.name, request, metric, guarantee,
                filter=active_fexpr,
                segments=tuple(sorted(sids)) if sids is not None else None,
                channels=growing_scopes.get(node.node_id, None),
                trace=node_trace,
                hedged=hedged,
            )
            if node_trace is not None:
                with trace_ctx.timed(node_trace[1]):
                    return node.search_request(node_req)
            return node.search_request(node_req)

        # Replica-aware plan: (node_id, sealed plan units) per dispatch;
        # channel servers join with an empty unit set for growing rows —
        # per channel, the freshest replica whose consumed watermark
        # already covers the guarantee when one exists (zero-wait routing,
        # paper §4.2), else the freshest available (waited).
        chosen, orphans, waits, routed = self._dispatch_plan(info.name, guarantee)
        pending: "list[tuple[str, frozenset[int]]]" = [
            (n, frozenset(s)) for n, s in sorted(chosen.items())
        ]
        # Consistency-wait scope per dispatched node: a sorted channel
        # tuple = wait only on those channels (empty = routed, no wait);
        # None = legacy full wait over every channel the node serves
        # (failover additions below stay conservative with None).
        wait_scopes: "dict[str, tuple | None]" = {
            n: tuple(sorted(waits.get(n, ()))) for n, _ in pending
        }
        # Growing-scan scope per dispatched node: each node serves growing
        # rows only for the channels routed TO IT (() = sealed units only).
        # Without this, a node picked for sealed segments that also
        # subscribes a channel routed to a fresher covering replica would
        # scan its lagging growing copy without a wait — tombstones are
        # per-node, so rows deleted before the wait target would resurface
        # in the merged top-k.  Failover/hedge additions below are absent
        # from the map: scope None = full growing scan, paired with the
        # conservative full wait above.
        growing_scopes: "dict[str, tuple | None]" = {
            n: tuple(sorted(routed.get(n, ()))) for n, _ in pending
        }
        if orphans:
            pending.extend(self._recover_orphans(info.name, orphans))
        # partials[f] collects every node's candidate list for sub-request f
        partials: list[list[tuple[np.ndarray, np.ndarray]]] = [
            [] for _ in range(n_fields)
        ]
        done_ids: set[str] = set()
        covered: set[int] = set()  # sealed units already answered
        hedged_units: set[tuple[str, frozenset]] = set()
        wait_scoped: bool | None = None  # does wait_fn accept a channel scope?
        while pending:
            node_id, sids = pending.pop(0)
            is_hedge = (node_id, sids) in hedged_units
            node = self.query_nodes.get(node_id)
            res = None
            failed = node is None or not node.alive
            if not failed:
                if wait_fn is not None:
                    scope = wait_scopes.get(node_id, None)
                    if scope is None:
                        wait_fn(node, guarantee)
                    elif scope:
                        if wait_scoped is None:
                            wait_scoped = _accepts_channel_scope(wait_fn)
                        if wait_scoped:
                            wait_fn(node, guarantee, scope)
                        else:  # legacy wait_fn: conservative full wait
                            wait_fn(node, guarantee)
                    # empty scope: every channel this node serves is already
                    # covered by a routed pick — zero-wait path, no call
                try:
                    if hedge_timeout_s is not None:
                        res = _run_with_timeout(
                            lambda: dispatch(node, sids, is_hedge),
                            hedge_timeout_s,
                        )
                        if res is None:  # straggler: hedge to other replicas
                            self.metrics.inc("proxy_hedges_total")
                            if trace_ctx is not None:
                                trace_ctx.span(
                                    "hedge", node_id=node_id,
                                    segment_ids=sorted(sids or ()),
                                    detail="timeout",
                                )
                            res, extra = self._hedge(
                                info, node, sids, dispatch,
                                channels=growing_scopes.get(node_id, None),
                            )
                            hedged_units.update(extra)
                            pending.extend(extra)
                    else:
                        res = dispatch(node, sids, is_hedge)
                except StalePlanError:
                    # A compaction swap landed between planning and scan:
                    # the scoped segments were retired and their rewrites
                    # are live.  Re-plan the uncovered remainder from
                    # fresh placement (pk-dedup at merge absorbs overlap
                    # with units already scanned).
                    self.metrics.inc("proxy_stale_replans_total")
                    if trace_ctx is not None:
                        trace_ctx.span(
                            "stale_replan", node_id=node_id,
                            segment_ids=sorted(sids or ()),
                        )
                    pending.extend(
                        self._replan_stale(info.name, covered, pending)
                    )
                    pending.extend(
                        self._channel_dispatches(info.name, done_ids, pending)
                    )
                    continue
                except RuntimeError:
                    failed = True
            if failed:
                # Mid-request failover: the node died between planning and
                # scan.  Report it so the control loop reassigns now, then
                # re-dispatch the failed units to surviving replicas; the
                # dead node's growing rows replay onto the takeover channel
                # owner, which joins the plan below.
                self.metrics.inc("proxy_failovers_total")
                if trace_ctx is not None:
                    trace_ctx.span(
                        "failover_replan", node_id=node_id,
                        segment_ids=sorted(sids or ()),
                        detail="node-dead-mid-request",
                    )
                if node_id in self.query_coord.nodes:
                    self.query_coord.on_node_down(node_id)
                if sids:
                    pending.extend(self._recover_orphans(info.name, sids))
                pending.extend(
                    self._channel_dispatches(info.name, done_ids, pending)
                )
                continue
            done_ids.add(node_id)
            if sids:
                covered.update(sids)
            if res is not None:
                for f in range(n_fields):
                    partials[f].append(res[f])
        waited_ms = (time.perf_counter() - t0) * 1e3
        target_nodes = [qn for qn in self.query_nodes.values() if qn.alive]

        nq = request.nq
        kk = request.k
        metric_str = "l2" if metric is Metric.L2 else "ip"
        fill = np.inf if metric is Metric.L2 else -np.inf
        merge_span = None
        if trace_ctx is not None:
            merge_span = trace_ctx.span("merge_topk", node_id=self.proxy_id)
            merge_t0 = trace_ctx.perf_counter()
        merged: list[tuple[np.ndarray, np.ndarray]] = []
        for f in range(n_fields):
            if not partials[f]:
                merged.append(
                    (
                        np.full((nq, kk), fill, np.float32),
                        np.full((nq, kk), -1, np.int64),
                    )
                )
                continue
            out_f = ops.merge_topk(
                np.concatenate([p[0] for p in partials[f]], axis=1),
                np.concatenate([p[1] for p in partials[f]], axis=1),
                kk,
                metric=metric_str,
            )
            # Range search: one post-scan radius cut on the GLOBAL per-field
            # list, so results are placement-independent ("the in-range
            # subset of the global top-k"); per-field params override the
            # request-level bounds.
            radius = request.anns[f].radius(request.radius)
            range_filter = request.anns[f].range_filter(request.range_filter)
            if radius is not None or range_filter is not None:
                out_f = ops.range_cut(
                    out_f[0], out_f[1], metric_str, radius, range_filter
                )
            merged.append(out_f)
        if request.is_hybrid:
            # Hybrid fusion over the per-field GLOBAL lists (RRF ranks are
            # only meaningful after the global reduce, hence proxy-side).
            out_s, out_p = ops.hybrid_fuse(
                [m[0] for m in merged],
                [m[1] for m in merged],
                kk,
                metrics=[metric.value] * n_fields,
                weights=[a.weight for a in request.anns],
                kind=request.ranker.kind,
                rrf_k=request.ranker.rrf_k,
            )
        else:
            out_s, out_p = merged[0]
        if merge_span is not None:
            merge_span.duration_us = (trace_ctx.perf_counter() - merge_t0) * 1e6
        fields = None
        if request.output_fields:
            hydrate_span = None
            if trace_ctx is not None:
                hydrate_span = trace_ctx.span("fetch_fields", node_id=self.proxy_id)
            if hydrate_span is not None:
                with trace_ctx.timed(hydrate_span):
                    fields = self._hydrate(
                        target_nodes, info, out_p, request.output_fields,
                        guarantee.query_ts, trace=(trace_ctx, hydrate_span),
                    )
            else:
                fields = self._hydrate(
                    target_nodes, info, out_p, request.output_fields,
                    guarantee.query_ts,
                )
        self.metrics.inc("proxy_searches_total")
        self.metrics.observe("proxy_search_latency_us", waited_ms * 1e3)
        trace = trace_ctx.finish(waited_ms * 1e3) if trace_ctx is not None else None
        return SearchResult(
            out_s, out_p, guarantee.query_ts, waited_ms, fields, trace
        )

    # ------------------------------------------------- replica-aware dispatch
    _FAILOVER_ROUNDS = 200  # pump iterations before giving up on a unit

    def _alive(self, node_id: str) -> bool:
        qn = self.query_nodes.get(node_id)
        return qn is not None and qn.alive

    def _node_load(self, node_id: str) -> tuple[int, int]:
        """(primary inflight requests, held replicas): the least-loaded
        key.  Hedged duplicates are deliberately excluded — counting them
        would double-book a straggler's work onto the replica that bailed
        it out and skew subsequent picks away from it."""
        qn = self.query_nodes.get(node_id)
        st = self.query_coord.nodes.get(node_id)
        return (
            qn.inflight_primary if qn is not None else 0,
            len(st.segments) if st is not None else 0,
        )

    def _pick_replica(
        self,
        collection: str,
        sid: int,
        exclude: "set[str] | frozenset[str]" = frozenset(),
        chosen: "dict[str, set[int]] | None" = None,
    ) -> str | None:
        """Least-loaded live replica of one segment that has the copy
        actually loaded (a committed-but-unloaded replica would silently
        scan nothing); ``chosen`` biases toward spreading this request's
        units evenly across its candidate nodes."""
        reps = self.query_coord.replica_sets.get((collection, sid), ())
        cands = [
            n for n in reps
            if n not in exclude
            and self._alive(n)
            and (collection, sid) in self.query_nodes[n].sealed
        ]
        if not cands:
            return None
        chosen = chosen or {}
        return min(
            cands,
            key=lambda n: (len(chosen.get(n, ())), *self._node_load(n), n),
        )

    def _channel_watermark(self, node_id: str, channel: str) -> int:
        """The node's consumed watermark on one DML channel (-1 = not
        actually subscribed yet — the coordinator committed the assignment
        but the subscribe message hasn't been applied)."""
        qn = self.query_nodes.get(node_id)
        if qn is None:
            return -1
        sub = qn.subscriptions.get(channel)
        return sub.last_tick_seen if sub is not None else -1

    def _dispatch_plan(
        self, collection: str, guarantee: GuaranteeTs | None = None
    ) -> (
        "tuple[dict[str, set[int]], list[int], dict[str, set[str]],"
        " dict[str, set[str]]]"
    ):
        """Build the replica-aware dispatch plan: per DML channel one
        serving replica for growing rows, plus per live sealed segment one
        replica chosen by load.  Segments with no dispatchable replica
        right now are returned as orphans for the failover path.

        Watermark-aware routing (paper §4.2 delta consistency): with a
        ``guarantee``, each channel prefers the *freshest candidate whose
        consumed watermark already covers* ``guarantee.wait_target_ts()``
        — that read waits 0 ms.  When nobody covers yet (e.g. STRONG: the
        query_ts postdates every tick by construction), the freshest
        candidate minimizes the wait, and the returned ``waits`` map marks
        the channel so the dispatch loop runs the consistency wait scoped
        to exactly the channels that still need it.

        The returned ``routed`` map records which channels each node serves
        growing rows for; the dispatch scopes every node's growing scan to
        its routed channels (a node picked only for sealed units, or whose
        channel went to a fresher covering replica, must not serve its own
        lagging growing copy — per-node tombstones would resurrect rows
        deleted before the wait target)."""
        coord = self.query_coord
        chosen: dict[str, set[int]] = {}
        waits: dict[str, set[str]] = {}
        routed: dict[str, set[str]] = {}
        prefix = f"dml/{collection}/"
        followers = getattr(coord, "channel_followers", {})
        cands_by_ch: dict[str, list[str]] = {}
        for n, st in coord.nodes.items():
            if not self._alive(n):
                continue
            for ch in st.channels:
                if ch.startswith(prefix):
                    cands_by_ch.setdefault(ch, []).append(n)
        for ch, fset in followers.items():
            if ch.startswith(prefix):
                for n in fset:
                    if self._alive(n) and n not in cands_by_ch.get(ch, ()):
                        cands_by_ch.setdefault(ch, []).append(n)
        for ch, cands in sorted(cands_by_ch.items()):
            covering = [] if guarantee is None else [
                n for n in cands
                if guarantee.satisfied_by(self._channel_watermark(n, ch))
            ]
            if covering:
                # Freshest covering candidate (owner or standby follower):
                # the delta-consistency zero-wait path.
                pick = min(
                    covering,
                    key=lambda n: (
                        -self._channel_watermark(n, ch),
                        *self._node_load(n),
                        n,
                    ),
                )
                chosen.setdefault(pick, set())
                routed.setdefault(pick, set()).add(ch)
                self.metrics.inc(
                    "consistency_routes_total", labels={"outcome": "covered"}
                )
                continue
            # Nobody covers (STRONG reads never can at plan time — their
            # query_ts postdates every consumed tick): legacy behavior,
            # the committed owner serves and runs the consistency wait.
            owners = [n for n in cands if ch in coord.nodes[n].channels]
            pick = min(
                owners or cands, key=lambda n: (*self._node_load(n), n)
            )
            chosen.setdefault(pick, set())
            routed.setdefault(pick, set()).add(ch)
            waits.setdefault(pick, set()).add(ch)
            if guarantee is not None:
                self.metrics.inc(
                    "consistency_routes_total", labels={"outcome": "waited"}
                )
        orphans: list[int] = []
        for sid in sorted(coord.placement_for(collection)):
            pick = self._pick_replica(collection, sid, chosen=chosen)
            if pick is None:
                orphans.append(sid)
            else:
                chosen.setdefault(pick, set()).add(sid)
        return chosen, orphans, waits, routed

    def _pump(self) -> None:
        """Advance coordination-message delivery while waiting on a
        placement change (failover reassignment, slow segment load)."""
        if self.pump_fn is not None:
            self.pump_fn()
        else:
            for qn in list(self.query_nodes.values()):
                if qn.alive:
                    qn.step()

    def _recover_orphans(
        self, collection: str, sids
    ) -> "list[tuple[str, frozenset[int]]]":
        """Re-plan segments that currently have no dispatchable replica:
        report observed-dead holders to the control loop, then reconcile
        and pump until a surviving replica has each copy loaded."""
        coord = self.query_coord
        missing = set(sids)
        for sid in sorted(missing):
            for n in list(coord.replica_sets.get((collection, sid), ())):
                if not self._alive(n) and n in coord.nodes:
                    coord.on_node_down(n)
        out: dict[str, set[int]] = {}
        for _ in range(self._FAILOVER_ROUNDS):
            for sid in sorted(missing):
                pick = self._pick_replica(collection, sid, chosen=out)
                if pick is not None:
                    out.setdefault(pick, set()).add(sid)
            missing -= {s for units in out.values() for s in units}
            if not missing:
                break
            coord.reconciler.reconcile()
            self._pump()
        if missing:
            raise RuntimeError(
                f"no live replica for segments {sorted(missing)} "
                f"of '{collection}'"
            )
        return [(n, frozenset(s)) for n, s in sorted(out.items())]

    def _replan_stale(
        self, collection: str, covered: set[int], pending
    ) -> "list[tuple[str, frozenset[int]]]":
        """After a stale-plan signal: dispatch every currently-live sealed
        segment that is neither answered nor still pending (the rewrites a
        compaction swapped in mid-request)."""
        pending_sids = {s for _n, ss in pending for s in (ss or ())}
        out: dict[str, set[int]] = {}
        orphans: list[int] = []
        for sid in sorted(self.query_coord.placement_for(collection)):
            if sid in covered or sid in pending_sids:
                continue
            pick = self._pick_replica(collection, sid, chosen=out)
            if pick is None:
                orphans.append(sid)
            else:
                out.setdefault(pick, set()).add(sid)
        units = [(n, frozenset(s)) for n, s in sorted(out.items())]
        if orphans:
            units.extend(self._recover_orphans(collection, orphans))
        return units

    def _channel_dispatches(
        self, collection: str, done_ids: set[str], pending
    ) -> "list[tuple[str, frozenset[int]]]":
        """Channel owners not yet part of the plan (a failover re-homed the
        dead node's DML channels) join with an empty sealed-unit set so
        their replayed growing rows are scanned."""
        pending_ids = {n for n, _ in pending}
        out = []
        for n, st in self.query_coord.nodes.items():
            if not self._alive(n) or n in done_ids or n in pending_ids:
                continue
            if any(ch.startswith(f"dml/{collection}/") for ch in st.channels):
                out.append((n, frozenset()))
        return out

    def _hedge(
        self, info: CollectionInfo, node: QueryNode, sids, dispatch,
        channels=None,
    ):
        """Straggler mitigation: re-dispatch each timed-out sealed unit to
        a *different* live replica of the same segment.  Units with no
        alternative copy — and the straggler's growing rows, which exist
        nowhere else — fall back to a blocking dispatch on the original
        node (scoped to just those, so the hedged work is not repeated).
        ``channels`` is the straggler's growing-scan scope: only growing
        rows it would actually have served count toward the fallback."""
        extra: dict[str, set[int]] = {}
        uncovered: set[int] = set()
        for sid in sids or ():
            alt = self._pick_replica(
                info.name, sid, exclude={node.node_id}, chosen=extra
            )
            if alt is None:
                uncovered.add(sid)
            else:
                extra.setdefault(alt, set()).add(sid)
        shard_scope = (
            None if channels is None
            else {shard_of_channel(c) for c in channels}
        )
        has_growing = any(
            c == info.name and gs.segment.num_rows
            and (shard_scope is None or gs.segment.shard in shard_scope)
            for (c, _sid), gs in node.growing.items()
        )
        res = None
        if uncovered or has_growing:
            res = dispatch(node, frozenset(uncovered))
        return res, [(n, frozenset(s)) for n, s in sorted(extra.items())]

    @staticmethod
    def _check_range_bounds(metric: Metric, request: SearchRequest) -> None:
        """Reject always-empty range windows early (the bounds follow the
        Milvus convention: L2 keeps ``range_filter <= d < radius``,
        IP/cosine keeps ``radius < s <= range_filter``)."""
        for a in request.anns:
            radius = a.radius(request.radius)
            range_filter = a.range_filter(request.range_filter)
            if radius is None or range_filter is None:
                continue
            if metric is Metric.L2 and range_filter >= radius:
                raise ValueError(
                    f"L2 range window is empty: requires range_filter < radius, "
                    f"got range_filter={range_filter} >= radius={radius}"
                )
            if metric is not Metric.L2 and radius >= range_filter:
                raise ValueError(
                    f"{metric.value} range window is empty: requires "
                    f"radius < range_filter, got radius={radius} >= "
                    f"range_filter={range_filter}"
                )

    # ----------------------------------------------------------- hydration
    def _hydrate(
        self,
        target_nodes: "list[QueryNode]",
        info: CollectionInfo,
        pks: np.ndarray,
        output_fields: "tuple[str, ...]",
        ts: int,
        trace: tuple | None = None,
    ) -> dict[str, np.ndarray]:
        """Gather ``output_fields`` columns for the result pks from the
        nodes' segment copies (binlog columns / growing rows)."""
        col_of = {
            f: ("pk" if f == "pk" else vector_column_of(info.schema, f)
                if info.schema.field(f).dtype is FieldType.VECTOR else f)
            for f in output_fields
        }
        columns = sorted(set(col_of.values()))
        found: dict[str, list[tuple[np.ndarray, np.ndarray]]] = {
            c: [] for c in columns
        }
        for node in target_nodes:
            if not node.alive:
                continue
            try:
                if trace is not None:
                    ctx, parent = trace
                    nspan = ctx.span(
                        "fetch_fields_node", parent=parent, node_id=node.node_id,
                        detail=",".join(columns),
                    )
                    with ctx.timed(nspan):
                        got = node.fetch_fields(info.name, pks, columns, ts)
                else:
                    got = node.fetch_fields(info.name, pks, columns, ts)
            except RuntimeError:
                continue
            for c, (fpks, vals) in got.items():
                if len(fpks):
                    found[c].append((fpks, vals))
        out: dict[str, np.ndarray] = {}
        flat = np.where(pks >= 0, pks, 0)
        live = pks >= 0
        for f in output_fields:
            c = col_of[f]
            if found[c]:
                fp = np.concatenate([x[0] for x in found[c]])
                fv = np.concatenate([x[1] for x in found[c]])
                order = np.argsort(fp, kind="stable")
                fp, fv = fp[order], fv[order]
                idx = np.minimum(np.searchsorted(fp, flat), len(fp) - 1)
                hit = live & (fp[idx] == flat)
                vals = fv[idx]
            else:
                hit = np.zeros_like(live)
                if f != "pk" and info.schema.field(f).dtype is FieldType.VECTOR:
                    # keep the documented [nq, k, dim] shape even when no
                    # candidate hydrated (empty result / range cut all)
                    dim = info.schema.field(f).dim
                    vals = np.zeros(pks.shape + (dim,), np.float32)
                else:
                    vals = np.zeros(pks.shape, np.float32)
            out[f] = _mask_fill(vals, hit)
        return out

    _FILTER_CACHE_CAP = 256

    def _compile_filter(self, collection: str, filter_expr):
        """Compile an attribute filter once per (collection, expr string).

        The LRU holds the parsed+validated :class:`FilterExpr`; repeated
        requests with the same filter skip the ``ast.parse`` entirely.
        Already-compiled expressions pass through untouched."""
        if filter_expr is None:
            return None
        from ..index.attribute import FilterExpr

        if isinstance(filter_expr, FilterExpr):
            return filter_expr
        key = (collection, str(filter_expr))
        cached = self._filter_cache.get(key)
        if cached is not None:
            self._filter_cache.move_to_end(key)
            self.metrics.inc("filter_parse_cache_hit_total")
            return cached
        expr = FilterExpr(str(filter_expr))
        self.metrics.inc("filter_parse_cache_miss_total")
        self._filter_cache[key] = expr
        while len(self._filter_cache) > self._FILTER_CACHE_CAP:
            self._filter_cache.popitem(last=False)
        return expr


def _mask_fill(vals: np.ndarray, hit: np.ndarray) -> np.ndarray:
    """Fill non-hydrated slots with a dtype-appropriate empty value
    (NaN for floats, 0/False for ints and bools, "" for strings)."""
    vals = np.asarray(vals)
    if hit.all():
        return vals
    if vals.ndim > hit.ndim:  # vector columns: [nq, k, dim]
        hit = hit[..., None]
    if np.issubdtype(vals.dtype, np.floating):
        return np.where(hit, vals, np.nan)
    if vals.dtype.kind in ("U", "S", "O"):
        return np.where(hit, vals, np.asarray("", vals.dtype))
    return np.where(hit, vals, np.zeros((), vals.dtype))


def _accepts_channel_scope(wait_fn) -> bool:
    """Can ``wait_fn`` take the optional third ``channels`` argument?
    Checked once per search so scoped waits degrade gracefully for legacy
    two-argument wait callables."""
    import inspect

    try:
        sig = inspect.signature(wait_fn)
    except (TypeError, ValueError):  # builtins / C callables: assume legacy
        return False
    params = list(sig.parameters.values())
    if any(p.kind is inspect.Parameter.VAR_POSITIONAL for p in params):
        return True
    positional = [
        p for p in params
        if p.kind in (
            inspect.Parameter.POSITIONAL_ONLY,
            inspect.Parameter.POSITIONAL_OR_KEYWORD,
        )
    ]
    return len(positional) >= 3


def _run_with_timeout(fn, timeout_s: float):
    """Run fn in a worker thread; None on timeout (hedged-request helper)."""
    result: list = []

    def target():
        result.append(fn())

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(timeout_s)
    return result[0] if result else None


# BatchingProxy is now the scheduler's read micro-batching facade; the
# import lives at the bottom because scheduler.py imports SearchResult.
from .scheduler import BatchingProxy, RequestScheduler  # noqa: E402,F401
