"""Hybrid logical clock (HLC) timestamps and the central TSO.

Manu assigns every state-changing request a logical sequence number (LSN)
drawn from a central time service oracle (TSO).  Each timestamp is a hybrid
logical clock value: a physical component tracking wall time (milliseconds)
and a logical component disambiguating events within one physical tick.

The packed representation is a single int64:

    ts = (physical_ms << LOGICAL_BITS) | logical

which is totally ordered, cheap to compare, and directly usable as an MVCC
version.  ``physical_of(ts)`` recovers wall-clock milliseconds so users can
express staleness tolerances (the paper's "grace time" tau) in physical
units.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

LOGICAL_BITS = 18
LOGICAL_MASK = (1 << LOGICAL_BITS) - 1
MAX_LOGICAL = LOGICAL_MASK

#: Sentinel: "no staleness bound" (eventual consistency).
INFINITE_STALENESS = float("inf")


def pack(physical_ms: int, logical: int) -> int:
    if logical > MAX_LOGICAL:
        raise ValueError(f"logical component {logical} overflows {LOGICAL_BITS} bits")
    return (int(physical_ms) << LOGICAL_BITS) | int(logical)


def physical_of(ts: int) -> int:
    """Wall-clock milliseconds encoded in ``ts``."""
    return ts >> LOGICAL_BITS


def logical_of(ts: int) -> int:
    return ts & LOGICAL_MASK


def delta_ms(ts_a: int, ts_b: int) -> float:
    """Physical-time difference ``ts_a - ts_b`` in milliseconds."""
    return float(physical_of(ts_a) - physical_of(ts_b))


def add_ms(ts: int, ms: float) -> int:
    """Timestamp ``ms`` milliseconds after ``ts`` (logical reset to 0)."""
    return pack(physical_of(ts) + int(ms), 0)


@dataclass(frozen=True)
class Timestamp:
    """Unpacked view of an HLC timestamp (for debugging / display)."""

    physical_ms: int
    logical: int

    @classmethod
    def unpack(cls, ts: int) -> "Timestamp":
        return cls(physical_of(ts), logical_of(ts))

    def packed(self) -> int:
        return pack(self.physical_ms, self.logical)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"HLC({self.physical_ms}ms+{self.logical})"


class Clock:
    """Wall clock abstraction; swap in ``ManualClock`` for deterministic tests."""

    def now_ms(self) -> int:
        return time.time_ns() // 1_000_000


class ManualClock(Clock):
    """A clock advanced explicitly — used by tests and simulations."""

    def __init__(self, start_ms: int = 0):
        self._now = int(start_ms)
        self._lock = threading.Lock()

    def now_ms(self) -> int:
        with self._lock:
            return self._now

    def advance(self, ms: int) -> int:
        with self._lock:
            self._now += int(ms)
            return self._now

    def set(self, ms: int) -> None:
        with self._lock:
            if ms < self._now:
                raise ValueError("manual clock cannot move backwards")
            self._now = int(ms)


class TSO:
    """Central timestamp oracle.

    Issues strictly increasing HLC timestamps.  Physical component never runs
    behind the wall clock; the logical component increments when multiple
    timestamps are issued within one millisecond.  This is the single
    source of event ordering for the whole system (paper §3.4).
    """

    def __init__(self, clock: Clock | None = None):
        self.clock = clock or Clock()
        self._last_physical = 0
        self._last_logical = 0
        self._lock = threading.Lock()

    def next(self) -> int:
        with self._lock:
            now = self.clock.now_ms()
            if now > self._last_physical:
                self._last_physical = now
                self._last_logical = 0
            else:
                self._last_logical += 1
                if self._last_logical > MAX_LOGICAL:
                    # Logical overflow within one ms: push physical forward.
                    self._last_physical += 1
                    self._last_logical = 0
            return pack(self._last_physical, self._last_logical)

    def next_batch(self, n: int) -> list[int]:
        return [self.next() for _ in range(n)]

    def advance_to(self, ts: int) -> None:
        """Floor the oracle at ``ts``: every subsequently issued timestamp is
        strictly greater.  Crash recovery seeds a fresh TSO from the largest
        timestamp found in the durable log so ordering survives a restart
        even under a frozen manual clock."""
        with self._lock:
            p, l = physical_of(ts), logical_of(ts)
            if (p, l) > (self._last_physical, self._last_logical):
                self._last_physical = p
                self._last_logical = l

    def last_issued(self) -> int:
        with self._lock:
            return pack(self._last_physical, self._last_logical)
