"""Declarative search requests (paper §3.1 Table 2, §6.4).

The read path is driven by one typed object instead of a kwarg chain:
a :class:`SearchRequest` carries the top-k budget, the consistency
requirement (a named level OR an explicit staleness / session
timestamp), an attribute filter, an optional radius cut (range search),
the output fields to hydrate, and one-or-more :class:`AnnsQuery`
sub-requests — one per vector field.  Multi-vector (hybrid) requests
fuse the per-field results with a :class:`Ranker` (weighted-sum over
normalized similarities, or reciprocal-rank fusion).

The proxy translates schema field names into segment *column* names
(the first vector field is stored as the primary ``"vector"`` column,
additional vector fields ride the extras columns under their own
names) and ships a :class:`NodeSearchRequest` to every query node —
the single object that replaces the old seven-positional-kwarg chain.
"""

from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np

from .collection import FieldType, Metric, Schema
from .consistency import ConsistencyLevel, GuaranteeTs, staleness_ms_of

#: Segment column name of the first (primary) vector field.
PRIMARY_VECTOR_COLUMN = "vector"


def vector_column_of(schema: Schema, field: str) -> str:
    """Map a schema vector-field name to its segment column name."""
    return PRIMARY_VECTOR_COLUMN if field == schema.vector_fields()[0].name else field


@dataclass
class AnnsQuery:
    """One per-vector-field sub-request of a (possibly hybrid) search.

    ``weight`` scales this field's contribution during fusion.  ``params``
    may override request-level knobs per field (``radius`` /
    ``range_filter``).
    """

    field: str
    queries: np.ndarray  # [nq, dim] float32
    weight: float = 1.0
    params: dict = dc_field(default_factory=dict)

    def __post_init__(self):
        q = np.asarray(self.queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2:
            raise ValueError(f"queries must be [nq, dim], got shape {q.shape}")
        self.queries = q

    def radius(self, default: float | None) -> float | None:
        return self.params.get("radius", default)

    def range_filter(self, default: float | None) -> float | None:
        return self.params.get("range_filter", default)


@dataclass(frozen=True)
class Ranker:
    """Hybrid fusion strategy for multi-vector requests.

    * ``weighted`` — fused score is the weight-scaled sum of per-field
      similarities normalized into (0, 1]: L2 ``1/(1+d)``, cosine
      ``(1+s)/2``, IP ``1/(1+exp(-s))``.  Candidates absent from a
      field's list contribute nothing for that field.
    * ``rrf`` — reciprocal-rank fusion: ``sum_f w_f / (rrf_k + rank_f)``
      with 1-based ranks within each field's result list.
    """

    kind: str = "weighted"  # "weighted" | "rrf"
    rrf_k: float = 60.0

    def __post_init__(self):
        if self.kind not in ("weighted", "rrf"):
            raise ValueError(f"unknown ranker kind '{self.kind}'")

    @staticmethod
    def weighted() -> "Ranker":
        return Ranker("weighted")

    @staticmethod
    def rrf(k: float = 60.0) -> "Ranker":
        return Ranker("rrf", rrf_k=k)


@dataclass
class SearchRequest:
    """The full declarative read request (client -> proxy)."""

    anns: list[AnnsQuery]
    k: int = 10
    consistency: ConsistencyLevel | None = None
    staleness_ms: float | None = None  # explicit tau overrides ``consistency``
    session_ts: int = 0  # read-your-writes watermark (session consistency)
    filter: object | None = None  # str | FilterExpr over attribute fields
    radius: float | None = None  # range search outer bound
    range_filter: float | None = None  # range search inner bound
    output_fields: tuple[str, ...] = ()
    time_travel_ts: int | None = None
    ranker: Ranker = dc_field(default_factory=Ranker)

    def __post_init__(self):
        if isinstance(self.anns, AnnsQuery):
            self.anns = [self.anns]
        self.anns = list(self.anns)
        if not self.anns:
            raise ValueError("SearchRequest needs at least one AnnsQuery")
        self.output_fields = tuple(self.output_fields)
        nqs = {len(a.queries) for a in self.anns}
        if len(nqs) != 1:
            raise ValueError(f"sub-requests disagree on query count: {sorted(nqs)}")
        if self.k < 1:
            raise ValueError("k must be >= 1")

    # ------------------------------------------------------------- helpers
    @classmethod
    def single(cls, queries: np.ndarray, field: str = "vector", **kw) -> "SearchRequest":
        """The common one-vector-field case."""
        return cls(anns=[AnnsQuery(field, queries)], **kw)

    @property
    def nq(self) -> int:
        return len(self.anns[0].queries)

    @property
    def is_hybrid(self) -> bool:
        return len(self.anns) > 1

    def resolve_staleness_ms(self, default_ms: float) -> float:
        """Explicit tau > named level > system default."""
        if self.staleness_ms is not None:
            return self.staleness_ms
        if self.consistency is not None:
            return staleness_ms_of(self.consistency)
        return default_ms

    def validate(self, schema: Schema) -> None:
        """Early rejection against cached metadata (paper §3.2)."""
        for a in self.anns:
            fs = schema.field(a.field)  # KeyError for unknown fields
            if fs.dtype is not FieldType.VECTOR:
                raise ValueError(
                    f"anns field '{a.field}' is {fs.dtype.value}, not a vector field"
                )
            if a.queries.shape[1] != fs.dim:
                raise ValueError(
                    f"anns field '{a.field}' expects dim {fs.dim}, "
                    f"got {a.queries.shape[1]}"
                )
        seen = set()
        for a in self.anns:
            if a.field in seen:
                raise ValueError(f"duplicate anns field '{a.field}'")
            seen.add(a.field)
        for f in self.output_fields:
            if f != "pk":
                schema.field(f)
        # radius/range_filter ordering depends on the collection metric;
        # the proxy rejects empty windows in ``_check_range_bounds``.


@dataclass
class NodeSearchRequest:
    """What travels proxy -> query node: field names already resolved to
    segment column names, consistency resolved to a pinned guarantee.

    Deliberately WITHOUT the radius bounds: the range cut runs once at the
    proxy on the globally merged per-field list (a node-local cut would
    make results depend on segment placement under an inner bound)."""

    collection: str
    k: int
    metric: Metric
    guarantee: GuaranteeTs
    anns: list[AnnsQuery]  # .field holds the segment COLUMN name here
    filter_masks: dict[int, np.ndarray] | None = None

    @classmethod
    def from_request(
        cls,
        schema: Schema,
        collection: str,
        request: SearchRequest,
        metric: Metric,
        guarantee: GuaranteeTs,
        filter_masks: dict[int, np.ndarray] | None = None,
    ) -> "NodeSearchRequest":
        anns = [
            AnnsQuery(
                vector_column_of(schema, a.field), a.queries, a.weight, dict(a.params)
            )
            for a in request.anns
        ]
        return cls(
            collection=collection,
            k=request.k,
            metric=metric,
            guarantee=guarantee,
            anns=anns,
            filter_masks=filter_masks,
        )
