"""Declarative search and mutation requests (paper §3.1 Table 2, §6.4).

The read path is driven by one typed object instead of a kwarg chain:
a :class:`SearchRequest` carries the top-k budget, the consistency
requirement (a named level OR an explicit staleness / session
timestamp), an attribute filter, an optional radius cut (range search),
the output fields to hydrate, and one-or-more :class:`AnnsQuery`
sub-requests — one per vector field.  Multi-vector (hybrid) requests
fuse the per-field results with a :class:`Ranker` (weighted-sum over
normalized similarities, or reciprocal-rank fusion).

The proxy translates schema field names into segment *column* names
(the first vector field is stored as the primary ``"vector"`` column,
additional vector fields ride the extras columns under their own
names) and ships a :class:`NodeSearchRequest` to every query node —
the single object that replaces the old seven-positional-kwarg chain.

The *write* path mirrors the same design (paper §4.2): one typed
:class:`InsertRequest` / :class:`DeleteRequest` / :class:`UpsertRequest`
flows client → proxy → logger → WAL, and every mutation answers with a
:class:`MutationResult` whose ``watermark_ts`` plugs directly into a
SESSION-consistency read (``SearchRequest(session_ts=...)``) — the
delta-consistency handshake between writes and reads.  Upserts travel as
a single WAL record carrying both the delete-by-pk and the insert half,
so old/new row visibility flips atomically at one LSN.
"""

from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np

from .collection import FieldType, Metric, Schema
from .consistency import ConsistencyLevel, GuaranteeTs, staleness_ms_of
from .segment import DEFAULT_PARTITION

#: Segment column name of the first (primary) vector field.
PRIMARY_VECTOR_COLUMN = "vector"


def vector_column_of(schema: Schema, field: str | None) -> str:
    """Map a schema vector-field name to its segment column name.
    ``None`` means "the primary vector field" (resolved per collection —
    see :class:`AnnsQuery`)."""
    if field is None or field == schema.vector_fields()[0].name:
        return PRIMARY_VECTOR_COLUMN
    return field


# ---------------------------------------------------------------------------
# Typed mutations (the write-path twin of SearchRequest)
# ---------------------------------------------------------------------------


@dataclass
class MutationResult:
    """What every mutation hands back instead of a bare LSN.

    ``watermark_ts`` is the request's LSN — feed it to a SESSION
    :class:`SearchRequest` (``session_ts=watermark_ts``) for
    read-your-writes.  ``shard_lsns`` lists the WAL channels the request
    actually touched (the paper assigns ONE LSN per request — row-level
    ACID — so every touched shard shares it).  ``pks`` are the primary
    keys assigned (insert/upsert) or accepted for deletion; ``ack_rows``
    counts rows acknowledged into the WAL (0 for a no-op delete).
    """

    op: str  # "insert" | "delete" | "upsert"
    pks: np.ndarray
    shard_lsns: dict[int, int]
    watermark_ts: int
    row_count: int
    ack_rows: int
    # Span tree for this mutation when requested via ``trace=True`` on
    # the mutate call (see core.telemetry.RequestTrace); None otherwise.
    trace: object | None = None

    def session_request(
        self, queries, field: str | None = None, **kw
    ) -> "SearchRequest":
        """A read-your-writes follow-up read pinned at this watermark.
        ``field=None`` targets the collection's primary vector field,
        resolved against the schema when the request executes."""
        kw.setdefault("consistency", ConsistencyLevel.SESSION)
        return SearchRequest.single(
            queries, field=field, session_ts=self.watermark_ts, **kw
        )


@dataclass
class MutationRequest:
    """Base of the typed write surface; subclasses set ``op``."""

    op = "mutation"

    def validate(self, schema: Schema) -> None:  # pragma: no cover - interface
        """Early rejection against cached metadata (paper §3.2)."""


@dataclass
class InsertRequest(MutationRequest):
    """One insert batch, optionally placed into a named partition."""

    rows: dict[str, np.ndarray]
    partition: str = DEFAULT_PARTITION
    trace: bool = False  # attach a RequestTrace to the MutationResult
    op = "insert"

    def validate(self, schema: Schema) -> None:
        from .collection import validate_rows

        validate_rows(schema, self.rows)


@dataclass
class DeleteRequest(MutationRequest):
    """Delete by primary key (global: pks are partition-independent)."""

    pks: np.ndarray
    trace: bool = False  # attach a RequestTrace to the MutationResult
    op = "delete"

    def __post_init__(self):
        self.pks = np.atleast_1d(np.asarray(self.pks))

    def validate(self, schema: Schema) -> None:
        if self.pks.ndim != 1:
            raise ValueError(f"delete pks must be 1-D, got shape {self.pks.shape}")


@dataclass
class UpsertRequest(MutationRequest):
    """Insert-or-replace by primary key.

    Travels the WAL as ONE record per shard carrying the delete-by-pk
    half and the insert half, so MVCC visibility of the old and new row
    versions flips atomically at the record's single LSN.  Batches
    without an explicit pk column degrade to plain inserts (fresh
    auto-IDs cannot collide, so there is nothing to replace).
    """

    rows: dict[str, np.ndarray]
    partition: str = DEFAULT_PARTITION
    trace: bool = False  # attach a RequestTrace to the MutationResult
    op = "upsert"

    def validate(self, schema: Schema) -> None:
        from .collection import validate_rows

        validate_rows(schema, self.rows)


@dataclass
class AnnsQuery:
    """One per-vector-field sub-request of a (possibly hybrid) search.

    ``field=None`` means "the collection's primary vector field" and is
    resolved against the schema at validation/dispatch time (requests
    built without a schema in hand — e.g. ``MutationResult.
    session_request`` — stay collection-agnostic).  ``weight`` scales
    this field's contribution during fusion.  ``params`` may override
    request-level knobs per field (``radius`` / ``range_filter``).
    """

    field: str | None
    queries: np.ndarray  # [nq, dim] float32
    weight: float = 1.0
    params: dict = dc_field(default_factory=dict)

    def __post_init__(self):
        q = np.asarray(self.queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2:
            raise ValueError(f"queries must be [nq, dim], got shape {q.shape}")
        self.queries = q

    def radius(self, default: float | None) -> float | None:
        return self.params.get("radius", default)

    def range_filter(self, default: float | None) -> float | None:
        return self.params.get("range_filter", default)


@dataclass(frozen=True)
class Ranker:
    """Hybrid fusion strategy for multi-vector requests.

    * ``weighted`` — fused score is the weight-scaled sum of per-field
      similarities normalized into (0, 1]: L2 ``1/(1+d)``, cosine
      ``(1+s)/2``, IP ``1/(1+exp(-s))``.  Candidates absent from a
      field's list contribute nothing for that field.
    * ``rrf`` — reciprocal-rank fusion: ``sum_f w_f / (rrf_k + rank_f)``
      with 1-based ranks within each field's result list.
    """

    kind: str = "weighted"  # "weighted" | "rrf"
    rrf_k: float = 60.0

    def __post_init__(self):
        if self.kind not in ("weighted", "rrf"):
            raise ValueError(f"unknown ranker kind '{self.kind}'")

    @staticmethod
    def weighted() -> "Ranker":
        return Ranker("weighted")

    @staticmethod
    def rrf(k: float = 60.0) -> "Ranker":
        return Ranker("rrf", rrf_k=k)


@dataclass
class SearchRequest:
    """The full declarative read request (client -> proxy)."""

    anns: list[AnnsQuery]
    k: int = 10
    consistency: ConsistencyLevel | None = None
    staleness_ms: float | None = None  # explicit tau overrides ``consistency``
    session_ts: int = 0  # read-your-writes watermark (session consistency)
    filter: object | None = None  # str | FilterExpr over attribute fields
    # Filtered-search strategy override: None = selectivity-adaptive
    # planning (the default); "pre" | "post" | "brute" force one strategy
    # for every (segment, filter) unit — the benchmark / equivalence-test
    # surface, not something clients normally set.
    filter_strategy: str | None = None
    radius: float | None = None  # range search outer bound
    range_filter: float | None = None  # range search inner bound
    output_fields: tuple[str, ...] = ()
    # Partition pruning: restrict the scan to these partitions (empty =
    # every partition).  The query-node planner skips non-matching
    # segments before any distance work happens.
    partition_names: tuple[str, ...] = ()
    time_travel_ts: int | None = None
    ranker: Ranker = dc_field(default_factory=Ranker)
    # Per-request tracing: when True the proxy allocates a TraceContext
    # and attaches the finished span tree as ``SearchResult.trace``.
    # Off by default — the disabled cost is one branch per call site.
    trace: bool = False

    def __post_init__(self):
        if isinstance(self.anns, AnnsQuery):
            self.anns = [self.anns]
        self.anns = list(self.anns)
        if not self.anns:
            raise ValueError("SearchRequest needs at least one AnnsQuery")
        self.output_fields = tuple(self.output_fields)
        if isinstance(self.partition_names, str):
            self.partition_names = (self.partition_names,)
        self.partition_names = tuple(self.partition_names)
        nqs = {len(a.queries) for a in self.anns}
        if len(nqs) != 1:
            raise ValueError(f"sub-requests disagree on query count: {sorted(nqs)}")
        if self.k < 1:
            raise ValueError("k must be >= 1")
        if self.filter_strategy not in (None, "pre", "post", "brute"):
            raise ValueError(
                f"unknown filter_strategy '{self.filter_strategy}' "
                "(expected None, 'pre', 'post' or 'brute')"
            )

    # ------------------------------------------------------------- helpers
    @classmethod
    def single(
        cls, queries: np.ndarray, field: str | None = "vector", **kw
    ) -> "SearchRequest":
        """The common one-vector-field case (None = primary vector field)."""
        return cls(anns=[AnnsQuery(field, queries)], **kw)

    @property
    def nq(self) -> int:
        return len(self.anns[0].queries)

    @property
    def is_hybrid(self) -> bool:
        return len(self.anns) > 1

    def resolve_staleness_ms(
        self, default_ms: float, bounded_ms: float = 2_000.0
    ) -> float:
        """Explicit tau > named level > system default.  ``bounded_ms`` is
        the deployment's BOUNDED staleness window (``ManuConfig.
        bounded_staleness_ms``), threaded through so the named level is a
        tunable, not a constant."""
        if self.staleness_ms is not None:
            return self.staleness_ms
        if self.consistency is not None:
            return staleness_ms_of(self.consistency, bounded_ms)
        return default_ms

    def validate(self, schema: Schema) -> None:
        """Early rejection against cached metadata (paper §3.2)."""
        primary_vec = schema.vector_fields()[0].name
        for a in self.anns:
            if a.field is None:
                fs = schema.vector_fields()[0]
            else:
                fs = schema.field(a.field)  # KeyError for unknown fields
            if fs.dtype is not FieldType.VECTOR:
                raise ValueError(
                    f"anns field '{a.field}' is {fs.dtype.value}, not a vector field"
                )
            if a.queries.shape[1] != fs.dim:
                raise ValueError(
                    f"anns field '{a.field}' expects dim {fs.dim}, "
                    f"got {a.queries.shape[1]}"
                )
        seen = set()
        for a in self.anns:
            name = a.field if a.field is not None else primary_vec
            if name in seen:
                raise ValueError(f"duplicate anns field '{name}'")
            seen.add(name)
        for f in self.output_fields:
            if f != "pk":
                schema.field(f)
        # radius/range_filter ordering depends on the collection metric;
        # the proxy rejects empty windows in ``_check_range_bounds``.


@dataclass
class NodeSearchRequest:
    """What travels proxy -> query node: field names already resolved to
    segment column names, consistency resolved to a pinned guarantee.

    Deliberately WITHOUT the radius bounds: the range cut runs once at the
    proxy on the globally merged per-field list (a node-local cut would
    make results depend on segment placement under an inner bound)."""

    collection: str
    k: int
    metric: Metric
    guarantee: GuaranteeTs
    anns: list[AnnsQuery]  # .field holds the segment COLUMN name here
    # The compiled filter expression (FilterExpr), shipped once per request;
    # query nodes resolve it locally — sealed segments through their
    # attribute-index satellites, growing rows by row-wise evaluation.
    filter: object | None = None
    # Strategy override from SearchRequest.filter_strategy (None = adaptive).
    filter_strategy: str | None = None
    # Legacy proxy-materialized bitmaps (segment_id -> row mask), still
    # honored when present: ANDed into visibility before planning.
    filter_masks: dict[int, np.ndarray] | None = None
    # None = no pruning; otherwise only segments tagged with one of these
    # partitions enter the plan.
    partitions: tuple[str, ...] | None = None
    # Replica-aware dispatch scope: None = every segment the node holds
    # (legacy full fan-out); a tuple = scan only these live sealed segments
    # (() = growing/channel data only).  Retired MVCC versions are exempt
    # from the scope — they are node-local epoch baggage that pinned
    # queries must still reach regardless of where replicas moved.
    segments: tuple[int, ...] | None = None
    # Growing-scan scope, the channel twin of ``segments``: None = every
    # growing copy the node holds (legacy full fan-out); a tuple of DML
    # channel names = scan only the growing segments fed by those channels
    # (() = sealed data only).  Watermark-aware routing relies on this: a
    # node dispatched for sealed units must NOT serve a lagging growing
    # copy of a channel the plan routed to a fresher replica — per-node
    # tombstones would resurrect rows deleted before the wait target.
    channels: tuple[str, ...] | None = None
    # Trace propagation: (TraceContext, parent Span) when the request is
    # traced; the node hangs plan/scan/reduce child spans off the parent.
    trace: tuple | None = None
    # True for hedge re-dispatches — the node books the search under
    # ``searches_hedged`` so least-loaded picks see primary load only.
    hedged: bool = False

    @classmethod
    def from_request(
        cls,
        schema: Schema,
        collection: str,
        request: SearchRequest,
        metric: Metric,
        guarantee: GuaranteeTs,
        filter=None,
        filter_masks: dict[int, np.ndarray] | None = None,
        segments: tuple[int, ...] | None = None,
        channels: tuple[str, ...] | None = None,
        trace: tuple | None = None,
        hedged: bool = False,
    ) -> "NodeSearchRequest":
        anns = [
            AnnsQuery(
                vector_column_of(schema, a.field), a.queries, a.weight, dict(a.params)
            )
            for a in request.anns
        ]
        return cls(
            collection=collection,
            k=request.k,
            metric=metric,
            guarantee=guarantee,
            anns=anns,
            filter=filter,
            filter_strategy=request.filter_strategy,
            filter_masks=filter_masks,
            partitions=request.partition_names or None,
            segments=segments,
            channels=channels,
            trace=trace,
            hedged=hedged,
        )


# ---------------------------------------------------------------------------
# Typed cluster-admin surface (read-only snapshots)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NodeStatus:
    """One query node as the control loop sees it.

    ``status`` is the HealthMonitor's observation: ``healthy`` /
    ``suspect`` (missed more than half a heartbeat TTL) / ``dead`` (lease
    expired) / ``draining`` (graceful scale-down in progress).  ``load``
    is the replica count used by least-loaded placement decisions.
    """

    node_id: str
    status: str
    load: int
    segments: tuple[tuple[str, int], ...]
    channels: tuple[str, ...]
    searches: int = 0
    # Hedge accounting split: primaries drive the load picker; hedges are
    # duplicated work and must not inflate a node's apparent traffic.
    searches_primary: int = 0
    searches_hedged: int = 0


@dataclass(frozen=True)
class SegmentPlacement:
    """One sealed segment's committed replica group.  ``replicas[0]`` is
    the primary; ``visible_from_ts`` is the MVCC epoch pin that rides
    along on every reassignment; ``under_replicated`` records graceful
    degradation when the cluster is smaller than the replication factor."""

    collection: str
    segment_id: int
    replicas: tuple[str, ...]
    under_replicated: bool
    visible_from_ts: int


@dataclass(frozen=True)
class ClusterState:
    """Frozen point-in-time snapshot of the serving tier, returned by
    ``ManuSystem.cluster_state()`` — node health, per-node load, the
    segment -> replica-set placement map, and the under-replication count
    the reconciler is working to drive to zero."""

    nodes: tuple[NodeStatus, ...]
    placement: tuple[SegmentPlacement, ...]
    under_replicated: int
    replication_factor: int

    def node(self, node_id: str) -> NodeStatus:
        for n in self.nodes:
            if n.node_id == node_id:
                return n
        raise KeyError(f"unknown query node '{node_id}'")

    def replicas_of(self, collection: str, segment_id: int) -> tuple[str, ...]:
        for p in self.placement:
            if p.collection == collection and p.segment_id == segment_id:
                return p.replicas
        return ()

    @property
    def live_node_ids(self) -> tuple[str, ...]:
        return tuple(n.node_id for n in self.nodes if n.status != "dead")


@dataclass(frozen=True)
class HistogramRow:
    """One histogram series in a :class:`MetricsSnapshot` — count, mean,
    and the interpolated p50/p95/p99 estimates from the log buckets."""

    name: str
    count: int
    mean: float
    p50: float
    p95: float
    p99: float

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "count": int(self.count),
            "mean": float(self.mean),
            "p50": float(self.p50),
            "p95": float(self.p95),
            "p99": float(self.p99),
        }


@dataclass(frozen=True)
class MetricsSnapshot:
    """Frozen point-in-time read-out of the telemetry registry, returned
    by ``ManuSystem.metrics()`` — the metrics twin of ``ClusterState``.

    ``counters``/``gauges`` map fully-labelled series keys (Prometheus
    ``name{label="v"}`` form) to values; ``histograms`` carry typed
    percentile rows.  Everything is plain Python, so the snapshot JSON
    round-trips via ``to_dict()``.
    """

    ts_ms: float
    counters: dict
    gauges: dict
    histograms: tuple[HistogramRow, ...]

    def counter(self, key: str, default: float = 0.0) -> float:
        return self.counters.get(key, default)

    def gauge(self, key: str, default: float = 0.0) -> float:
        return self.gauges.get(key, default)

    def histogram(self, key: str) -> HistogramRow | None:
        for h in self.histograms:
            if h.name == key:
                return h
        return None

    def to_dict(self) -> dict:
        return {
            "ts_ms": float(self.ts_ms),
            "counters": {k: float(v) for k, v in self.counters.items()},
            "gauges": {k: float(v) for k, v in self.gauges.items()},
            "histograms": [h.to_dict() for h in self.histograms],
        }


@dataclass(frozen=True)
class IndexDescription:
    """Declared index of one vector field."""

    field: str
    kind: str
    params: dict
    metric: Metric


@dataclass(frozen=True)
class DescribeCollection:
    """Frozen schema + placement description of one collection, returned
    by ``ManuCollection.describe()``."""

    name: str
    fields: tuple
    partitions: tuple[str, ...]
    indexes: tuple[IndexDescription, ...]
    num_entities: int
    num_shards: int
    metric: Metric
    replication_factor: int

    def index_on(self, field: str) -> IndexDescription | None:
        for ix in self.indexes:
            if ix.field == field:
                return ix
        return None
