"""Data nodes: WAL -> binlog archivers (paper §3.3).

A data node subscribes to a set of DML channels, accumulates rows into the
authoritative growing segments, and when the data coordinator marks a
segment for sealing (size or idle-time policy), serializes it to columnar
binlog objects and announces ``segment_sealed`` on the coordination
channel.  Data nodes are stateless in the recovery sense: everything they
hold is reconstructible by replaying the WAL from the last sealed
checkpoint positions.
"""

from __future__ import annotations

from .log import COORD_CHANNEL, EntryType, LogBroker, LogEntry, Subscription
from .binlog import write_segment_binlog
from .object_store import ObjectStore
from .segment import Segment
from .timestamp import TSO


class DataNode:
    def __init__(
        self,
        node_id: str,
        broker: LogBroker,
        store: ObjectStore,
        tso: TSO,
        data_coord,
    ):
        self.node_id = node_id
        self.broker = broker
        self.store = store
        self.tso = tso
        self.data_coord = data_coord
        self.subscriptions: dict[str, Subscription] = {}
        # (collection, segment_id) -> growing Segment
        self.growing: dict[tuple[str, int], Segment] = {}
        self.alive = True

    def subscribe(self, channel: str, from_position: int = 0) -> None:
        self.subscriptions[channel] = Subscription(self.broker, channel, from_position)

    def unsubscribe(self, channel: str) -> None:
        self.subscriptions.pop(channel, None)

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        if not self.alive:
            return False
        progress = False
        for sub in list(self.subscriptions.values()):
            for entry in sub.poll():
                progress |= self._consume(entry, sub.position)
        progress |= self._flush_sealed()
        return progress

    def _consume(self, entry: LogEntry, position: int) -> bool:
        if entry.type is EntryType.INSERT:
            p = entry.payload
            key = (p["collection"], p["segment_id"])
            seg = self.growing.get(key)
            if seg is None:
                dim = p["vector"].shape[1]
                extra_fields = tuple(sorted(p.get("extras", {})))
                seg = Segment(
                    p["segment_id"], p["collection"], p["shard"], dim,
                    extra_fields=extra_fields,
                )
                self.growing[key] = seg
            n = len(p["pk"])
            ts_col = [entry.ts] * n
            import numpy as np

            seg.append(p["pk"], p["vector"], np.asarray(ts_col), p.get("extras"))
            seg.checkpoint_pos = position
            return True
        if entry.type is EntryType.DELETE:
            p = entry.payload
            for (coll, _sid), seg in self.growing.items():
                if coll == p["collection"]:
                    seg.delete(p["pk"], entry.ts)
            return True
        return False

    def _flush_sealed(self) -> bool:
        """Seal + flush segments the data coordinator marked."""
        progress = False
        for key in list(self.growing):
            coll, sid = key
            if not self.data_coord.should_seal(coll, sid):
                continue
            seg = self.growing.pop(key)
            seg.seal()
            keys = write_segment_binlog(self.store, seg)
            ts = self.tso.next()
            self.broker.publish(
                COORD_CHANNEL,
                LogEntry(
                    ts=ts,
                    type=EntryType.COORD,
                    payload={
                        "msg": "segment_sealed",
                        "collection": coll,
                        "segment_id": sid,
                        "shard": seg.shard,
                        "num_rows": seg.num_rows,
                        "binlog_keys": keys,
                        "checkpoint_pos": seg.checkpoint_pos,
                        "min_ts": seg.min_ts(),
                        "max_ts": seg.max_ts(),
                    },
                ),
            )
            self.data_coord.on_sealed(coll, sid, seg.num_rows)
            progress = True
        return progress
