"""Data nodes: WAL -> binlog archivers (paper §3.3).

A data node subscribes to a set of DML channels, accumulates rows into the
authoritative growing segments, and when the data coordinator marks a
segment for sealing (size or idle-time policy), serializes it to columnar
binlog objects and announces ``segment_sealed`` on the coordination
channel.  Data nodes are stateless in the recovery sense: everything they
hold is reconstructible by replaying the WAL from the last sealed
checkpoint positions.
"""

from __future__ import annotations

from .log import COORD_CHANNEL, EntryType, LogBroker, LogEntry, Subscription
from .binlog import write_attr_satellites, write_segment_binlog
from .object_store import ObjectStore
from .segment import DEFAULT_PARTITION, Segment
from .telemetry import MetricsRegistry
from .timestamp import TSO


class DataNode:
    def __init__(
        self,
        node_id: str,
        broker: LogBroker,
        store: ObjectStore,
        tso: TSO,
        data_coord,
        metrics: MetricsRegistry | None = None,
    ):
        self.node_id = node_id
        self.broker = broker
        self.store = store
        self.tso = tso
        self.data_coord = data_coord
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.subscriptions: dict[str, Subscription] = {}
        # (collection, segment_id) -> growing Segment
        self.growing: dict[tuple[str, int], Segment] = {}
        # LSN-keyed dedup: highest applied position per channel.  The broker
        # is at-least-once (duplicate delivery is an injectable fault), so
        # every subscriber must treat re-delivered entries as no-ops.
        self._applied_pos: dict[str, int] = {}
        # (collection, segment_id) -> already archived to binlog?  Replaying
        # the WAL from position 0 after a crash must skip insert halves whose
        # segment is durable in the base log (binlog); delete halves always
        # apply (they tombstone the *growing* segments being rebuilt).
        self._archived: dict[tuple[str, int], bool] = {}
        self.alive = True

    def subscribe(self, channel: str, from_position: int = 0) -> None:
        self.subscriptions[channel] = Subscription(self.broker, channel, from_position)

    def unsubscribe(self, channel: str) -> None:
        self.subscriptions.pop(channel, None)

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        if not self.alive:
            return False
        progress = False
        for sub in list(self.subscriptions.values()):
            watermark = self._applied_pos.get(sub.channel, -1)
            for entry in sub.poll():
                if entry.position <= watermark:
                    self.metrics.inc("log_dedup_skipped_total",
                                     labels={"node": self.node_id})
                    continue
                progress |= self._consume(entry, entry.position + 1)
                watermark = entry.position
            self._applied_pos[sub.channel] = watermark
        progress |= self._flush_sealed()
        return progress

    def _is_archived(self, coll: str, sid: int) -> bool:
        key = (coll, sid)
        hit = self._archived.get(key)
        if hit is None:
            hit = self.store.exists(f"binlog/{coll}/{sid}/meta")
            self._archived[key] = hit
        return hit

    def _consume(self, entry: LogEntry, position: int) -> bool:
        import numpy as np

        if entry.type in (EntryType.INSERT, EntryType.UPSERT):
            p = entry.payload
            if entry.type is EntryType.UPSERT:
                # Delete half of the atomic upsert record: tombstone older
                # versions of these pks (rows with ts < entry.ts) wherever
                # they live; the insert half below lands at the same LSN.
                for (coll, _sid), seg in self.growing.items():
                    if coll == p["collection"]:
                        seg.delete(p["pk"], entry.ts)
            key = (p["collection"], p["segment_id"])
            if key not in self.growing and self._is_archived(*key):
                # Crash-recovery replay: this insert is already durable in
                # the sealed binlog; rebuilding it as growing rows would
                # double-count.  (The delete half above still applied.)
                return entry.type is EntryType.UPSERT
            seg = self.growing.get(key)
            if seg is None:
                dim = p["vector"].shape[1]
                extra_fields = tuple(sorted(p.get("extras", {})))
                seg = Segment(
                    p["segment_id"], p["collection"], p["shard"], dim,
                    extra_fields=extra_fields,
                    partition=p.get("partition", DEFAULT_PARTITION),
                )
                self.growing[key] = seg
            n = len(p["pk"])
            seg.append(
                p["pk"], p["vector"], np.full(n, entry.ts, np.int64), p.get("extras")
            )
            seg.checkpoint_pos = position
            return True
        if entry.type is EntryType.DELETE:
            p = entry.payload
            for (coll, _sid), seg in self.growing.items():
                if coll == p["collection"]:
                    seg.delete(p["pk"], entry.ts)
            return True
        return False

    def _flush_sealed(self) -> bool:
        """Seal + flush segments the data coordinator marked."""
        import time as _t

        progress = False
        for key in list(self.growing):
            coll, sid = key
            if not self.data_coord.should_seal(coll, sid):
                continue
            seg = self.growing.pop(key)
            t0 = _t.perf_counter()
            seg.seal()
            keys = write_segment_binlog(self.store, seg)
            # Attribute-index satellites ride behind the binlog meta (the
            # flush-complete proof): a crash in this window leaves a sealed
            # binlog without satellites, which reconcile_sealed rebuilds.
            attr_keys = write_attr_satellites(self.store, seg)
            self.metrics.inc("data_node_attr_indexes_built_total", len(attr_keys))
            self.metrics.observe(
                "data_node_seal_flush_us", (_t.perf_counter() - t0) * 1e6
            )
            self.metrics.inc("data_node_segments_sealed_total")
            self.metrics.inc("data_node_rows_flushed_total", seg.num_rows)
            ts = self.tso.next()
            self.broker.publish(
                COORD_CHANNEL,
                LogEntry(
                    ts=ts,
                    type=EntryType.COORD,
                    payload={
                        "msg": "segment_sealed",
                        "collection": coll,
                        "segment_id": sid,
                        "shard": seg.shard,
                        "partition": seg.partition,
                        "num_rows": seg.num_rows,
                        "binlog_keys": keys,
                        "attr_keys": attr_keys,
                        "checkpoint_pos": seg.checkpoint_pos,
                        "min_ts": seg.min_ts(),
                        "max_ts": seg.max_ts(),
                    },
                ),
            )
            self.data_coord.on_sealed(
                coll, sid, seg.num_rows, seg.partition, shard=seg.shard,
                attr_fields=sorted(attr_keys),
            )
            progress = True
        return progress

    def drop_partition(self, collection: str, partition: str) -> int:
        """Discard growing segments of a dropped partition (their rows
        must not seal into binlogs after the drop)."""
        doomed = [
            key
            for key, seg in self.growing.items()
            if key[0] == collection and seg.partition == partition
        ]
        for key in doomed:
            del self.growing[key]
        return len(doomed)
