"""The log backbone (paper §3.3).

Manu structures the entire system as log publish/subscribe services: the
WAL is the incremental part, the binlog the base part.  We reproduce the
WAL side here as a multi-channel broker with:

* durable, append-only channels (Kafka/Pulsar stand-in),
* positional subscription (``subscribe(from_position)``) and ``seek`` —
  required for failure recovery and time travel replay,
* **time-ticks**: special control entries inserted periodically into every
  channel signalling event-time progress (the watermark mechanism behind
  delta consistency),
* logical (not physical) log entries: each entry records an *event*
  (insert/delete/ddl/coordination), so different subscribers consume the
  same log in different ways (data node → binlog, query node → in-memory
  growing segment, ...).

Channel layout (paper: "multiple logical channels ... to prevent different
types of requests from interfering"):

* ``ddl``                    — data-definition requests
* ``coord``                  — system-coordination messages
* ``dml/<collection>/<shard>`` — data-manipulation requests, hashed by PK
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Iterator

import numpy as np


class EntryType(Enum):
    INSERT = "insert"
    DELETE = "delete"
    # One atomic record carrying a delete-by-pk half AND an insert half:
    # MVCC visibility of the old and new row versions flips at the record's
    # single LSN (the paper's row-level ACID upsert idiom).
    UPSERT = "upsert"
    DDL = "ddl"
    COORD = "coord"
    TIME_TICK = "time_tick"


@dataclass(frozen=True)
class LogEntry:
    """One logical log record.

    ``ts`` is the HLC timestamp (LSN) assigned by the TSO at publish time.
    ``payload`` is a dict for control entries; INSERT entries carry numpy
    arrays in ``payload`` (rows: pks, vectors, labels, numerics).
    """

    ts: int
    type: EntryType
    payload: dict[str, Any]
    channel: str = ""
    position: int = -1  # offset within the channel, set by the broker


@dataclass
class _Channel:
    name: str
    entries: list[LogEntry] = field(default_factory=list)
    last_tick_ts: int = 0
    bytes_published: int = 0


def _entry_nbytes(entry: LogEntry) -> int:
    total = 64
    for v in entry.payload.values():
        if isinstance(v, np.ndarray):
            total += v.nbytes
        elif isinstance(v, (bytes, str)):
            total += len(v)
        else:
            total += 16
    return total


class LogBroker:
    """In-process multi-channel durable log (Kafka/Pulsar stand-in).

    The API mirrors what Manu needs from a cloud message queue: create
    channels, append (publish), read from an offset, and truncate below a
    retention point.  All reads are positional so any subscriber can replay
    independently — the property the whole architecture leans on.
    """

    def __init__(self) -> None:
        self._channels: dict[str, _Channel] = {}
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)

    # --------------------------------------------------------------- admin
    def create_channel(self, name: str) -> None:
        with self._lock:
            if name not in self._channels:
                self._channels[name] = _Channel(name)

    def has_channel(self, name: str) -> bool:
        with self._lock:
            return name in self._channels

    def channels(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(c for c in self._channels if c.startswith(prefix))

    def drop_channel(self, name: str) -> None:
        with self._lock:
            self._channels.pop(name, None)

    # ------------------------------------------------------------- publish
    def publish(self, channel: str, entry: LogEntry) -> int:
        with self._cv:
            ch = self._channels.get(channel)
            if ch is None:
                raise KeyError(f"unknown channel: {channel}")
            if ch.entries and entry.ts < ch.entries[-1].ts:
                raise ValueError(
                    f"out-of-order publish on {channel}: "
                    f"{entry.ts} < {ch.entries[-1].ts}"
                )
            position = len(ch.entries)
            stamped = LogEntry(
                ts=entry.ts,
                type=entry.type,
                payload=entry.payload,
                channel=channel,
                position=position,
            )
            ch.entries.append(stamped)
            ch.bytes_published += _entry_nbytes(stamped)
            if entry.type is EntryType.TIME_TICK:
                ch.last_tick_ts = entry.ts
            self._cv.notify_all()
            return position

    # ------------------------------------------------------------ consume
    def read(self, channel: str, from_position: int, max_entries: int | None = None) -> list[LogEntry]:
        with self._lock:
            ch = self._channels.get(channel)
            if ch is None:
                raise KeyError(f"unknown channel: {channel}")
            end = len(ch.entries)
            if max_entries is not None:
                end = min(end, from_position + max_entries)
            return ch.entries[from_position:end]

    def end_position(self, channel: str) -> int:
        with self._lock:
            return len(self._channels[channel].entries)

    def last_tick(self, channel: str) -> int:
        with self._lock:
            return self._channels[channel].last_tick_ts

    def wait_for_tick(self, channel: str, min_ts: int, timeout_s: float | None = None) -> bool:
        """Block until the channel's last time-tick >= min_ts."""
        with self._cv:
            return self._cv.wait_for(
                lambda: self._channels[channel].last_tick_ts >= min_ts, timeout=timeout_s
            )

    def entries_between(
        self, channel: str, ts_lo: int, ts_hi: int
    ) -> Iterator[LogEntry]:
        """Entries with ts in (ts_lo, ts_hi], skipping time-ticks (replay API)."""
        for e in self.read(channel, 0):
            if ts_lo < e.ts <= ts_hi and e.type is not EntryType.TIME_TICK:
                yield e

    # ----------------------------------------------------------- retention
    def truncate_before(self, channel: str, ts: int) -> int:
        """Drop entries with timestamp < ts (log expiration, paper §4.3)."""
        with self._lock:
            ch = self._channels[channel]
            keep_from = 0
            for i, e in enumerate(ch.entries):
                if e.ts >= ts:
                    keep_from = i
                    break
            else:
                keep_from = len(ch.entries)
            dropped = keep_from
            # Preserve absolute positions by leaving a tombstone prefix
            # out of band: we renumber but record the base offset.
            ch.entries = ch.entries[keep_from:]
            return dropped

    def stats(self) -> dict[str, dict[str, int]]:
        with self._lock:
            return {
                name: {
                    "entries": len(ch.entries),
                    "bytes": ch.bytes_published,
                    "last_tick": ch.last_tick_ts,
                }
                for name, ch in self._channels.items()
            }


class Subscription:
    """A positional cursor over one channel.

    Subscribers pull entries and track their own progress — the broker holds
    no per-subscriber state (exactly the Kafka consumer model).  ``seek``
    supports failure recovery: a new node resumes from a checkpointed
    position.
    """

    def __init__(self, broker: LogBroker, channel: str, from_position: int = 0):
        self.broker = broker
        self.channel = channel
        self.position = from_position
        self.last_tick_seen = 0

    def poll(self, max_entries: int | None = None) -> list[LogEntry]:
        entries = self.broker.read(self.channel, self.position, max_entries)
        if entries:
            self.position = entries[-1].position + 1
            for e in entries:
                if e.type is EntryType.TIME_TICK:
                    self.last_tick_seen = max(self.last_tick_seen, e.ts)
        return entries

    def seek(self, position: int) -> None:
        self.position = position

    def lag(self) -> int:
        return self.broker.end_position(self.channel) - self.position


# --------------------------------------------------------------------------
# Channel naming helpers
# --------------------------------------------------------------------------

DDL_CHANNEL = "ddl"
COORD_CHANNEL = "coord"


def dml_channel(collection: str, shard: int) -> str:
    return f"dml/{collection}/{shard}"


def shard_of_channel(channel: str) -> int:
    """Inverse of :func:`dml_channel`: the shard a DML channel carries."""
    return int(channel.rsplit("/", 1)[1])


_HASH_MASK = 0x7FFFFFFF


def shard_of_pk(pk: int | str, num_shards: int) -> int:
    """Consistent hash of a primary key onto a shard (paper Fig. 4).

    String keys hash their unicode codepoints through a Horner polynomial —
    the scalar twin of the vectorized :func:`shards_of_pks`, which the write
    pipeline uses to split whole batches without per-row Python loops."""
    if isinstance(pk, str):
        h = 0
        for c in pk:
            h = (h * 131 + ord(c)) & _HASH_MASK
        return h % num_shards
    return int(pk) % num_shards


def shards_of_pks(pks: np.ndarray, num_shards: int) -> np.ndarray:
    """Vectorized :func:`shard_of_pk` over a whole pk batch.

    Integer keys are one modulo; string keys view the fixed-width unicode
    buffer as a [n, width] codepoint matrix and run the Horner hash one
    *column* at a time (loop over max string length, not over rows),
    skipping NUL padding so short and long keys agree with the scalar hash.
    """
    pks = np.asarray(pks)
    if pks.size == 0:
        return np.empty(0, np.int64)
    if pks.dtype.kind in "iu":
        return (pks.astype(np.int64) % num_shards).astype(np.int64)
    codes = (
        np.ascontiguousarray(pks.astype(np.str_))
        .view(np.uint32)
        .reshape(len(pks), -1)
        .astype(np.int64)
    )
    h = np.zeros(len(pks), np.int64)
    for col in range(codes.shape[1]):
        c = codes[:, col]
        h = np.where(c > 0, (h * 131 + c) & _HASH_MASK, h)
    return h % num_shards
