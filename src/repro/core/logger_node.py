"""Loggers: WAL entry points (paper Fig. 4, §4.2).

Loggers sit in a consistent-hash ring; each owns one or more shards
(logical buckets).  Every mutation arrives as one typed request
(:class:`InsertRequest` / :class:`DeleteRequest` / :class:`UpsertRequest`):
the owning logger verifies it, obtains ONE LSN from the TSO (row-level
ACID: all rows of a request share it), splits the batch over shards with
a single vectorized hash + ``bincount``/``argsort`` scatter, resolves the
*segment* each entity belongs to (consulting the data coordinator's
per-partition allocations), and appends one entry per touched shard to
the WAL channels.  The answer is a :class:`MutationResult` whose
``watermark_ts`` feeds SESSION-consistency reads.

Upserts publish a single ``UPSERT`` record per shard carrying both the
delete-by-pk half and the insert half, so MVCC visibility of the old and
new row versions flips atomically at the record's LSN.

Loggers also emit the periodic time-ticks that drive delta consistency.
"""

from __future__ import annotations

import threading

import numpy as np

from ..kernels import ops
from .collection import CollectionInfo, validate_rows
from .log import EntryType, LogBroker, LogEntry, dml_channel, shards_of_pks
from .request import (
    DeleteRequest,
    InsertRequest,
    MutationRequest,
    MutationResult,
    UpsertRequest,
)
from .telemetry import MetricsRegistry
from .timestamp import TSO, Clock


class Logger:
    """One logger instance; owns a set of shards for each collection."""

    def __init__(
        self,
        logger_id: str,
        broker: LogBroker,
        tso: TSO,
        data_coord,  # DataCoordinator (duck-typed to avoid import cycle)
        clock: Clock,
        tick_interval_ms: float = 50.0,
        metrics: MetricsRegistry | None = None,
    ):
        self.logger_id = logger_id
        self.broker = broker
        self.tso = tso
        self.data_coord = data_coord
        self.clock = clock
        self.tick_interval_ms = tick_interval_ms
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._last_tick_ms: dict[str, float] = {}
        self.alive = True
        # Serializes LSN-assign + WAL publish: the broker enforces
        # monotonic per-channel timestamps, so a threaded scheduler flush
        # racing a user-thread mutation must not interleave the two steps.
        self._lock = threading.Lock()

    # ----------------------------------------------------------- mutations
    def mutate(
        self,
        info: CollectionInfo,
        request: MutationRequest,
        trace: tuple | None = None,
    ) -> MutationResult:
        """Validate, assign one LSN, split by shard, publish to the WAL.

        ``trace`` is the optional ``(TraceContext, parent Span)`` pair from
        a traced ``Proxy.mutate``: the WAL append gets a child span with
        the touched shard count and rows written.
        """
        if not self.alive:
            raise RuntimeError(f"logger {self.logger_id} is down")
        with self._lock:
            return self._mutate_one(info, request, trace)

    def mutate_batch(
        self,
        info: CollectionInfo,
        requests: "list[MutationRequest]",
        traces: "list[tuple | None] | None" = None,
        prevalidated: bool = False,
    ) -> "list[MutationResult | Exception]":
        """One WAL-entry-point crossing for a scheduler-flushed batch.

        Each request keeps its OWN LSN and its own result slot — batching
        amortizes the call and the lock, never merges semantics.  Per-slot
        failures come back as the exception object (the scheduler fails
        just that ticket); ``Crash`` is a BaseException and still
        propagates, killing the whole flush like any other process death.
        ``prevalidated`` skips per-request schema validation: the
        scheduler already ran it at admission time.
        """
        if not self.alive:
            raise RuntimeError(f"logger {self.logger_id} is down")
        if traces is None:
            traces = [None] * len(requests)
        out: "list[MutationResult | Exception]" = []
        with self._lock:
            for request, trace in zip(requests, traces):
                try:
                    out.append(
                        self._mutate_one(info, request, trace,
                                         prevalidated=prevalidated)
                    )
                except Exception as exc:
                    out.append(exc)
        self.metrics.inc("logger_batches_total")
        self.metrics.observe("logger_batch_requests", len(requests))
        return out

    def _mutate_one(
        self,
        info: CollectionInfo,
        request: MutationRequest,
        trace: tuple | None = None,
        prevalidated: bool = False,
    ) -> MutationResult:
        import time as _t

        t0 = _t.perf_counter()
        if isinstance(request, UpsertRequest):
            res = self._write_rows(info, request.rows, request.partition,
                                   upsert=True, prevalidated=prevalidated)
        elif isinstance(request, InsertRequest):
            res = self._write_rows(info, request.rows, request.partition,
                                   upsert=False, prevalidated=prevalidated)
        elif isinstance(request, DeleteRequest):
            if not prevalidated:
                request.validate(info.schema)
            res = self._delete(info, request.pks)
        else:
            raise TypeError(f"unknown mutation request {type(request).__name__}")
        elapsed_us = (_t.perf_counter() - t0) * 1e6
        self.metrics.observe("wal_append_latency_us", elapsed_us)
        self.metrics.inc("logger_rows_written_total", res.ack_rows)
        self.metrics.inc(
            "logger_mutations_total", labels={"op": res.op}
        )
        if trace is not None:
            ctx, parent = trace
            span = ctx.span(
                "wal_append", parent=parent, node_id=self.logger_id,
                detail=(
                    f"op={res.op};shards={sorted(res.shard_lsns)};"
                    f"lsn={res.watermark_ts}"
                ),
            )
            span.duration_us = elapsed_us
            span.rows_scanned = res.ack_rows
        return res

    def _write_rows(
        self,
        info: CollectionInfo,
        rows: dict[str, np.ndarray],
        partition: str,
        upsert: bool,
        prevalidated: bool = False,
    ) -> MutationResult:
        if prevalidated:  # the scheduler verified at admission time
            n = len(next(iter(rows.values())))
        else:
            n = validate_rows(info.schema, rows)  # the logger verifies (Fig. 4)
        pk_field = info.schema.primary()
        explicit = pk_field is not None and pk_field.name in rows
        if explicit:
            pks = np.asarray(rows[pk_field.name])
            # keep the auto-ID watermark ahead of user-supplied keys so
            # allocation never collides and no-match deletes stay cheap
            self.data_coord.id_alloc.note_explicit(info.name, pks)
        else:
            pks = self.data_coord.allocate_pks(info.name, n)
        # Fresh auto-IDs cannot collide: nothing to replace, plain insert.
        upsert = upsert and explicit

        lsn = self.tso.next()
        # One vectorized hash over the whole batch, then a bincount/argsort
        # scatter into per-shard row groups — no per-row Python loops.
        shards = shards_of_pks(pks, info.num_shards)
        order, offsets = ops.shard_split(shards, info.num_shards)

        # The first vector field is the segment's primary "vector" column;
        # additional vector fields ride the extras columns under their own
        # names (same path as attributes), so multi-vector rows stay columnar
        # end to end (WAL -> growing segment -> binlog).
        vec_fields = info.schema.vector_fields()
        vectors = np.asarray(rows[vec_fields[0].name], np.float32)
        extras_all = {
            f.name: np.asarray(rows[f.name])
            for f in info.schema.attribute_fields()
            if f.name in rows
        }
        extras_all.update(
            {
                f.name: np.asarray(rows[f.name], np.float32)
                for f in vec_fields[1:]
                if f.name in rows
            }
        )
        shard_lsns: dict[int, int] = {}
        for shard in range(info.num_shards):
            sel = order[offsets[shard] : offsets[shard + 1]]
            if sel.size == 0:
                continue
            segment_id = self.data_coord.assign_segment(
                info.name, shard, len(sel), partition
            )
            payload = {
                "collection": info.name,
                "shard": shard,
                "segment_id": segment_id,
                "partition": partition,
                "pk": pks[sel],
                "vector": vectors[sel],
                "extras": {f: a[sel] for f, a in extras_all.items()},
            }
            self.broker.publish(
                dml_channel(info.name, shard),
                LogEntry(
                    ts=lsn,
                    type=EntryType.UPSERT if upsert else EntryType.INSERT,
                    payload=payload,
                ),
            )
            shard_lsns[shard] = lsn
        if upsert and shard_lsns:
            self._broadcast_tombstones(info.name, pks, lsn)
        return MutationResult(
            op="upsert" if upsert else "insert",
            pks=pks,
            shard_lsns=shard_lsns,
            watermark_ts=lsn,
            row_count=n,
            ack_rows=n,
        )

    def _delete(self, info: CollectionInfo, pks: np.ndarray) -> MutationResult:
        pks = np.atleast_1d(np.asarray(pks))
        requested = len(pks)
        if pks.size and pks.dtype.kind in "iu":
            # Cheap no-match rejection: integer keys beyond the allocator's
            # high watermark (or negative) were never inserted.
            high = self.data_coord.id_alloc.high(info.name)
            pks = pks[(pks >= 0) & (pks < high)]
        if pks.size == 0:
            # No-op: publish nothing, but hand back a valid watermark — the
            # last issued timestamp is already covered by any read that
            # waits on it, so a SESSION follow-up costs nothing.
            return MutationResult(
                op="delete",
                pks=pks,
                shard_lsns={},
                watermark_ts=self.tso.last_issued(),
                row_count=requested,
                ack_rows=0,
            )
        lsn = self.tso.next()
        shards = shards_of_pks(pks, info.num_shards)
        order, offsets = ops.shard_split(shards, info.num_shards)
        shard_lsns: dict[int, int] = {}
        for shard in range(info.num_shards):
            sel = order[offsets[shard] : offsets[shard + 1]]
            if sel.size == 0:
                continue
            self.broker.publish(
                dml_channel(info.name, shard),
                LogEntry(
                    ts=lsn,
                    type=EntryType.DELETE,
                    payload={
                        "collection": info.name,
                        "shard": shard,
                        "pk": pks[sel],
                    },
                ),
            )
            shard_lsns[shard] = lsn
        self._broadcast_tombstones(info.name, pks, lsn)
        return MutationResult(
            op="delete",
            pks=pks,
            shard_lsns=shard_lsns,
            watermark_ts=lsn,
            row_count=requested,
            ack_rows=len(pks),
        )

    def _broadcast_tombstones(
        self, collection: str, pks: np.ndarray, lsn: int
    ) -> None:
        """Mirror the full tombstone set onto the broadcast coord channel.

        Per-shard DELETE entries only reach the query node that owns that
        shard's DML channel, but sealed-segment placement is not shard-affine
        (handoffs and restarts move segments across nodes).  The coord mirror
        — carried at the SAME LSN as the DML halves, and replayed from
        position 0 by every (re)started query node — guarantees every server
        of a sealed copy learns about the kills.  Appliers dedup by (pk, ts),
        so double delivery via both channels is harmless."""
        self.broker.publish(
            "coord",
            LogEntry(
                ts=lsn,
                type=EntryType.COORD,
                payload={
                    "msg": "tombstones",
                    "collection": collection,
                    "pk": pks,
                },
            ),
        )

    # ------------------------------------------------------ legacy facades
    def insert(
        self, info: CollectionInfo, rows: dict[str, np.ndarray]
    ) -> tuple[int, int]:
        """Legacy surface: (lsn, row_count) via the typed pipeline."""
        res = self.mutate(info, InsertRequest(rows))
        return res.watermark_ts, res.row_count

    def delete(self, info: CollectionInfo, pks: np.ndarray) -> int:
        """Legacy surface: bare LSN via the typed pipeline."""
        return self.mutate(info, DeleteRequest(pks)).watermark_ts

    # ---------------------------------------------------------- time ticks
    def tick(self, channels: list[str], force: bool = False) -> int:
        """Emit time-ticks on owned channels if the interval elapsed."""
        now = self.clock.now_ms()
        emitted = 0
        for ch in channels:
            last = self._last_tick_ms.get(ch, -1e18)
            if force or (now - last) >= self.tick_interval_ms:
                ts = self.tso.next()
                self.broker.publish(
                    ch, LogEntry(ts=ts, type=EntryType.TIME_TICK, payload={})
                )
                self._last_tick_ms[ch] = now
                emitted += 1
        return emitted
