"""Loggers: WAL entry points (paper Fig. 4).

Loggers sit in a consistent-hash ring; each owns one or more shards
(logical buckets).  On an insert/delete the owning logger verifies the
request, obtains an LSN from the TSO, resolves the *segment* each entity
belongs to (consulting the data coordinator's allocations), and appends the
entry to the shard's WAL channel.  Loggers also emit the periodic
time-ticks that drive delta consistency.
"""

from __future__ import annotations

import numpy as np

from .collection import CollectionInfo, validate_rows
from .log import EntryType, LogBroker, LogEntry, dml_channel, shard_of_pk
from .timestamp import TSO, Clock


class Logger:
    """One logger instance; owns a set of shards for each collection."""

    def __init__(
        self,
        logger_id: str,
        broker: LogBroker,
        tso: TSO,
        data_coord,  # DataCoordinator (duck-typed to avoid import cycle)
        clock: Clock,
        tick_interval_ms: float = 50.0,
    ):
        self.logger_id = logger_id
        self.broker = broker
        self.tso = tso
        self.data_coord = data_coord
        self.clock = clock
        self.tick_interval_ms = tick_interval_ms
        self._last_tick_ms: dict[str, float] = {}
        self.alive = True

    # ------------------------------------------------------------- inserts
    def insert(
        self, info: CollectionInfo, rows: dict[str, np.ndarray]
    ) -> tuple[int, int]:
        """Validate, assign LSN + segment, publish to WAL.

        Returns (lsn, row_count).  The paper assigns one LSN per request;
        all rows in the batch share it (row-level ACID).
        """
        if not self.alive:
            raise RuntimeError(f"logger {self.logger_id} is down")
        n = validate_rows(info.schema, rows)
        pk_field = info.schema.primary()
        if pk_field and pk_field.name in rows:
            pks = np.asarray(rows[pk_field.name])
        else:
            pks = self.data_coord.allocate_pks(info.name, n)

        lsn = self.tso.next()
        shards = np.array([shard_of_pk(pk, info.num_shards) for pk in pks.tolist()])
        # The first vector field is the segment's primary "vector" column;
        # additional vector fields ride the extras columns under their own
        # names (same path as attributes), so multi-vector rows stay columnar
        # end to end (WAL -> growing segment -> binlog).
        vec_fields = info.schema.vector_fields()
        vec_field = vec_fields[0].name
        extra_names = [
            f.name for f in info.schema.attribute_fields() if f.name in rows
        ]
        extra_vec_names = [f.name for f in vec_fields[1:] if f.name in rows]
        for shard in np.unique(shards):
            sel = shards == shard
            count = int(sel.sum())
            segment_id = self.data_coord.assign_segment(info.name, int(shard), count)
            extras = {f: np.asarray(rows[f])[sel] for f in extra_names}
            extras.update(
                {f: np.asarray(rows[f], np.float32)[sel] for f in extra_vec_names}
            )
            payload = {
                "collection": info.name,
                "shard": int(shard),
                "segment_id": segment_id,
                "pk": pks[sel],
                "vector": np.asarray(rows[vec_field], np.float32)[sel],
                "extras": extras,
            }
            self.broker.publish(
                dml_channel(info.name, int(shard)),
                LogEntry(ts=lsn, type=EntryType.INSERT, payload=payload),
            )
        return lsn, n

    def delete(self, info: CollectionInfo, pks: np.ndarray) -> int:
        if not self.alive:
            raise RuntimeError(f"logger {self.logger_id} is down")
        lsn = self.tso.next()
        pks = np.asarray(pks)
        shards = np.array([shard_of_pk(pk, info.num_shards) for pk in pks.tolist()])
        for shard in np.unique(shards):
            sel = shards == shard
            self.broker.publish(
                dml_channel(info.name, int(shard)),
                LogEntry(
                    ts=lsn,
                    type=EntryType.DELETE,
                    payload={"collection": info.name, "shard": int(shard), "pk": pks[sel]},
                ),
            )
        return lsn

    # ---------------------------------------------------------- time ticks
    def tick(self, channels: list[str], force: bool = False) -> int:
        """Emit time-ticks on owned channels if the interval elapsed."""
        now = self.clock.now_ms()
        emitted = 0
        for ch in channels:
            last = self._last_tick_ms.get(ch, -1e18)
            if force or (now - last) >= self.tick_interval_ms:
                ts = self.tso.next()
                self.broker.publish(
                    ch, LogEntry(ts=ts, type=EntryType.TIME_TICK, payload={})
                )
                self._last_tick_ms[ch] = now
                emitted += 1
        return emitted
