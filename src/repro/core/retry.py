"""Typed retry/timeout/backoff plane (paper §6.3, robustness PR).

Manu's components talk to three external substrates — the object store
(S3/MinIO), the meta store (etcd) and the log broker (Kafka/Pulsar) — all of
which fail transiently in production.  This module defines the exception
taxonomy that separates *retryable* faults from fatal ones, a seeded
``RetryPolicy`` (jittered exponential backoff with an attempt budget), and
retrying wrappers for the object/meta store boundaries so every data, index,
compaction and GC path absorbs transient I/O errors instead of crashing.

The taxonomy is the contract with ``core/faults.py``: the fault injector
raises exactly these types, and anything *not* in the taxonomy (including an
injected ``Crash``) must propagate — a retry loop must never eat a process
kill or a genuine logic error.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Any, Callable

from .object_store import ObjectStore

# --------------------------------------------------------------------------
# Exception taxonomy
# --------------------------------------------------------------------------


class TransientError(RuntimeError):
    """Base class for retryable infrastructure faults."""


class TransientStoreError(TransientError):
    """Object-store put/get/delete/list failed transiently (S3 5xx, timeout)."""


class TransientMetaError(TransientError):
    """Meta-store RPC failed transiently (etcd unavailable, leader election)."""


class TransientLogError(TransientError):
    """Log-broker publish/read failed transiently (broker rebalance, timeout)."""


class RetryExhaustedError(RuntimeError):
    """The attempt budget ran out; carries the last transient error."""

    def __init__(self, site: str, attempts: int, last: BaseException):
        super().__init__(
            f"retry budget exhausted at {site} after {attempts} attempts: {last!r}"
        )
        self.site = site
        self.attempts = attempts
        self.last = last


# --------------------------------------------------------------------------
# Policy
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Jittered exponential backoff with a bounded attempt budget.

    Deterministic: jitter comes from a ``random.Random(seed)`` owned by the
    caller (each wrapper keeps its own), so a seeded chaos run replays
    bit-for-bit.  ``sleep`` is pluggable — cooperative (ManualClock) systems
    pass ``None`` and backoff is accounting-only, threaded systems pass
    ``time.sleep``.
    """

    max_attempts: int = 6
    base_delay_ms: float = 1.0
    multiplier: float = 2.0
    max_delay_ms: float = 64.0
    jitter: float = 0.5  # +/- fraction of the computed delay
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError("jitter must be in [0, 1]")

    def delay_ms(self, attempt: int, rng: random.Random) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        raw = min(
            self.base_delay_ms * (self.multiplier ** (attempt - 1)),
            self.max_delay_ms,
        )
        if self.jitter:
            raw *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return max(raw, 0.0)


class _Retrier:
    """Shared engine for the retrying wrappers below."""

    def __init__(
        self,
        policy: RetryPolicy,
        *,
        metrics=None,
        event_log=None,
        sleep: Callable[[float], None] | None = None,
        retry_on: tuple[type, ...] = (TransientError,),
    ):
        self.policy = policy
        self.metrics = metrics
        self.event_log = event_log
        self.sleep = sleep
        self.retry_on = retry_on
        self.rng = random.Random(policy.seed)

    def run(self, site: str, fn: Callable[[], Any]) -> Any:
        last: BaseException | None = None
        for attempt in range(1, self.policy.max_attempts + 1):
            try:
                out = fn()
                if attempt > 1:
                    if self.metrics is not None:
                        self.metrics.inc(
                            "retry_recovered_total", labels={"site": site}
                        )
                return out
            except self.retry_on as exc:  # fatal types propagate untouched
                last = exc
                if self.metrics is not None:
                    self.metrics.inc("retry_attempts_total", labels={"site": site})
                if attempt == self.policy.max_attempts:
                    break
                delay = self.policy.delay_ms(attempt, self.rng)
                if self.sleep is not None and delay > 0:
                    self.sleep(delay / 1e3)
        if self.metrics is not None:
            self.metrics.inc("retry_exhausted_total", labels={"site": site})
        if self.event_log is not None:
            self.event_log.emit(
                "retry_exhausted",
                source="retry",
                site=site,
                attempts=self.policy.max_attempts,
                error=repr(last),
            )
        raise RetryExhaustedError(site, self.policy.max_attempts, last)


# --------------------------------------------------------------------------
# Retrying wrappers
# --------------------------------------------------------------------------


class RetryingObjectStore(ObjectStore):
    """Wraps any ``ObjectStore`` with the retry policy.

    Composed *outside* a ``FaultyObjectStore`` so retries absorb injected
    transients: ``RetryingObjectStore(FaultyObjectStore(real, inj), policy)``.
    Unknown attributes (``put_count`` etc.) delegate to the inner store.
    """

    def __init__(
        self,
        inner: ObjectStore,
        policy: RetryPolicy | None = None,
        *,
        metrics=None,
        event_log=None,
        sleep: Callable[[float], None] | None = None,
    ):
        self.inner = inner
        self._retrier = _Retrier(
            policy or RetryPolicy(), metrics=metrics, event_log=event_log, sleep=sleep
        )

    # -- retried I/O ------------------------------------------------------
    def put(self, key: str, data: bytes):
        return self._retrier.run("object_store.put", lambda: self.inner.put(key, data))

    def get(self, key: str) -> bytes:
        return self._retrier.run("object_store.get", lambda: self.inner.get(key))

    def exists(self, key: str) -> bool:
        return self._retrier.run("object_store.exists", lambda: self.inner.exists(key))

    def delete(self, key: str) -> bool:
        return self._retrier.run("object_store.delete", lambda: self.inner.delete(key))

    def list(self, prefix: str = ""):
        # materialized so transient errors surface inside the retry scope
        return self._retrier.run(
            "object_store.list", lambda: list(self.inner.list(prefix))
        )

    def __getattr__(self, name: str):
        return getattr(self.inner, name)


class RetryingMetaStore:
    """Duck-typed retrying wrapper over ``MetaStore``.

    Only genuine RPC transients are retried; a ``cas`` that returns ``False``
    is a *semantic* conflict (someone else won the race) and flows back to the
    caller's CAS loop untouched.
    """

    def __init__(
        self,
        inner,
        policy: RetryPolicy | None = None,
        *,
        metrics=None,
        event_log=None,
        sleep: Callable[[float], None] | None = None,
    ):
        self.inner = inner
        self._retrier = _Retrier(
            policy or RetryPolicy(), metrics=metrics, event_log=event_log, sleep=sleep
        )

    def put(self, key, value, lease_id=None):
        return self._retrier.run(
            "meta.put", lambda: self.inner.put(key, value, lease_id=lease_id)
        )

    def get(self, key, default=None):
        return self._retrier.run("meta.get", lambda: self.inner.get(key, default))

    def get_rev(self, key):
        return self._retrier.run("meta.get_rev", lambda: self.inner.get_rev(key))

    def delete(self, key):
        return self._retrier.run("meta.delete", lambda: self.inner.delete(key))

    def cas(self, key, expected_rev, value):
        return self._retrier.run(
            "meta.cas", lambda: self.inner.cas(key, expected_rev, value)
        )

    def scan(self, prefix):
        return self._retrier.run("meta.scan", lambda: self.inner.scan(prefix))

    def __getattr__(self, name: str):
        return getattr(self.inner, name)


class RetryingLogBroker:
    """Duck-typed retrying wrapper over ``LogBroker``.

    Covers every publisher and subscriber in one place (coordinators, nodes,
    ``Subscription`` cursors all go through the broker handle).  Retrying a
    publish is safe here because an injected transient raises *before* the
    inner append lands — a failed attempt never half-publishes; real brokers
    get the same property from idempotent producers.
    """

    def __init__(
        self,
        inner,
        policy: RetryPolicy | None = None,
        *,
        metrics=None,
        event_log=None,
        sleep: Callable[[float], None] | None = None,
    ):
        self.inner = inner
        self._retrier = _Retrier(
            policy or RetryPolicy(), metrics=metrics, event_log=event_log, sleep=sleep
        )

    def publish(self, channel, entry):
        return self._retrier.run(
            "log.publish", lambda: self.inner.publish(channel, entry)
        )

    def read(self, channel, from_position, max_entries=None):
        return self._retrier.run(
            "log.read", lambda: self.inner.read(channel, from_position, max_entries)
        )

    def __getattr__(self, name: str):
        return getattr(self.inner, name)


def default_sleep(threaded: bool) -> Callable[[float], None] | None:
    """Backoff sleeper: real sleep in threaded mode, accounting-only otherwise."""
    return time.sleep if threaded else None
