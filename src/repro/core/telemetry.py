"""Zero-dependency observability plane: metrics, traces, events.

Three cooperating primitives, all process-local and allocation-light:

``MetricsRegistry``
    Counters, gauges, and fixed log-bucket histograms.  Histograms are
    backed by a single numpy count array per series; recording a value
    is two array ops (bucket index + in-place add), and percentile
    read-out interpolates within the winning bucket.  ``snapshot()``
    produces plain-Python rows (see ``core.request.MetricsSnapshot``)
    and ``export()`` renders Prometheus text format.

``TraceContext`` / ``Span`` / ``RequestTrace``
    Per-request span trees.  A context is allocated at the proxy only
    when ``SearchRequest(trace=True)`` — every hot-path call site guards
    with ``if trace is not None`` so the disabled cost is one branch.
    Durations use an injectable ``perf_counter``; tracing carries node
    ids, segment ids, and rows scanned so chaos tests can assert the
    tree bit-for-bit matches what was executed.

``EventLog``
    Bounded ring of typed control-plane events (node death, CAS
    retries, drain steps, hot-swaps, GC reaps, pump progress).  Event
    timestamps come from an injectable clock — the system's manual
    clock in cooperative tests — so event history is deterministic.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "MetricsRegistry",
    "Histogram",
    "TraceContext",
    "Span",
    "RequestTrace",
    "Event",
    "EventLog",
]


# --------------------------------------------------------------------------
# histograms
# --------------------------------------------------------------------------

# Fixed log-spaced bucket edges shared by every histogram: 60 buckets per
# decade-ish span covering 1us .. ~100s when values are in microseconds
# (and equally serviceable for row counts).  A shared layout keeps each
# series to one small int64 array and makes merging snapshots trivial.
_N_BUCKETS = 64
_LOG_LO = 0.0   # log10(1.0)
_LOG_HI = 8.0   # log10(1e8)
_LOG_STEP = (_LOG_HI - _LOG_LO) / _N_BUCKETS
# Upper edge of each bucket (bucket i covers (edge[i-1], edge[i]]).
BUCKET_EDGES = np.logspace(
    _LOG_LO + _LOG_STEP, _LOG_HI, _N_BUCKETS, dtype=np.float64
)


class Histogram:
    """Fixed log-bucket histogram: record is ~two numpy ops."""

    __slots__ = ("name", "counts", "total", "sum")

    def __init__(self, name: str) -> None:
        self.name = name
        self.counts = np.zeros(_N_BUCKETS, dtype=np.int64)
        self.total = 0
        self.sum = 0.0

    def record(self, value: float) -> None:
        if value < 1.0:
            idx = 0
        else:
            idx = min(int(math.log10(value) / _LOG_STEP), _N_BUCKETS - 1)
        self.counts[idx] += 1
        self.total += 1
        self.sum += value

    def record_many(self, values: np.ndarray) -> None:
        v = np.asarray(values, dtype=np.float64)
        if v.size == 0:
            return
        idx = np.clip(
            (np.log10(np.maximum(v, 1.0)) / _LOG_STEP).astype(np.int64),
            0,
            _N_BUCKETS - 1,
        )
        np.add.at(self.counts, idx, 1)
        self.total += int(v.size)
        self.sum += float(v.sum())

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (0..100) by bucket interpolation."""
        if self.total == 0:
            return 0.0
        rank = q / 100.0 * self.total
        cum = np.cumsum(self.counts)
        idx = int(np.searchsorted(cum, rank, side="left"))
        idx = min(idx, _N_BUCKETS - 1)
        hi = BUCKET_EDGES[idx]
        lo = BUCKET_EDGES[idx - 1] if idx > 0 else 0.0
        in_bucket = int(self.counts[idx])
        if in_bucket == 0:
            return float(hi)
        below = int(cum[idx]) - in_bucket
        frac = (rank - below) / in_bucket
        return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------


class MetricsRegistry:
    """Process-local named counters, gauges, and histograms.

    Series names follow Prometheus convention (``snake_case``); an
    optional ``labels`` dict is folded into the series key so e.g.
    per-node counters stay one dict lookup on the hot path.
    """

    def __init__(self) -> None:
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._histograms: dict[str, Histogram] = {}

    # -- series key ----------------------------------------------------
    @staticmethod
    def _key(name: str, labels: dict | None) -> str:
        if not labels:
            return name
        inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
        return f"{name}{{{inner}}}"

    # -- recording -----------------------------------------------------
    def inc(self, name: str, value: float = 1.0, labels: dict | None = None) -> None:
        key = self._key(name, labels)
        self._counters[key] = self._counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float, labels: dict | None = None) -> None:
        self._gauges[self._key(name, labels)] = float(value)

    def observe(self, name: str, value: float, labels: dict | None = None) -> None:
        key = self._key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(key)
        h.record(value)

    def histogram(self, name: str, labels: dict | None = None) -> Histogram:
        key = self._key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(key)
        return h

    # -- read-out ------------------------------------------------------
    def counter_value(self, name: str, labels: dict | None = None) -> float:
        return self._counters.get(self._key(name, labels), 0.0)

    def gauge_value(self, name: str, labels: dict | None = None) -> float:
        return self._gauges.get(self._key(name, labels), 0.0)

    def snapshot_rows(self):
        """Return (counters, gauges, histogram rows) in plain Python.

        Histogram rows are tuples ``(name, count, mean, p50, p95, p99)``.
        The typed wrapper lives in ``core.request.MetricsSnapshot``.
        """
        counters = dict(sorted(self._counters.items()))
        gauges = dict(sorted(self._gauges.items()))
        hists = []
        for key in sorted(self._histograms):
            h = self._histograms[key]
            hists.append(
                (
                    key,
                    int(h.total),
                    float(h.mean),
                    float(h.percentile(50)),
                    float(h.percentile(95)),
                    float(h.percentile(99)),
                )
            )
        return counters, gauges, hists

    def export(self) -> str:
        """Render all series in Prometheus text exposition format."""
        lines: list[str] = []
        seen_meta: set[str] = set()

        def base_name(key: str) -> str:
            return key.split("{", 1)[0]

        for key, v in sorted(self._counters.items()):
            base = base_name(key)
            if base not in seen_meta:
                lines.append(f"# TYPE {base} counter")
                seen_meta.add(base)
            lines.append(f"{key} {v:g}")
        for key, v in sorted(self._gauges.items()):
            base = base_name(key)
            if base not in seen_meta:
                lines.append(f"# TYPE {base} gauge")
                seen_meta.add(base)
            lines.append(f"{key} {v:g}")
        for key in sorted(self._histograms):
            h = self._histograms[key]
            base = base_name(key)
            if base not in seen_meta:
                lines.append(f"# TYPE {base} summary")
                seen_meta.add(base)
            for q in (50, 95, 99):
                qkey = (
                    f'{base}{{quantile="0.{q}"}}'
                    if "{" not in key
                    else key[:-1] + f',quantile="0.{q}"}}'
                )
                lines.append(f"{qkey} {h.percentile(q):g}")
            lines.append(f"{key}_sum {h.sum:g}")
            lines.append(f"{key}_count {h.total}")
        return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# tracing
# --------------------------------------------------------------------------


@dataclass
class Span:
    """One timed step of a request: a node-side scan, a hedge, a reduce."""

    name: str
    node_id: str | None = None
    segment_ids: tuple[int, ...] = ()
    duration_us: float = 0.0
    rows_scanned: int = 0
    detail: str = ""
    children: list["Span"] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "node_id": self.node_id,
            "segment_ids": [int(s) for s in self.segment_ids],
            "duration_us": float(self.duration_us),
            "rows_scanned": int(self.rows_scanned),
            "detail": self.detail,
            "children": [c.to_dict() for c in self.children],
        }


@dataclass
class RequestTrace:
    """The finished span tree attached to SearchResult/MutationResult."""

    request_id: int
    kind: str  # "search" | "mutation"
    root: Span

    def walk(self):
        stack = [self.root]
        while stack:
            s = stack.pop()
            yield s
            stack.extend(reversed(s.children))

    def spans_named(self, name: str) -> list[Span]:
        return [s for s in self.walk() if s.name == name]

    def to_dict(self) -> dict:
        return {
            "request_id": int(self.request_id),
            "kind": self.kind,
            "root": self.root.to_dict(),
        }

    def format(self) -> str:
        lines: list[str] = []

        def fmt(span: Span, depth: int) -> None:
            pad = "  " * depth
            bits = [f"{pad}{span.name}"]
            if span.node_id:
                bits.append(f"node={span.node_id}")
            if span.segment_ids:
                bits.append(f"segments={list(span.segment_ids)}")
            if span.rows_scanned:
                bits.append(f"rows={span.rows_scanned}")
            bits.append(f"{span.duration_us:.0f}us")
            if span.detail:
                bits.append(span.detail)
            lines.append(" ".join(bits))
            for c in span.children:
                fmt(c, depth + 1)

        fmt(self.root, 0)
        return "\n".join(lines)


class TraceContext:
    """Mutable trace builder threaded through one request.

    Hot paths hold ``trace: TraceContext | None`` and guard every use
    with ``if trace is not None`` — no object is allocated when tracing
    is off.  ``perf_counter`` is injectable for deterministic tests.
    """

    _next_id = 0

    __slots__ = ("request_id", "kind", "root", "perf_counter")

    def __init__(self, kind: str, perf_counter=time.perf_counter) -> None:
        TraceContext._next_id += 1
        self.request_id = TraceContext._next_id
        self.kind = kind
        self.root = Span(name=kind)
        self.perf_counter = perf_counter

    def span(
        self,
        name: str,
        parent: Span | None = None,
        node_id: str | None = None,
        segment_ids=(),
        detail: str = "",
    ) -> Span:
        s = Span(
            name=name,
            node_id=node_id,
            segment_ids=tuple(int(x) for x in segment_ids),
            detail=detail,
        )
        (parent if parent is not None else self.root).children.append(s)
        return s

    def timed(self, span: Span):
        """Context manager stamping ``duration_us`` on exit."""
        return _SpanTimer(span, self.perf_counter)

    def finish(self, duration_us: float) -> RequestTrace:
        self.root.duration_us = duration_us
        return RequestTrace(request_id=self.request_id, kind=self.kind, root=self.root)


class _SpanTimer:
    __slots__ = ("span", "perf_counter", "t0")

    def __init__(self, span: Span, perf_counter) -> None:
        self.span = span
        self.perf_counter = perf_counter

    def __enter__(self) -> Span:
        self.t0 = self.perf_counter()
        return self.span

    def __exit__(self, *exc) -> None:
        self.span.duration_us = (self.perf_counter() - self.t0) * 1e6


# --------------------------------------------------------------------------
# control-plane event log
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Event:
    """One typed control-plane event."""

    ts_ms: float
    kind: str
    source: str
    detail: dict

    def to_dict(self) -> dict:
        return {
            "ts_ms": float(self.ts_ms),
            "kind": self.kind,
            "source": self.source,
            "detail": {k: _jsonable(v) for k, v in self.detail.items()},
        }


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (set, frozenset)):
        return sorted(_jsonable(x) for x in v)
    return v


class EventLog:
    """Bounded ring buffer of control-plane events.

    ``clock`` supplies timestamps (``now_ms()``) — the system's manual
    clock in cooperative tests, wall clock in threaded mode — so event
    history is deterministic where the system is.
    """

    def __init__(self, clock, capacity: int = 4096) -> None:
        self.clock = clock
        self.capacity = capacity
        self._events: list[Event] = []
        self.dropped = 0

    def emit(self, kind: str, source: str, **detail) -> Event:
        ev = Event(
            ts_ms=float(self.clock.now_ms()),
            kind=kind,
            source=source,
            detail=detail,
        )
        self._events.append(ev)
        if len(self._events) > self.capacity:
            overflow = len(self._events) - self.capacity
            del self._events[:overflow]
            self.dropped += overflow
        return ev

    def query(self, since_ts: float | None = None, kind: str | None = None) -> list[Event]:
        out = self._events
        if since_ts is not None:
            out = [e for e in out if e.ts_ms >= since_ts]
        if kind is not None:
            out = [e for e in out if e.kind == kind]
        return list(out)

    def __len__(self) -> int:
        return len(self._events)
