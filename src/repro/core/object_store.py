"""S3-like object store abstraction.

Manu persists binlogs, sealed segments, and index files in object storage
(S3 / MinIO / local FS).  We expose the minimal S3 verb surface —
put/get/list/delete/exists with ETags — behind one interface, with two
implementations:

* ``MemoryObjectStore`` — in-process dict; used by unit tests.
* ``FileObjectStore``   — directory-backed; objects are files under a root,
  keys map to paths.  Matches the paper's "object KV can be the local file
  system on personal computers, S3 on AWS" adaptability story.

Values are opaque ``bytes``.  Higher layers (binlog, index files, train
checkpoints) serialize with numpy ``.npz`` / msgpack-like headers on top.
"""

from __future__ import annotations

import hashlib
import os
import threading
from dataclasses import dataclass
from typing import Iterator


@dataclass(frozen=True)
class ObjectMeta:
    key: str
    size: int
    etag: str


class ObjectStore:
    """Abstract S3-like store."""

    def put(self, key: str, data: bytes) -> ObjectMeta:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def delete(self, key: str) -> bool:
        """Remove ``key``; True iff an object was actually deleted.

        Implementations keep reclamation counters (``delete_count``,
        ``bytes_deleted``) covering only *real* removals, so GC benches and
        tests can assert reclaimed bytes.
        """
        raise NotImplementedError

    def list(self, prefix: str = "") -> Iterator[ObjectMeta]:
        raise NotImplementedError

    # -- convenience -------------------------------------------------------
    def get_or_none(self, key: str) -> bytes | None:
        return self.get(key) if self.exists(key) else None

    def copy(self, src: str, dst: str) -> ObjectMeta:
        return self.put(dst, self.get(src))


def _etag(data: bytes) -> str:
    return hashlib.md5(data).hexdigest()


class MemoryObjectStore(ObjectStore):
    def __init__(self) -> None:
        self._objects: dict[str, bytes] = {}
        self._lock = threading.RLock()
        self.put_count = 0
        self.get_count = 0
        self.delete_count = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.bytes_deleted = 0

    def put(self, key: str, data: bytes) -> ObjectMeta:
        if not isinstance(data, (bytes, bytearray)):
            raise TypeError(f"object value must be bytes, got {type(data)}")
        with self._lock:
            self._objects[key] = bytes(data)
            self.put_count += 1
            self.bytes_written += len(data)
            return ObjectMeta(key, len(data), _etag(data))

    def get(self, key: str) -> bytes:
        with self._lock:
            if key not in self._objects:
                raise KeyError(f"object not found: {key}")
            data = self._objects[key]
            self.get_count += 1
            self.bytes_read += len(data)
            return data

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def delete(self, key: str) -> bool:
        with self._lock:
            data = self._objects.pop(key, None)
            if data is None:
                return False
            self.delete_count += 1
            self.bytes_deleted += len(data)
            return True

    def list(self, prefix: str = "") -> Iterator[ObjectMeta]:
        with self._lock:
            keys = sorted(k for k in self._objects if k.startswith(prefix))
            metas = [ObjectMeta(k, len(self._objects[k]), _etag(self._objects[k])) for k in keys]
        yield from metas


class FileObjectStore(ObjectStore):
    """Objects as files under ``root``.  Keys may contain '/'."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.RLock()
        self._tmp_seq = 0
        self.put_count = 0
        self.get_count = 0
        self.delete_count = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self.bytes_deleted = 0

    def _path(self, key: str) -> str:
        if ".." in key.split("/"):
            raise ValueError(f"illegal key: {key}")
        return os.path.join(self.root, key)

    def put(self, key: str, data: bytes) -> ObjectMeta:
        path = self._path(key)
        with self._lock:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            # Unique tmp name per put: a crash mid-write can only ever strand
            # a private .tmp file (skipped by list/get), never tear the
            # published object, and concurrent puts of one key can't collide
            # on the staging file.  os.replace is the atomic commit point.
            self._tmp_seq += 1
            tmp = f"{path}.{os.getpid()}.{self._tmp_seq}.tmp"
            try:
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, path)  # atomic publish, like S3 PUT
            except BaseException:
                try:
                    os.remove(tmp)
                except OSError:
                    pass
                raise
            self.put_count += 1
            self.bytes_written += len(data)
        return ObjectMeta(key, len(data), _etag(data))

    def get(self, key: str) -> bytes:
        path = self._path(key)
        if not os.path.isfile(path):
            raise KeyError(f"object not found: {key}")
        with open(path, "rb") as f:
            data = f.read()
        with self._lock:
            self.get_count += 1
            self.bytes_read += len(data)
        return data

    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def delete(self, key: str) -> bool:
        path = self._path(key)
        with self._lock:
            try:
                size = os.path.getsize(path)
                os.remove(path)
            except FileNotFoundError:
                return False
            self.delete_count += 1
            self.bytes_deleted += size
            return True

    def list(self, prefix: str = "") -> Iterator[ObjectMeta]:
        out = []
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for fn in filenames:
                if fn.endswith(".tmp"):
                    continue
                full = os.path.join(dirpath, fn)
                key = os.path.relpath(full, self.root).replace(os.sep, "/")
                if key.startswith(prefix):
                    out.append(ObjectMeta(key, os.path.getsize(full), ""))
        yield from sorted(out, key=lambda m: m.key)
