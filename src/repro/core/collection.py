"""Schema and collection metadata (paper §3.1).

Basic data types: vector, string, boolean, integer, float.  An entity has a
primary key, one or more feature vectors, optional labels (categorical) and
numerical attributes, plus the hidden LSN system field.  Collections have no
relations to each other (no joins).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Any

import numpy as np


class FieldType(Enum):
    VECTOR = "vector"
    INT = "int"
    FLOAT = "float"
    STRING = "string"
    BOOL = "bool"


class Metric(Enum):
    L2 = "l2"
    IP = "ip"
    COSINE = "cosine"


@dataclass(frozen=True)
class FieldSchema:
    name: str
    dtype: FieldType
    dim: int = 0  # vectors only
    is_primary: bool = False

    def __post_init__(self):
        if self.dtype is FieldType.VECTOR and self.dim <= 0:
            raise ValueError(f"vector field '{self.name}' needs dim > 0")
        if self.is_primary and self.dtype not in (FieldType.INT, FieldType.STRING):
            raise ValueError("primary key must be int or string")

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "dtype": self.dtype.value,
            "dim": self.dim,
            "is_primary": self.is_primary,
        }

    @staticmethod
    def from_dict(d: dict) -> "FieldSchema":
        return FieldSchema(
            d["name"],
            FieldType(d["dtype"]),
            dim=int(d.get("dim", 0)),
            is_primary=bool(d.get("is_primary", False)),
        )


@dataclass(frozen=True)
class Schema:
    fields: tuple[FieldSchema, ...]

    def __post_init__(self):
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError("duplicate field names")
        if sum(f.is_primary for f in self.fields) > 1:
            raise ValueError("at most one primary key")
        if not self.vector_fields():
            raise ValueError("schema needs at least one vector field")

    def primary(self) -> FieldSchema | None:
        for f in self.fields:
            if f.is_primary:
                return f
        return None

    def vector_fields(self) -> list[FieldSchema]:
        return [f for f in self.fields if f.dtype is FieldType.VECTOR]

    def field(self, name: str) -> FieldSchema:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(f"no field '{name}'")

    def attribute_fields(self) -> list[FieldSchema]:
        return [
            f
            for f in self.fields
            if not f.is_primary and f.dtype is not FieldType.VECTOR
        ]

    def to_dict(self) -> dict:
        """Durable form, so a restarted system can reconstruct collections
        purely from the meta store."""
        return {"fields": [f.to_dict() for f in self.fields]}

    @staticmethod
    def from_dict(d: dict) -> "Schema":
        return Schema(tuple(FieldSchema.from_dict(f) for f in d["fields"]))

    @staticmethod
    def simple(dim: int, metric: Metric = Metric.L2, extra: list[FieldSchema] | None = None) -> "Schema":
        """The common case: int PK + one vector field (+ extras)."""
        fields = [
            FieldSchema("pk", FieldType.INT, is_primary=True),
            FieldSchema("vector", FieldType.VECTOR, dim=dim),
        ]
        fields.extend(extra or [])
        return Schema(tuple(fields))


@dataclass
class CollectionInfo:
    """Coordinator-side collection metadata (lives in the meta store)."""

    name: str
    schema: Schema
    num_shards: int
    metric: Metric = Metric.L2
    created_ts: int = 0
    index_specs: dict[str, dict[str, Any]] = field(default_factory=dict)
    dropped: bool = False
    replication_factor: int = 1

    def dim(self, vector_field: str = "vector") -> int:
        return self.schema.field(vector_field).dim


def validate_rows(schema: Schema, rows: dict[str, np.ndarray]) -> int:
    """Validate one insert batch against the schema; returns row count.

    Rejects unknown field names outright — a typo'd column must fail the
    request, not silently vanish from the batch."""
    if not rows:
        raise ValueError("empty insert batch (no fields)")
    known = {f.name for f in schema.fields}
    stray = sorted(set(rows) - known)
    if stray:
        raise ValueError(
            f"unknown field(s) {stray} in insert batch; schema has {sorted(known)}"
        )
    n = None
    for f in schema.fields:
        if f.name not in rows:
            if f.is_primary:
                continue  # auto-assigned PK allowed
            raise ValueError(f"missing field '{f.name}' in insert batch")
        arr = rows[f.name]
        if n is None:
            n = len(arr)
        elif len(arr) != n:
            raise ValueError(f"field '{f.name}' length {len(arr)} != {n}")
        if f.dtype is FieldType.VECTOR:
            if arr.ndim != 2 or arr.shape[1] != f.dim:
                raise ValueError(
                    f"vector field '{f.name}' must be (n,{f.dim}), got {arr.shape}"
                )
    if n is None:
        raise ValueError("empty insert batch")
    return n
