# Manu's primary contribution lives here: the log backbone (WAL + binlog +
# time-ticks), delta consistency, segments with MVCC, the decoupled
# coordinator/worker services, and the PyManu-style public API.
from .collection import FieldSchema, FieldType, Metric, Schema
from .compaction import CompactionCoordinator, CompactionNode, GCReaper
from .consistency import ConsistencyLevel, GuaranteeTs
from .manu import ManuCollection, ManuConfig, ManuSystem
from .request import (
    AnnsQuery,
    ClusterState,
    DeleteRequest,
    DescribeCollection,
    HistogramRow,
    IndexDescription,
    InsertRequest,
    MetricsSnapshot,
    MutationRequest,
    MutationResult,
    NodeStatus,
    Ranker,
    SearchRequest,
    SegmentPlacement,
    UpsertRequest,
)
from .segment import DEFAULT_PARTITION
from .telemetry import (
    Event,
    EventLog,
    Histogram,
    MetricsRegistry,
    RequestTrace,
    Span,
    TraceContext,
)
from .timestamp import TSO, Clock, ManualClock

__all__ = [
    "DEFAULT_PARTITION",
    "DeleteRequest",
    "InsertRequest",
    "MutationRequest",
    "MutationResult",
    "UpsertRequest",
    "FieldSchema",
    "FieldType",
    "Metric",
    "Schema",
    "CompactionCoordinator",
    "CompactionNode",
    "GCReaper",
    "ConsistencyLevel",
    "GuaranteeTs",
    "AnnsQuery",
    "Ranker",
    "SearchRequest",
    "ClusterState",
    "NodeStatus",
    "SegmentPlacement",
    "DescribeCollection",
    "HistogramRow",
    "IndexDescription",
    "MetricsSnapshot",
    "ManuCollection",
    "ManuConfig",
    "ManuSystem",
    "Event",
    "EventLog",
    "Histogram",
    "MetricsRegistry",
    "RequestTrace",
    "Span",
    "TraceContext",
    "TSO",
    "Clock",
    "ManualClock",
]
