"""Compaction & garbage collection: maintenance as log subscribers.

Manu's binlog is the *base* part of the data and segments are the unit of
placement (paper §3.3, §3.6); maintenance is therefore "just another
subscriber" of the log services:

* The **compaction coordinator** watches the coord channel for sealed
  segments and the DML channels for delete tombstones, applies the policy
  (delete-ratio threshold, small-segment merging up to the seal size), and
  publishes ``compaction_task`` messages on the coord channel.  It also
  owns the versioned segment-mapping epoch in the meta store and the
  tombstone/time-travel retention horizon.
* Stateless **compaction nodes** claim tasks with a meta-store CAS
  (mirroring the index nodes), read the source segments' columnar binlogs,
  fold the delta-delete tombstones in with vectorized mask/gather column
  rewrites, write the merged/purged binlog back to the object store, and
  announce ``segment_compacted``.
* The **GC reaper** deletes binlog/index objects of segments retired
  before the retention horizon, skipping anything still referenced by a
  time-travel checkpoint, and announces ``segment_gc`` so coordinators
  drop their metadata.

MVCC through the swap: the query coordinator loads the rewrite gated at
``compact_ts`` and retires the sources at the same timestamp, so a query
pinned at a pre-compaction ``ts`` keeps reading the old versions (and the
tombstones they need) until ``retention_advance`` moves the horizon past
the swap.
"""

from __future__ import annotations

import numpy as np

from ..kernels import ops
from .binlog import (
    read_binlog_column,
    read_binlog_meta,
    write_attr_satellites,
    write_segment_binlog,
)
from .log import COORD_CHANNEL, EntryType, LogBroker, LogEntry, Subscription
from .meta_store import MetaStore, SegmentMap
from .object_store import ObjectStore
from .segment import DEFAULT_PARTITION, Segment, add_tombstone, flatten_tombstones
from .telemetry import EventLog, MetricsRegistry
from .timestamp import TSO

DEFAULT_DELETE_RATIO = 0.2
DEFAULT_SMALL_FRACTION = 0.5
MAX_TASK_SEAL_FACTOR = 4  # one task rewrites at most this many seals of rows


def prune_folded(dd: dict, folded_pks: np.ndarray, compact_ts: int) -> dict | None:
    """Drop tombstones folded into a compaction from a pk->delete-ts map
    (values may be a bare ts or a sorted ts list — upsert histories).

    A tombstone dies iff its pk was rewritten out (``folded_pks``, sorted)
    AND its delete predates the swap (``dts <= compact_ts``); later deletes
    of the same pk and tombstones for other segments survive.  Returns the
    pruned dict, or None when nothing changed.  Shared by the query nodes'
    retention handler and the compaction coordinator so the two tombstone
    views can never drift apart.
    """
    folded_pks = np.asarray(folded_pks)
    if not dd or folded_pks.size == 0:
        return None
    pks, dts = flatten_tombstones(dd)
    kill = ops.isin_sorted(pks, folded_pks) & (dts <= compact_ts)
    if not kill.any():
        return None
    out: dict = {}
    for pk, t, dead in zip(pks.tolist(), dts.tolist(), kill.tolist()):
        if not dead:
            add_tombstone(out, pk, t)
    return out


# ---------------------------------------------------------------------------
# Coordinator: policy + task fan-out + epoch bookkeeping
# ---------------------------------------------------------------------------


class CompactionCoordinator:
    """Decides *what* to compact; the nodes decide *who* does it (CAS)."""

    def __init__(
        self,
        broker: LogBroker,
        meta: MetaStore,
        tso: TSO,
        data_coord,
        store: ObjectStore,
        delete_ratio: float = DEFAULT_DELETE_RATIO,
        small_fraction: float = DEFAULT_SMALL_FRACTION,
        retention_ms: float = 0.0,
        events: EventLog | None = None,
    ):
        self.broker = broker
        self.meta = meta
        self.tso = tso
        self.data_coord = data_coord
        self.store = store
        self.delete_ratio = delete_ratio
        self.small_fraction = small_fraction
        self.retention_ms = retention_ms
        self.events = events
        self.sub = Subscription(broker, COORD_CHANNEL)
        self._dml_subs: dict[str, Subscription] = {}
        # collection -> pk -> delete ts (ts list for repeated deletes) —
        # the coordinator's tombstone view, fed by subscribing to every DML
        # channel like any query node
        self.tombstones: dict[str, dict] = {}
        # (collection, segment_id) -> {"rows", "shard", "partition"}
        self.sealed: dict[tuple[str, int], dict] = {}
        # (collection, segment_id) -> (pk column, ts column) scoring cache
        self._seg_cols: dict[tuple[str, int], tuple[np.ndarray, np.ndarray]] = {}
        self.pending: dict[str, dict] = {}  # task_id -> task payload
        self._next_task = 1
        self.segment_map = SegmentMap(meta)
        self.compactions_completed = 0
        # LSN-keyed dedup: highest applied position per channel (at-least-
        # once broker; duplicate delivery is an injectable fault).
        self._applied_pos: dict[str, int] = {}

    # ------------------------------------------------------------------ log
    def _refresh_dml_subs(self) -> None:
        for ch in self.broker.channels("dml/"):
            if ch not in self._dml_subs:
                self._dml_subs[ch] = Subscription(self.broker, ch)

    def step(self) -> bool:
        progress = False
        self._refresh_dml_subs()
        for sub in self._dml_subs.values():
            watermark = self._applied_pos.get(sub.channel, -1)
            for entry in sub.poll():
                if entry.position <= watermark:
                    continue  # duplicate delivery: already applied this LSN
                watermark = entry.position
                if entry.type in (EntryType.DELETE, EntryType.UPSERT):
                    # an upsert's delete half is a tombstone like any other
                    # (row-ts aware: it only kills versions older than it)
                    p = entry.payload
                    dd = self.tombstones.setdefault(p["collection"], {})
                    for pk in np.asarray(p["pk"]).tolist():
                        add_tombstone(dd, pk, entry.ts)
                    progress = True
            self._applied_pos[sub.channel] = watermark
        watermark = self._applied_pos.get(COORD_CHANNEL, -1)
        for entry in self.sub.poll():
            if entry.position <= watermark:
                continue
            watermark = entry.position
            if entry.type is not EntryType.COORD:
                continue
            p = entry.payload
            msg = p.get("msg")
            if msg == "segment_sealed":
                self.sealed[(p["collection"], p["segment_id"])] = {
                    "rows": p["num_rows"],
                    "shard": p["shard"],
                    "partition": p.get("partition", DEFAULT_PARTITION),
                }
                progress = True
            elif msg == "compaction_task":
                progress |= self._on_task_replayed(p)
            elif msg == "segment_compacted":
                progress |= self._on_compacted(p)
            elif msg == "partition_dropped":
                for sid in p.get("segment_ids", ()):
                    self.sealed.pop((p["collection"], sid), None)
                    self._seg_cols.pop((p["collection"], sid), None)
                progress = True
        self._applied_pos[COORD_CHANNEL] = watermark
        return progress

    # ------------------------------------------------------------- recovery
    def _claim_key(self, coll: str, task_id: str) -> str:
        return f"compaction_claim/{coll}/{task_id}"

    def _is_done(self, coll: str, task_id: str) -> bool:
        claim = self.meta.get(self._claim_key(coll, task_id))
        return bool(claim and claim.get("done"))

    def _on_task_replayed(self, p: dict) -> bool:
        """A ``compaction_task`` read back from the coord channel.

        The publishing coordinator already holds it in ``pending``; a
        *restarted* coordinator (fresh subscription from position 0) rebuilds
        its in-flight task table from exactly these entries — the log is the
        durable task queue.  Completed tasks (done-marker on the claim) stay
        out of ``pending`` so their late ``segment_compacted`` replays take
        the idempotent view-only path.  The task-id sequence also resumes
        past every replayed id so new tasks never collide with old claims.
        """
        task_id = p["task_id"]
        try:
            seq = int(task_id.rsplit("-", 1)[1])
            self._next_task = max(self._next_task, seq + 1)
        except (IndexError, ValueError):
            pass
        if task_id in self.pending or self._is_done(p["collection"], task_id):
            return False
        self.pending[task_id] = dict(p)
        return True

    def clear_stale_claims(self, owner: str | None = None) -> int:
        """Release not-done claims (optionally only ``owner``'s) so pending
        tasks wedged behind a crashed node's claim become takeable again."""
        cleared = 0
        for key, claim in list(self.meta.scan("compaction_claim/").items()):
            if claim.get("done"):
                continue
            if owner is not None and claim.get("owner") != owner:
                continue
            task_id = key.rsplit("/", 1)[1]
            if task_id in self.pending:
                self.meta.delete(key)
                cleared += 1
        return cleared

    def _on_compacted(self, p: dict) -> bool:
        task = self.pending.pop(p["task_id"], None)
        if task is None:
            if self._is_done(p["collection"], p["task_id"]):
                # Replay/duplicate of a completed task: the durable writes
                # (retired_segment, segment map, data-coord meta) already
                # happened; refresh the in-memory view only.
                self._apply_compacted_view(p)
            return False
        coll = p["collection"]
        targets = list(p["segments"])  # [{"segment_id", "num_rows"}, ...]
        sources = list(p["sources"])
        partition = p.get("partition", DEFAULT_PARTITION)
        for sid in sources:
            self.meta.put(
                f"retired_segment/{coll}/{sid}",
                {
                    "retired_at_ts": p["compact_ts"],
                    "compacted_into": [t["segment_id"] for t in targets],
                },
            )
        self._apply_compacted_view(p)
        self.segment_map.apply(
            coll,
            add=[t["segment_id"] for t in targets],
            remove=sources,
            ts=p["compact_ts"],
        )
        self.data_coord.on_compacted(
            coll, sources, targets, partition,
            shard=p.get("shard", 0), compact_ts=p["compact_ts"],
            attr_fields=p.get("attr_fields"),
        )
        # Done-marker instead of deleting the claim: a restarted coordinator
        # or node replaying the coord channel can tell "completed" apart from
        # "never ran", so completed tasks are never re-executed.
        self.meta.put(
            self._claim_key(coll, p["task_id"]),
            {"owner": p.get("built_by"), "done": True},
        )
        self.compactions_completed += 1
        if self.events is not None:
            self.events.emit(
                "compaction_done", "compaction_coord",
                collection=coll, task_id=p["task_id"], sources=sources,
                targets=[t["segment_id"] for t in targets],
                rows_purged=p.get("rows_purged", 0),
            )
        return True

    def _apply_compacted_view(self, p: dict) -> None:
        """In-memory effects of a completed compaction (idempotent): swap
        sources for targets in the sealed table and prune folded tombstones
        (folded pks existed only in the rewritten sources, so the
        coordinator's view can drop them — same unbounded-growth fix as the
        query nodes')."""
        coll = p["collection"]
        partition = p.get("partition", DEFAULT_PARTITION)
        for sid in p["sources"]:
            self.sealed.pop((coll, sid), None)
            self._seg_cols.pop((coll, sid), None)
        for t in p["segments"]:
            self.sealed[(coll, t["segment_id"])] = {
                "rows": t["num_rows"],
                "shard": p["shard"],
                "partition": partition,
            }
        pruned = prune_folded(
            self.tombstones.get(coll) or {}, p["folded_pks"], p["compact_ts"]
        )
        if pruned is not None:
            self.tombstones[coll] = pruned

    def lag(self) -> int:
        """Unconsumed log entries across this coordinator's subscriptions."""
        return self.sub.lag() + sum(s.lag() for s in self._dml_subs.values())

    # --------------------------------------------------------------- policy
    def _cols_of(
        self, collection: str, segment_id: int
    ) -> tuple[np.ndarray, np.ndarray]:
        key = (collection, segment_id)
        cols = self._seg_cols.get(key)
        if cols is None:
            cols = (
                read_binlog_column(self.store, collection, segment_id, "pk"),
                read_binlog_column(self.store, collection, segment_id, "ts"),
            )
            self._seg_cols[key] = cols
        return cols

    def _doomed_now(self, collection: str):
        """(sorted pks, effective delete ts) of every current tombstone."""
        dd = self.tombstones.get(collection)
        if not dd:
            return None
        pks, dts = flatten_tombstones(dd)
        return ops.eff_tombstones(pks, dts, np.iinfo(np.int64).max)

    def plan(self, collection: str) -> list[dict]:
        """Evaluate the policy and publish the rewrite tasks.

        A segment becomes a rewrite candidate when >= ``delete_ratio`` of
        its rows are tombstoned (purge) or its live rows fall below
        ``small_fraction * seal_rows`` (fragment).  Candidates are grouped
        per (shard, partition) — a rewrite never crosses shard boundaries
        (delta deletes travel on per-shard DML channels, so a target must
        stay aligned with one channel's subscriber) nor partition
        boundaries (partitions are a placement surface the planner prunes
        on) — and packed into tasks of at most ``MAX_TASK_SEAL_FACTOR``
        seals of live rows; each task's output is repacked into seal-size
        target segments, so compaction simultaneously purges dead rows,
        merges fragments, and restores the uniform segment sizes the fused
        scan path batches best on.  A lone candidate with nothing to fold
        is left alone (rewriting it would churn forever).
        """
        seal_rows = self.data_coord.seal_rows_for(collection)
        busy = {
            sid
            for t in self.pending.values()
            if t["collection"] == collection
            for sid in t["sources"]
        }
        doomed = self._doomed_now(collection)
        # (shard, partition) -> [(segment_id, live, dead), ...]
        cands: dict[tuple[int, str], list[tuple[int, int, int]]] = {}
        for (coll, sid), info in sorted(self.sealed.items()):
            if coll != collection or sid in busy:
                continue
            rows = info["rows"]
            if rows == 0:
                continue
            if doomed is not None:
                pk_col, ts_col = self._cols_of(coll, sid)
                n_dead = int(
                    ops.tombstone_mask(pk_col, ts_col, doomed[0], doomed[1]).sum()
                )
            else:
                n_dead = 0
            if (
                n_dead / rows >= self.delete_ratio
                or rows - n_dead < self.small_fraction * seal_rows
            ):
                group_key = (info["shard"], info.get("partition", DEFAULT_PARTITION))
                cands.setdefault(group_key, []).append((sid, rows - n_dead, n_dead))

        tasks = []
        max_rows = MAX_TASK_SEAL_FACTOR * seal_rows
        for shard, partition in sorted(cands):
            group: list[tuple[int, int, int]] = []
            group_live = 0

            def emit_group():
                nonlocal group, group_live
                if group and (len(group) >= 2 or any(d for _s, _l, d in group)):
                    tasks.append(
                        self._publish_task(
                            collection, shard, partition,
                            [s for s, _l, _d in group], group_live, seal_rows,
                        )
                    )
                group, group_live = [], 0

            for cand in cands[(shard, partition)]:
                if group and group_live + cand[1] > max_rows:
                    emit_group()
                group.append(cand)
                group_live += cand[1]
            emit_group()
        # The pk/ts columns are only needed while scoring candidates;
        # holding them between plans would pin the whole corpus in memory.
        self._seg_cols.clear()
        return tasks

    def _publish_task(
        self,
        collection: str,
        shard: int,
        partition: str,
        sources: list[int],
        live_rows: int,
        seal_rows: int,
    ) -> dict:
        compact_ts = self.tso.next()
        dd = self.tombstones.get(collection) or {}
        doomed = None
        if dd:
            pks, dts = flatten_tombstones(dd)
            doomed = ops.eff_tombstones(pks, dts, compact_ts)
        if doomed is None:
            doomed = (np.empty(0, np.int64), np.empty(0, np.int64))
        n_targets = max(1, -(-live_rows // seal_rows))  # ceil
        task_id = f"ct-{self._next_task}"
        self._next_task += 1
        payload = {
            "msg": "compaction_task",
            "task_id": task_id,
            "collection": collection,
            "shard": shard,
            "partition": partition,
            "sources": list(sources),
            "targets": [
                self.data_coord.allocate_segment_id() for _ in range(n_targets)
            ],
            "seal_rows": seal_rows,
            "compact_ts": compact_ts,
            "doomed_pks": doomed[0],
            "doomed_eff": doomed[1],
        }
        self.pending[task_id] = payload
        self.broker.publish(
            COORD_CHANNEL,
            LogEntry(ts=compact_ts, type=EntryType.COORD, payload=payload),
        )
        if self.events is not None:
            self.events.emit(
                "compaction_task", "compaction_coord",
                collection=collection, task_id=task_id, shard=shard,
                partition=partition, sources=list(sources),
                live_rows=live_rows,
            )
        return payload

    # ------------------------------------------------------------ retention
    def advance_horizon(
        self, horizon_ts: int, collection: str | None = None
    ) -> None:
        """Broadcast a retention-horizon advance: query nodes release
        retired segment versions and prune folded tombstones; the GC
        reaper may reclaim objects retired before ``horizon_ts``.
        ``collection=None`` advances every collection's horizon."""
        payload = {"msg": "retention_advance", "horizon_ts": horizon_ts}
        if collection is not None:
            payload["collection"] = collection
        self.broker.publish(
            COORD_CHANNEL,
            LogEntry(ts=self.tso.next(), type=EntryType.COORD, payload=payload),
        )


# ---------------------------------------------------------------------------
# Worker: stateless rewrite executors
# ---------------------------------------------------------------------------


class CompactionNode:
    """Claims ``compaction_task``s via meta-store CAS and rewrites binlogs.

    The rewrite is pure column algebra: one boolean keep-mask per source
    (binary-search probe of the sorted doomed-pk set) and one gather per
    column — no per-row Python loops, same conventions as ``kernels/ops``.
    """

    def __init__(
        self,
        node_id: str,
        broker: LogBroker,
        store: ObjectStore,
        meta: MetaStore,
        tso: TSO,
        metrics: MetricsRegistry | None = None,
    ):
        self.node_id = node_id
        self.broker = broker
        self.store = store
        self.meta = meta
        self.tso = tso
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.sub = Subscription(broker, COORD_CHANNEL)
        self.alive = True
        self.compactions_completed = 0
        self.rows_purged = 0
        self._applied_pos = -1  # LSN-keyed dedup over the coord channel
        self._retry: list[dict] = []  # tasks whose claim CAS lost spuriously

    def step(self) -> bool:
        if not self.alive:
            return False
        progress = False
        retries, self._retry = self._retry, []
        for task in retries:
            progress |= self._try_compact(task)
        for entry in self.sub.poll():
            if entry.position <= self._applied_pos:
                continue  # duplicate delivery: already saw this LSN
            self._applied_pos = entry.position
            if entry.type is not EntryType.COORD:
                continue
            p = entry.payload
            if p.get("msg") != "compaction_task":
                continue
            progress |= self._try_compact(p)
        return progress

    def _try_compact(self, task: dict) -> bool:
        coll = task["collection"]
        claim_key = f"compaction_claim/{coll}/{task['task_id']}"
        # CAS claim: only one compaction node executes a given task.
        if not self.meta.cas(claim_key, None, {"owner": self.node_id}):
            if self.meta.get(claim_key) is None:
                # Lost the CAS yet nobody holds the claim — a conflict storm
                # (injected or a genuinely vanished rival).  Requeue locally:
                # the task must not wedge behind a race that nobody won.
                self._retry.append(task)
            return False
        try:
            return self._rewrite(task)
        except Exception:
            # Release the claim so another node (or a retry) can take the
            # task instead of wedging it behind a dead claim.  (A simulated
            # Crash is a BaseException: it leaks the claim, as a real kill
            # would — the coordinator's clear_stale_claims handles that.)
            self.meta.delete(claim_key)
            raise

    def _rewrite(self, task: dict) -> bool:
        import time as _t

        t0 = _t.perf_counter()
        coll = task["collection"]
        sources = list(task["sources"])
        # Sorted pks + aligned effective delete ts (coordinator-materialized):
        # a row dies iff its pk is doomed AND its row ts predates the
        # effective delete, so upserted row versions written after the
        # delete survive the rewrite.
        doomed_pks = np.asarray(task["doomed_pks"])
        doomed_eff = np.asarray(
            task.get("doomed_eff", np.full(len(doomed_pks), np.iinfo(np.int64).max)),
            np.int64,
        )
        metas = [read_binlog_meta(self.store, coll, sid) for sid in sources]
        extra_fields = tuple(metas[0].get("extra_fields", ()))
        partition = task.get(
            "partition", metas[0].get("partition", DEFAULT_PARTITION)
        )
        cols: dict[str, list[np.ndarray]] = {
            f: [] for f in ("pk", "vector", "ts", *extra_fields)
        }
        folded: list[np.ndarray] = []
        rows_in = 0
        for sid, m in zip(sources, metas):
            if m["num_rows"] == 0:
                continue
            pks = read_binlog_column(self.store, coll, sid, "pk")
            ts_col = read_binlog_column(self.store, coll, sid, "ts")
            rows_in += len(pks)
            keep = ~ops.tombstone_mask(pks, ts_col, doomed_pks, doomed_eff)
            if not keep.all():
                folded.append(pks[~keep])
            if not keep.any():
                continue
            cols["pk"].append(pks[keep])
            cols["ts"].append(ts_col[keep])
            for field in ("vector", *extra_fields):
                cols[field].append(
                    read_binlog_column(self.store, coll, sid, field)[keep]
                )

        merged = {
            f: (np.concatenate(chunks) if chunks else None)
            for f, chunks in cols.items()
        }
        n_live = len(merged["pk"]) if merged["pk"] is not None else 0
        checkpoint_pos = max(m["checkpoint_pos"] for m in metas)

        # Repack the live rows into seal-size targets: compaction output is
        # uniform again, which is exactly the shape the fused scan batches.
        # Empty chunks (everything dead) produce no segment at all — the
        # sources simply vanish from the live mapping.
        targets = list(task["targets"])
        seal_rows = task["seal_rows"]
        out_segments = []
        attr_fields: list[str] = []
        for i, target in enumerate(targets):
            lo = i * seal_rows
            hi = (i + 1) * seal_rows if i < len(targets) - 1 else n_live
            if lo >= n_live or lo >= hi:
                continue
            seg = Segment(
                target, coll, metas[0]["shard"], metas[0]["dim"],
                extra_fields=extra_fields, partition=partition,
            )
            seg.append(
                merged["pk"][lo:hi],
                merged["vector"][lo:hi],
                merged["ts"][lo:hi],
                {f: merged[f][lo:hi] for f in extra_fields},
            )
            seg.checkpoint_pos = checkpoint_pos
            seg.seal()
            write_segment_binlog(self.store, seg)
            attr_fields = sorted(write_attr_satellites(self.store, seg))
            out_segments.append({"segment_id": target, "num_rows": seg.num_rows})

        folded_pks = (
            np.unique(np.concatenate(folded)) if folded else np.empty(0, np.int64)
        )
        self.compactions_completed += 1
        self.rows_purged += rows_in - n_live
        self.metrics.observe(
            "compaction_rewrite_us", (_t.perf_counter() - t0) * 1e6
        )
        self.metrics.inc("compactions_total")
        self.metrics.inc("compaction_rows_purged_total", rows_in - n_live)
        self.broker.publish(
            COORD_CHANNEL,
            LogEntry(
                ts=self.tso.next(),
                type=EntryType.COORD,
                payload={
                    "msg": "segment_compacted",
                    "task_id": task["task_id"],
                    "collection": coll,
                    "segments": out_segments,
                    "sources": sources,
                    "shard": metas[0]["shard"],
                    "partition": partition,
                    "num_rows": n_live,
                    "rows_purged": rows_in - n_live,
                    "compact_ts": task["compact_ts"],
                    # only tombstones actually folded into THIS rewrite are
                    # prunable — a doomed pk living in another segment must
                    # keep its delta-delete entry
                    "folded_pks": folded_pks,
                    "attr_fields": attr_fields,
                    "built_by": self.node_id,
                },
            ),
        )
        return True


# ---------------------------------------------------------------------------
# GC reaper: object-store reclamation behind the retention horizon
# ---------------------------------------------------------------------------


class GCReaper:
    """Deletes binlog/index objects of retired segments past the horizon.

    Segments referenced by a time-travel checkpoint are never reclaimed —
    checkpoints pin their binlogs so ``restore`` keeps working (§4.3).
    """

    def __init__(
        self,
        broker: LogBroker,
        store: ObjectStore,
        meta: MetaStore,
        tso: TSO,
        metrics: MetricsRegistry | None = None,
        events: EventLog | None = None,
    ):
        self.broker = broker
        self.store = store
        self.meta = meta
        self.tso = tso
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.events = events
        self.segments_reclaimed = 0
        self.bytes_reclaimed = 0

    def protected_segments(self, collection: str) -> set[int]:
        import json

        protected: set[int] = set()
        for m in self.store.list(f"checkpoint/{collection}/"):
            d = json.loads(self.store.get(m.key).decode())
            protected.update(d.get("sealed_segment_ids", ()))
        return protected

    def reap(self, horizon_ts: int, collection: str | None = None) -> dict:
        report = {"segments": [], "objects": 0, "bytes": 0, "protected": 0}
        protected_of: dict[str, set[int]] = {}  # one checkpoint scan per coll
        for key, val in self.meta.scan("retired_segment/").items():
            _, coll, sid_s = key.rsplit("/", 2)
            sid = int(sid_s)
            if collection is not None and coll != collection:
                continue
            if val["retired_at_ts"] > horizon_ts:
                continue
            if coll not in protected_of:
                protected_of[coll] = self.protected_segments(coll)
            if sid in protected_of[coll]:
                report["protected"] += 1
                continue
            for prefix in (
                f"binlog/{coll}/{sid}/",
                f"index/{coll}/{sid}/",
                f"attr/{coll}/{sid}/",
            ):
                for m in list(self.store.list(prefix)):
                    if self.store.delete(m.key):
                        report["objects"] += 1
                        report["bytes"] += m.size
            self.meta.delete(key)
            self.meta.delete(f"segment/{coll}/{sid}")
            for ak in list(self.meta.scan(f"attr_index/{coll}/{sid}/")):
                self.meta.delete(ak)
            self.broker.publish(
                COORD_CHANNEL,
                LogEntry(
                    ts=self.tso.next(),
                    type=EntryType.COORD,
                    payload={
                        "msg": "segment_gc",
                        "collection": coll,
                        "segment_id": sid,
                    },
                ),
            )
            report["segments"].append((coll, sid))
        self.segments_reclaimed += len(report["segments"])
        self.bytes_reclaimed += report["bytes"]
        if report["segments"] or report["protected"]:
            self.metrics.inc(
                "gc_segments_reclaimed_total", len(report["segments"])
            )
            self.metrics.inc("gc_bytes_reclaimed_total", report["bytes"])
            if self.events is not None:
                self.events.emit(
                    "gc_reap", "gc_reaper",
                    horizon_ts=horizon_ts,
                    segments=[sid for _c, sid in report["segments"]],
                    objects=report["objects"], bytes=report["bytes"],
                    protected=report["protected"],
                )
        return report
