"""Delta consistency (paper §3.4).

A query carries its issue timestamp ``L_r`` (assigned by the TSO) and a
user staleness tolerance ``tau`` in physical milliseconds.  A subscriber
whose consumed watermark is ``L_s`` may execute the query iff

    physical(L_r) - physical(L_s) < tau        (equivalently L_s > L_r - tau)

otherwise it must wait for the next time-tick.  tau = 0 gives strong
consistency (wait for *all* data up to the query's issue time), tau = inf
gives eventual consistency (never wait).

``ConsistencyLevel`` provides the named presets Manu exposes to users.
"""

from __future__ import annotations

from dataclasses import dataclass
from enum import Enum

from .timestamp import INFINITE_STALENESS, delta_ms


class ConsistencyLevel(Enum):
    STRONG = "strong"
    BOUNDED = "bounded"
    EVENTUAL = "eventual"
    SESSION = "session"  # read-your-writes: wait for the caller's last write ts


def staleness_ms_of(level: ConsistencyLevel, bounded_ms: float = 2_000.0) -> float:
    if level is ConsistencyLevel.STRONG:
        return 0.0
    if level is ConsistencyLevel.BOUNDED:
        return bounded_ms
    return INFINITE_STALENESS


@dataclass(frozen=True)
class GuaranteeTs:
    """What a query must wait for before executing."""

    query_ts: int  # L_r
    staleness_ms: float  # tau
    session_ts: int = 0  # for session consistency: caller's last write LSN

    def satisfied_by(self, watermark_ts: int) -> bool:
        if self.session_ts and watermark_ts < self.session_ts:
            return False
        if self.staleness_ms == INFINITE_STALENESS:
            return True
        return delta_ms(self.query_ts, watermark_ts) < self.staleness_ms or (
            watermark_ts >= self.query_ts
        )

    def wait_target_ts(self) -> int:
        """The minimal watermark that satisfies this guarantee."""
        import math

        from .timestamp import pack, physical_of

        if self.staleness_ms == INFINITE_STALENESS:
            return self.session_ts
        if self.staleness_ms <= 0:
            # strong: wm >= query_ts is the (only) satisfying condition
            return max(self.query_ts, self.session_ts)
        # smallest integer physical ms with (q_phys - p) < tau; wm >= query_ts
        # always satisfies too, so the minimal target is the min of the two
        phys_min = math.floor(physical_of(self.query_ts) - self.staleness_ms) + 1
        target = min(pack(max(phys_min, 0), 0), self.query_ts)
        return max(target, self.session_ts)
