"""Request scheduler: the serving tier between clients and the proxy
(paper §3.6 request batching, §4.2 delta consistency).

Writes no longer cross the WAL entry point one client request at a time.
``submit_mutation`` admits a typed mutation into a bounded per-(collection,
shard) queue under **credit-based backpressure** — a queue out of row
credits rejects at admission time with the typed :class:`AdmissionRejected`
(overload surfaces as an error the client can act on, never as silent
queueing collapse) — and hands back a :class:`MutationTicket`.  Queues
flush on three triggers:

* **depth** — the queue accumulated ``flush_rows`` rows;
* **age** — the oldest ticket waited ``flush_interval_ms`` (checked by
  ``step()``, which both runtimes drive: the cooperative ``pump()`` and the
  threaded pump loop);
* **explicit** — ``flush_writes()`` / ``MutationTicket.result()``.

A flushed batch crosses the proxy/logger boundary ONCE
(``Proxy.mutate_batch`` -> ``Logger.mutate_batch``): requests from
different clients are micro-batched cross-user, but each original request
keeps its own LSN and its own :class:`MutationResult` — batching is a
transport optimization, never a semantic merge.

Reads generalize the old ``BatchingProxy``: ``submit_search`` queues typed
:class:`SearchRequest`\\ s, ``flush_reads`` groups them by **compatible
plan shape** (collection, k, anns fields/weights, filter, partitions,
output fields, ranker, ...), concatenates the query vectors of each group,
executes ONE ``Proxy.search`` under the group's *strictest* guarantee
(max ``wait_target_ts``), and splits the result rows back per ticket.
``BatchingProxy`` survives as a thin facade over this stage.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field as dc_field

import numpy as np

from .consistency import GuaranteeTs
from .log import shard_of_pk
from .request import (
    DeleteRequest,
    InsertRequest,
    MutationRequest,
    MutationResult,
    SearchRequest,
    UpsertRequest,
)
from .telemetry import MetricsRegistry, TraceContext
from .timestamp import Clock


class AdmissionRejected(RuntimeError):
    """Typed admission-control rejection: the target write queue is out of
    row credits.  Carries enough structure for a client to back off or
    route elsewhere instead of parsing a message string."""

    def __init__(
        self,
        collection: str,
        shard: int,
        pending_rows: int,
        capacity_rows: int,
        request_rows: int,
    ):
        self.collection = collection
        self.shard = shard
        self.pending_rows = pending_rows
        self.capacity_rows = capacity_rows
        self.request_rows = request_rows
        super().__init__(
            f"ingest queue for '{collection}' shard {shard} is full: "
            f"{pending_rows}/{capacity_rows} rows pending, "
            f"request needs {request_rows}"
        )


class MutationTicket:
    """Handle for one admitted async mutation.  ``result()`` force-flushes
    the owning queue if the batch has not gone out yet (so cooperative
    callers never deadlock on their own unflushed write), then blocks until
    the scheduler resolves it with the request's own :class:`MutationResult`
    — or re-raises the request's own failure."""

    __slots__ = (
        "request", "collection", "shard", "rows", "enqueued_ms",
        "trace_ctx", "_done", "_event", "_result", "_error", "_scheduler",
        "_callbacks",
    )

    def __init__(
        self,
        scheduler: "RequestScheduler",
        collection: str,
        shard: int,
        request: MutationRequest,
        rows: int,
        enqueued_ms: float,
    ):
        self.request = request
        self.collection = collection
        self.shard = shard
        self.rows = rows
        self.enqueued_ms = enqueued_ms
        self.trace_ctx = (
            TraceContext("mutation") if getattr(request, "trace", False) else None
        )
        # The Event is created lazily on the first wait: most tickets
        # resolve before anyone blocks on them, and an Event allocation
        # per admission is measurable on the ingest hot path.
        self._done = False
        self._event: threading.Event | None = None
        self._result: MutationResult | None = None
        self._error: BaseException | None = None
        self._scheduler = scheduler
        self._callbacks: list | None = None

    @property
    def done(self) -> bool:
        return self._done

    def _wait(self, timeout_s: float) -> bool:
        if self._done:
            return True
        ev = self._event
        if ev is None:
            # Publish the event BEFORE re-checking ``_done``: a resolver
            # that flips ``_done`` after our check is then guaranteed to
            # see (and set) the event, so the wait below cannot hang.
            ev = self._event = threading.Event()
            if self._done:
                return True
        return ev.wait(timeout_s)

    def on_resolve(self, fn) -> None:
        """Run ``fn(result)`` when the mutation lands (immediately if it
        already has).  Used by the system facade to advance session
        watermarks without polling.

        Registration synchronizes with the resolver through the scheduler
        lock: without it, a threaded pump resolving concurrently could run
        the callback list just before this append lands, leaving ``fn``
        registered but never invoked (a silently lost session-watermark
        advance)."""
        with self._scheduler._lock:
            if not self._done:
                if self._callbacks is None:
                    self._callbacks = []
                self._callbacks.append(fn)
                return
        if self._result is not None:
            fn(self._result)

    def wait(self, timeout_s: float) -> bool:
        """Block for a scheduler-triggered flush (depth/age) WITHOUT
        forcing one — the age-trigger test surface and the pattern for
        clients that want purely async acks."""
        return self._wait(timeout_s)

    def result(self, timeout_s: float = 30.0) -> MutationResult:
        if not self._done:
            self._scheduler.flush_writes(collection=self.collection)
        if not self._wait(timeout_s):
            raise TimeoutError(
                f"mutation ticket for '{self.collection}' shard {self.shard} "
                f"did not resolve within {timeout_s}s"
            )
        if self._error is not None:
            raise self._error
        return self._result

    # scheduler-side
    def _resolve(self, result: MutationResult) -> None:
        # ``_result`` is published before ``_done`` so any reader that
        # observes the done flag sees the result; the callback list is
        # detached and the flag flipped under the scheduler lock (see
        # ``on_resolve``), but the callbacks themselves run outside it.
        self._result = result
        with self._scheduler._lock:
            callbacks, self._callbacks = self._callbacks, None
            self._done = True
        if callbacks is not None:
            for fn in callbacks:
                fn(result)
        ev = self._event
        if ev is not None:
            ev.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        with self._scheduler._lock:
            self._callbacks = None
            self._done = True
        ev = self._event
        if ev is not None:
            ev.set()


class SearchTicket:
    """Handle for one queued read; resolved by ``flush_reads`` with this
    request's slice of its group's batched result."""

    __slots__ = ("info", "request", "guarantee", "_event", "_result", "_error")

    def __init__(self, info, request: SearchRequest, guarantee: GuaranteeTs | None):
        self.info = info
        self.request = request
        self.guarantee = guarantee  # None = resolve at flush time
        self._event = threading.Event()
        self._result = None
        self._error: BaseException | None = None

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout_s: float = 30.0):
        if not self._event.wait(timeout_s):
            raise TimeoutError("search ticket did not resolve (flush_reads not run?)")
        if self._error is not None:
            raise self._error
        return self._result


@dataclass
class _WriteQueue:
    info: object
    collection: str
    shard: int
    tickets: "list[MutationTicket]" = dc_field(default_factory=list)
    pending_rows: int = 0
    oldest_ms: float = 0.0
    # Precomposed series key for the depth gauge: label formatting per
    # admission is measurable on the ingest hot path.
    gauge_key: str = ""

    def __post_init__(self):
        self.gauge_key = MetricsRegistry._key(
            "sched_queue_rows",
            {"collection": self.collection, "shard": str(self.shard)},
        )


class RequestScheduler:
    """Per-system serving-tier scheduler (see module docstring)."""

    def __init__(
        self,
        proxy,
        clock: Clock | None = None,
        queue_rows: int = 8_192,
        flush_rows: int = 1_024,
        flush_interval_ms: float = 20.0,
        metrics: MetricsRegistry | None = None,
        guarantee_fn=None,
        on_flush=None,
    ):
        self.proxy = proxy
        self.clock = clock if clock is not None else Clock()
        self.queue_rows = int(queue_rows)
        self.flush_rows = int(flush_rows)
        self.flush_interval_ms = float(flush_interval_ms)
        self.metrics = metrics if metrics is not None else proxy.metrics
        # guarantee_fn(info, request) -> GuaranteeTs for read tickets whose
        # submitter pinned nothing; default = the proxy's standalone rules.
        self._guarantee_fn = guarantee_fn or (
            lambda _info, request: proxy.resolve_guarantee(request)
        )
        # Called after every write flush (the system facade pumps the
        # cooperative runtime here so subscribers observe the WAL entries).
        self.on_flush = on_flush
        self._queues: dict[tuple[str, int], _WriteQueue] = {}
        self._searches: list[SearchTicket] = []
        self._lock = threading.RLock()
        self._flushing = False  # re-entrancy guard: on_flush may pump us
        # Precomposed per-op admission-counter keys (see _WriteQueue.gauge_key)
        self._admit_keys = {
            op: MetricsRegistry._key("sched_admitted_total", {"op": op})
            for op in ("insert", "upsert", "delete")
        }

    # -------------------------------------------------------------- writes
    @staticmethod
    def _rows_of(request: MutationRequest) -> int:
        if isinstance(request, DeleteRequest):
            return len(request.pks)  # __post_init__ made them 1-D
        return len(next(iter(request.rows.values())))

    def _route(self, info, request: MutationRequest) -> int:
        """Routing shard — same rule as ``Proxy.mutate``: the batch's first
        primary key picks the owning logger."""
        if isinstance(request, (InsertRequest, UpsertRequest)):
            self.proxy._verify_partition(info.name, request.partition)
            pk_field = info.schema.primary()
            if pk_field is not None and pk_field.name in request.rows:
                first = np.asarray(request.rows[pk_field.name])[:1]
                if first.size:
                    return shard_of_pk(first.tolist()[0], info.num_shards)
        elif isinstance(request, DeleteRequest) and len(request.pks):
            return shard_of_pk(request.pks.tolist()[0], info.num_shards)
        return 0

    def submit_mutation(self, info, request: MutationRequest) -> MutationTicket:
        """Admit one typed mutation: verify against cached metadata NOW
        (admission-time early rejection — a queued request must never fail
        validation later, when the client is gone), charge the queue's row
        credits, enqueue.  Raises :class:`AdmissionRejected` when the queue
        is out of credits; an oversize request (larger than the whole
        queue) is admitted only when the queue is empty."""
        self.proxy._verify(info.name)
        request.validate(info.schema)
        shard = self._route(info, request)
        rows = self._rows_of(request)
        depth_flush = None
        with self._lock:
            key = (info.name, shard)
            q = self._queues.get(key)
            if q is None:  # get-then-insert: setdefault would construct
                q = self._queues[key] = _WriteQueue(info, info.name, shard)
                # (and discard) a fresh queue on every admission
            if q.pending_rows and q.pending_rows + rows > self.queue_rows:
                self.metrics.inc("sched_rejected_total")
                raise AdmissionRejected(
                    info.name, shard, q.pending_rows, self.queue_rows, rows
                )
            now = self.clock.now_ms()
            ticket = MutationTicket(self, info.name, shard, request, rows, now)
            if ticket.trace_ctx is not None:
                ticket.trace_ctx.span(
                    "sched_enqueue",
                    detail=f"shard={shard};queue_rows={q.pending_rows + rows}",
                )
            if not q.tickets:
                q.oldest_ms = now
            q.tickets.append(ticket)
            q.pending_rows += rows
            self.metrics.inc(
                self._admit_keys.get(request.op, "sched_admitted_total"))
            self._set_depth_gauge(q)
            if q.pending_rows >= min(self.flush_rows, self.queue_rows):
                depth_flush = self._take(q)
        if depth_flush is not None:
            self._execute(depth_flush, trigger="depth")
        return ticket

    def step(self) -> bool:
        """Age-trigger pass, driven by both runtimes' pumps: flush every
        queue whose oldest ticket has waited ``flush_interval_ms``."""
        if self._flushing:
            return False
        now = self.clock.now_ms()
        aged = []
        with self._lock:
            for q in self._queues.values():
                if q.tickets and now - q.oldest_ms >= self.flush_interval_ms:
                    aged.append(self._take(q))
        for batch in aged:
            self._execute(batch, trigger="age")
        return bool(aged)

    def flush_writes(self, collection: str | None = None) -> int:
        """Flush every (matching) queue now; returns requests flushed."""
        with self._lock:
            batches = [
                self._take(q)
                for q in self._queues.values()
                if q.tickets and (collection is None or q.collection == collection)
            ]
        n = 0
        for batch in batches:
            n += len(batch[1])
            self._execute(batch, trigger="explicit")
        return n

    def pending_write_rows(self, collection: str | None = None) -> int:
        with self._lock:
            return sum(
                q.pending_rows
                for q in self._queues.values()
                if collection is None or q.collection == collection
            )

    def _take(self, q: _WriteQueue):
        """Detach the queue's current contents (call under the lock)."""
        tickets, q.tickets = q.tickets, []
        q.pending_rows = 0
        self._set_depth_gauge(q)
        return (q, tickets)

    def _set_depth_gauge(self, q: _WriteQueue) -> None:
        self.metrics.set_gauge(q.gauge_key, q.pending_rows)

    def _execute(self, batch, trigger: str) -> None:
        """One proxy/logger crossing for the whole batch; each ticket is
        resolved with its request's own result (or its own failure — one
        request's fatal error never poisons its queue-mates)."""
        q, tickets = batch
        if not tickets:
            return
        now = self.clock.now_ms()
        rows = sum(t.rows for t in tickets)
        self.metrics.inc("sched_flushes_total", labels={"trigger": trigger})
        self.metrics.observe("sched_batch_requests", len(tickets))
        self.metrics.observe("sched_batch_rows", rows)
        for t in tickets:
            self.metrics.observe("sched_queue_wait_ms", max(0.0, now - t.enqueued_ms))
        traces = []
        for t in tickets:
            if t.trace_ctx is None:
                traces.append(None)
            else:
                span = t.trace_ctx.span(
                    "sched_flush",
                    detail=(
                        f"trigger={trigger};batch_requests={len(tickets)};"
                        f"batch_rows={rows}"
                    ),
                )
                traces.append((t.trace_ctx, span))
        t0 = time.perf_counter()
        was_flushing, self._flushing = self._flushing, True
        try:
            try:
                results = self.proxy.mutate_batch(
                    q.info, [t.request for t in tickets], shard=q.shard,
                    traces=traces, prevalidated=True,
                )
            except Exception as exc:
                # Whole-batch failure (e.g. no live logger): every ticket
                # reports it — a queued mutation never vanishes silently.
                for t in tickets:
                    t._fail(exc)
                return
            elapsed_us = (time.perf_counter() - t0) * 1e6
            for t, res in zip(tickets, results):
                if isinstance(res, MutationResult):
                    if t.trace_ctx is not None:
                        res.trace = t.trace_ctx.finish(elapsed_us)
                    t._resolve(res)
                else:
                    t._fail(res)
        finally:
            self._flushing = was_flushing
        if self.on_flush is not None:
            self.on_flush()

    # --------------------------------------------------------------- reads
    def submit_search(
        self, info, request: SearchRequest, guarantee: GuaranteeTs | None = None
    ) -> SearchTicket:
        self.proxy._verify(info.name)
        request.validate(info.schema)
        ticket = SearchTicket(info, request, guarantee)
        with self._lock:
            self._searches.append(ticket)
        return ticket

    @staticmethod
    def _plan_shape(info, request: SearchRequest) -> tuple:
        """Two requests with the same shape can run as one plan: same
        collection, k, anns signature, filter, scope and post-processing.
        Traced requests group only with traced ones (they share the batch's
        span tree)."""
        return (
            info.name,
            request.k,
            tuple(
                (a.field, a.weight, tuple(sorted(a.params.items())))
                for a in request.anns
            ),
            None if request.filter is None else str(request.filter),
            request.filter_strategy,
            request.radius,
            request.range_filter,
            request.output_fields,
            request.partition_names,
            request.time_travel_ts,
            (request.ranker.kind, request.ranker.rrf_k),
            request.trace,
        )

    def flush_reads(self, wait_fn=None, hedge_timeout_s: float | None = None) -> list:
        """Group queued reads by plan shape, run one ``Proxy.search`` per
        group under its strictest guarantee, split rows back per ticket.
        Returns the results in submit order (also delivered through each
        ticket)."""
        from .proxy import SearchResult  # local: proxy imports this module

        with self._lock:
            tickets, self._searches = self._searches, []
        if not tickets:
            return []
        groups: dict[tuple, list[int]] = {}
        for i, t in enumerate(tickets):
            groups.setdefault(self._plan_shape(t.info, t.request), []).append(i)
        results: list = [None] * len(tickets)
        self.metrics.inc("sched_search_requests_total", len(tickets))
        for idxs in groups.values():
            head = tickets[idxs[0]]
            guarantees = [
                t.guarantee
                if t.guarantee is not None
                else self._guarantee_fn(t.info, t.request)
                for t in (tickets[i] for i in idxs)
            ]
            # The batch executes under the *strictest* guarantee in the
            # group: every member's wait target is covered.
            guarantee = max(guarantees, key=lambda g: g.wait_target_ts())
            combined = SearchRequest(
                anns=[
                    type(a)(
                        a.field,
                        np.concatenate(
                            [tickets[i].request.anns[f].queries for i in idxs],
                            axis=0,
                        ),
                        a.weight,
                        dict(a.params),
                    )
                    for f, a in enumerate(head.request.anns)
                ],
                k=head.request.k,
                filter=head.request.filter,
                filter_strategy=head.request.filter_strategy,
                radius=head.request.radius,
                range_filter=head.request.range_filter,
                output_fields=head.request.output_fields,
                partition_names=head.request.partition_names,
                time_travel_ts=head.request.time_travel_ts,
                ranker=head.request.ranker,
                trace=head.request.trace,
            )
            self.metrics.inc("sched_search_batches_total")
            self.metrics.observe("sched_search_batch_nq", combined.nq)
            try:
                batch_res = self.proxy.search(
                    head.info, combined, guarantee=guarantee,
                    wait_fn=wait_fn, hedge_timeout_s=hedge_timeout_s,
                )
            except Exception as exc:
                for i in idxs:
                    tickets[i]._error = exc
                    tickets[i]._event.set()
                continue
            row = 0
            for i in idxs:
                n_i = tickets[i].request.nq
                sliced = SearchResult(
                    batch_res.scores[row : row + n_i],
                    batch_res.pks[row : row + n_i],
                    batch_res.query_ts,
                    batch_res.waited_ms,
                    fields=(
                        None
                        if batch_res.fields is None
                        else {
                            f: v[row : row + n_i]
                            for f, v in batch_res.fields.items()
                        }
                    ),
                    trace=batch_res.trace,
                )
                results[i] = sliced
                tickets[i]._result = sliced
                tickets[i]._event.set()
                row += n_i
        for t in tickets:
            if not t._event.is_set():  # unreachable guard: never hang a caller
                t._error = RuntimeError("search ticket dropped by flush_reads")
                t._event.set()
        return results


class BatchingProxy:
    """Request batching (paper §3.6) — a thin facade over the scheduler's
    read micro-batching stage.  The legacy ``submit(info, query, k,
    guarantee)`` tuple surface survives unchanged; ``submit_request`` is
    the typed surface (filters / output_fields / hybrid all batch)."""

    def __init__(self, proxy, max_batch: int = 64, scheduler=None):
        self.proxy = proxy
        self.max_batch = max_batch
        self.scheduler = scheduler if scheduler is not None else RequestScheduler(proxy)
        self._tickets: list[SearchTicket] = []

    def submit(self, info, query, k: int, guarantee: GuaranteeTs) -> int:
        request = SearchRequest.single(
            np.asarray(query, np.float32), field=None, k=k
        )
        return self.submit_request(info, request, guarantee=guarantee)

    def submit_request(
        self, info, request: SearchRequest, guarantee: GuaranteeTs | None = None
    ) -> int:
        self._tickets.append(
            self.scheduler.submit_search(info, request, guarantee=guarantee)
        )
        return len(self._tickets) - 1

    def flush(self, wait_fn=None, hedge_timeout_s: float | None = None) -> list:
        self.scheduler.flush_reads(wait_fn=wait_fn, hedge_timeout_s=hedge_timeout_s)
        out = [t.result() for t in self._tickets]
        self._tickets.clear()
        return out
