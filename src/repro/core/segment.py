"""Segments: Manu's unit of data placement (paper §3.1, §3.6).

Entities from each shard are organized into segments.  A segment is
*growing* (accepts new rows) until it reaches ``seal_size`` rows or a
time limit, then *sealed* (read-only).  Growing segments are subdivided
into *slices* (10k vectors by default); once a slice fills up, a
light-weight temporary index (IVF-FLAT) is built for it so brute-force
scan cost stays bounded (paper reports up to 10x speedup).

MVCC: every row carries its LSN (HLC timestamp); deletes are recorded as
per-pk tombstone timestamps.  A tombstone ``(pk, dts)`` kills exactly the
row versions with ``row_ts < dts`` — so an upsert's delete half (published
at the same LSN as its insert half) retires the old version without
touching the new one, and visibility flips atomically at one timestamp.
``visible_mask(ts)`` gives the set of rows a query pinned at ``ts`` may
see — this one primitive yields delta consistency, repeatable reads, and
time travel.

Partitions: each segment carries the partition tag it was placed under
(paper §3.1: collection → shard → partition → segment); the query-node
planner prunes non-matching segments before any scan.
"""

from __future__ import annotations

import io
import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any

import numpy as np

DEFAULT_SLICE_ROWS = 10_000
DEFAULT_SEAL_ROWS = 65_536
#: Every collection owns one implicit partition; unplaced writes land here.
DEFAULT_PARTITION = "_default"


# Tombstone maps (pk -> delete ts) keep the common single-delete case as a
# bare int and promote to a sorted list only when the same pk is deleted
# (upserted) again — the dict shape older tests poke directly stays valid.
def add_tombstone(dd: dict, pk, ts: int) -> bool:
    """Record one (pk, delete-ts) tombstone; returns False on duplicates."""
    cur = dd.get(pk)
    if cur is None:
        dd[pk] = int(ts)
        return True
    if isinstance(cur, list):
        if ts in cur:
            return False
        cur.append(int(ts))
        cur.sort()
        return True
    if cur == ts:
        return False
    dd[pk] = sorted((cur, int(ts)))
    return True


def flatten_tombstones(dd: dict) -> tuple[np.ndarray, np.ndarray]:
    """Flatten a pk -> (ts | [ts, ...]) tombstone map into aligned
    (pks, dts) arrays — the shape ``ops.eff_tombstones`` consumes."""
    pks: list = []
    dts: list = []
    for pk, v in dd.items():
        if isinstance(v, list):
            pks.extend([pk] * len(v))
            dts.extend(v)
        else:
            pks.append(pk)
            dts.append(v)
    return np.asarray(pks), np.asarray(dts, np.int64)


class SegmentState(Enum):
    GROWING = "growing"
    SEALED = "sealed"
    DROPPED = "dropped"


@dataclass
class SegmentStats:
    num_rows: int
    num_deleted: int
    state: str
    min_ts: int
    max_ts: int


class Segment:
    """Columnar in-memory segment with MVCC visibility.

    Storage is column-major (one numpy array per field) — the same layout
    as the binlog, so sealing = serializing columns verbatim.
    """

    def __init__(
        self,
        segment_id: int,
        collection: str,
        shard: int,
        dim: int,
        slice_rows: int = DEFAULT_SLICE_ROWS,
        extra_fields: tuple[str, ...] = (),
        partition: str = DEFAULT_PARTITION,
    ):
        self.segment_id = segment_id
        self.collection = collection
        self.shard = shard
        self.partition = partition
        self.dim = dim
        self.slice_rows = slice_rows
        self.state = SegmentState.GROWING
        self.extra_fields = tuple(extra_fields)

        self._pks: list[np.ndarray] = []
        self._vectors: list[np.ndarray] = []
        self._timestamps: list[np.ndarray] = []
        self._extras: dict[str, list[np.ndarray]] = {f: [] for f in self.extra_fields}
        self._num_rows = 0

        # Materialized (concatenated) columns; invalidated on append.
        self._mat: dict[str, np.ndarray] | None = None
        # Row-normalized vector columns (cosine scans), cached per column
        # name; invalidated on append alongside ``_mat``.
        self._unit: dict[str, np.ndarray] = {}

        # Tombstones: pk -> delete ts (or a sorted list when the pk was
        # deleted more than once — repeated upserts).  The row-level kill
        # masks are derived lazily (and are what the scan kernels consume).
        self._deleted: dict[Any, Any] = {}
        self._del_flat: tuple[np.ndarray, np.ndarray] | None = None

        # Slice boundaries with a temporary index handle each (built by the
        # query node once a slice is full).
        self.slice_indexes: dict[int, Any] = {}

        self._lock = threading.RLock()
        self.checkpoint_pos: int = 0  # WAL position this segment has consumed up to

    # -------------------------------------------------------------- writes
    def append(
        self,
        pks: np.ndarray,
        vectors: np.ndarray,
        timestamps: np.ndarray,
        extras: dict[str, np.ndarray] | None = None,
    ) -> None:
        with self._lock:
            if self.state is not SegmentState.GROWING:
                raise RuntimeError(f"segment {self.segment_id} is {self.state}, not growing")
            if vectors.ndim != 2 or vectors.shape[1] != self.dim:
                raise ValueError(f"expected (n,{self.dim}) vectors, got {vectors.shape}")
            n = len(pks)
            if not (len(vectors) == len(timestamps) == n):
                raise ValueError("pks/vectors/timestamps length mismatch")
            self._pks.append(np.asarray(pks))
            self._vectors.append(np.asarray(vectors, dtype=np.float32))
            self._timestamps.append(np.asarray(timestamps, dtype=np.int64))
            for name in self.extra_fields:
                src = (extras or {}).get(name)
                if src is None:
                    raise ValueError(f"missing extra field '{name}'")
                self._extras[name].append(np.asarray(src))
            self._num_rows += n
            self._mat = None
            self._unit.clear()

    def delete(self, pks: np.ndarray, ts: int) -> int:
        """Tombstone primary keys as of ``ts``: row versions with
        ``row_ts < ts`` become invisible to queries pinned at or after
        ``ts``; versions written at or after ``ts`` (re-inserts, the
        insert half of an upsert at the same LSN) are untouched.
        Returns the number of tombstones recorded."""
        with self._lock:
            existing = set(np.asarray(self.pks()).tolist())
            hits = 0
            for pk in np.asarray(pks).tolist():
                if pk in existing and add_tombstone(self._deleted, pk, ts):
                    hits += 1
            if hits:
                self._del_flat = None
            return hits

    def seal(self) -> None:
        with self._lock:
            self.state = SegmentState.SEALED

    # --------------------------------------------------------------- reads
    def _materialize(self) -> dict[str, np.ndarray]:
        with self._lock:
            if self._mat is None:
                cols: dict[str, np.ndarray] = {}
                cols["pk"] = (
                    np.concatenate(self._pks) if self._pks else np.empty(0, np.int64)
                )
                cols["vector"] = (
                    np.concatenate(self._vectors)
                    if self._vectors
                    else np.empty((0, self.dim), np.float32)
                )
                cols["ts"] = (
                    np.concatenate(self._timestamps)
                    if self._timestamps
                    else np.empty(0, np.int64)
                )
                for name in self.extra_fields:
                    chunks = self._extras[name]
                    cols[name] = (
                        np.concatenate(chunks) if chunks else np.empty(0)
                    )
                self._mat = cols
            return self._mat

    @property
    def num_rows(self) -> int:
        return self._num_rows

    def pks(self) -> np.ndarray:
        return self._materialize()["pk"]

    def vectors(self) -> np.ndarray:
        return self._materialize()["vector"]

    def timestamps(self) -> np.ndarray:
        return self._materialize()["ts"]

    def extra(self, name: str) -> np.ndarray:
        return self._materialize()[name]

    def unit_column(self, name: str = "vector") -> np.ndarray:
        """Row-normalized copy of a vector column, cached until the next
        append — cosine brute scans reuse it instead of renormalizing the
        whole column on every search."""
        with self._lock:
            cached = self._unit.get(name)
            if cached is None:
                col = self._materialize()[name]
                norms = np.linalg.norm(col, axis=1, keepdims=True)
                cached = col / np.maximum(norms, 1e-12)
                self._unit[name] = cached
            return cached

    def _tombstones_flat(self) -> tuple[np.ndarray, np.ndarray] | None:
        """(pks, dts) tombstone pairs, cached until the next delete."""
        with self._lock:
            if not self._deleted:
                return None
            if self._del_flat is None:
                self._del_flat = flatten_tombstones(self._deleted)
            return self._del_flat

    def delete_bitmap(self) -> np.ndarray:
        """Boolean mask of rows currently dead (killed at any timestamp)."""
        return ~self.visible_mask(np.iinfo(np.int64).max)

    def visible_mask(self, ts: int) -> np.ndarray:
        """MVCC visibility at query timestamp ``ts``: rows written at or
        before ``ts`` and not killed by any tombstone in ``(row_ts, ts]``."""
        from ..kernels import ops

        cols = self._materialize()
        mask = cols["ts"] <= ts
        flat = self._tombstones_flat()
        if flat is not None:
            eff = ops.eff_tombstones(flat[0], flat[1], ts)
            if eff is not None:
                mask &= ~ops.tombstone_mask(cols["pk"], cols["ts"], eff[0], eff[1])
        return mask

    def min_ts(self) -> int:
        ts = self.timestamps()
        return int(ts.min()) if len(ts) else 0

    def max_ts(self) -> int:
        ts = self.timestamps()
        return int(ts.max()) if len(ts) else 0

    def stats(self) -> SegmentStats:
        return SegmentStats(
            num_rows=self.num_rows,
            num_deleted=len(self._deleted),
            state=self.state.value,
            min_ts=self.min_ts(),
            max_ts=self.max_ts(),
        )

    # -------------------------------------------------------------- slices
    def full_slices(self) -> list[int]:
        """Indices of completed slices (candidates for temporary indexes)."""
        return list(range(self._num_rows // self.slice_rows))

    def slice_bounds(self, slice_idx: int) -> tuple[int, int]:
        lo = slice_idx * self.slice_rows
        hi = min(lo + self.slice_rows, self._num_rows)
        return lo, hi

    def tail_rows(self) -> tuple[int, int]:
        """Row range not covered by any full slice (always brute-force)."""
        lo = (self._num_rows // self.slice_rows) * self.slice_rows
        return lo, self._num_rows

    # -------------------------------------------------- binlog (de)serialize
    def to_binlog(self) -> bytes:
        """Columnar serialization (the binlog format, paper §3.3).
        Tombstones flatten to aligned (pk, ts) pair arrays — one entry per
        delete event, so multi-delete histories round-trip exactly."""
        cols = dict(self._materialize())
        flat = self._tombstones_flat()
        if flat is not None:
            cols["__deleted_pks"], cols["__deleted_ts"] = flat
        else:
            cols["__deleted_pks"] = np.empty(0, cols["pk"].dtype)
            cols["__deleted_ts"] = np.empty(0, np.int64)
        buf = io.BytesIO()
        np.savez_compressed(
            buf,
            __meta=np.array(
                [self.segment_id, self.shard, self.dim, self.checkpoint_pos],
                dtype=np.int64,
            ),
            __partition=np.array(self.partition),
            **cols,
        )
        return buf.getvalue()

    @classmethod
    def from_binlog(
        cls, collection: str, data: bytes, slice_rows: int = DEFAULT_SLICE_ROWS
    ) -> "Segment":
        with np.load(io.BytesIO(data), allow_pickle=False) as z:
            meta = z["__meta"]
            segment_id, shard, dim, ckpt = (int(x) for x in meta)
            extra_names = tuple(
                k
                for k in z.files
                if k not in ("__meta", "__partition", "pk", "vector", "ts",
                             "__deleted_pks", "__deleted_ts")
            )
            partition = (
                str(z["__partition"]) if "__partition" in z.files else DEFAULT_PARTITION
            )
            seg = cls(segment_id, collection, shard, dim, slice_rows, extra_names,
                      partition=partition)
            n = len(z["pk"])
            if n:
                extras = {k: z[k] for k in extra_names}
                seg.append(z["pk"], z["vector"], z["ts"], extras)
            seg.checkpoint_pos = ckpt
            for pk, dts in zip(z["__deleted_pks"].tolist(), z["__deleted_ts"].tolist()):
                add_tombstone(seg._deleted, pk, dts)
            seg.seal()
            return seg

    def deleted_fraction(self) -> float:
        return len(self._deleted) / max(1, self.num_rows)


def merge_segments(new_id: int, segments: list[Segment]) -> Segment:
    """Compaction: merge small sealed segments into one, dropping rows whose
    delete tombstone is already present (paper §3.1 'merges small segments')."""
    if not segments:
        raise ValueError("nothing to merge")
    base = segments[0]
    out = Segment(
        new_id, base.collection, base.shard, base.dim, base.slice_rows,
        base.extra_fields, partition=base.partition,
    )
    for seg in segments:
        keep = ~seg.delete_bitmap()
        if keep.any():
            extras = {f: seg.extra(f)[keep] for f in seg.extra_fields}
            out.append(seg.pks()[keep], seg.vectors()[keep], seg.timestamps()[keep], extras)
    out.checkpoint_pos = max(s.checkpoint_pos for s in segments)
    out.seal()
    return out
