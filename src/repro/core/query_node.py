"""Query nodes: the search workers (paper §3.6).

A query node gets data from three sources:

* **WAL** — it subscribes to DML channels and keeps its own growing
  segments so freshly inserted rows are searchable within one time-tick;
  full slices get a light-weight temporary index (IVF-FLAT), the tail is
  brute-force scanned.
* **index files** — sealed segments' indexes, loaded from the object store
  when the query coordinator assigns the segment to this node.
* **binlog** — sealed segment columns, loaded on assignment/failover.

Searches run under MVCC: a query pinned at ``ts`` sees exactly the rows
with LSN <= ts that are not deleted as of ts.  Node-level results are the
node-wise top-k of the two-phase reduce; the proxy performs the global
merge and pk-dedup.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..index.base import IndexSpec, VectorIndex
from ..index.flat import FlatIndex
from ..index.ivf import IVFFlatIndex
from ..index.registry import create_index
from .binlog import load_segment
from .collection import Metric
from .consistency import GuaranteeTs
from .log import EntryType, LogBroker, LogEntry, Subscription
from .object_store import ObjectStore
from .segment import Segment

TEMP_INDEX_SLICE_ROWS = 2_048  # scaled-down default of the paper's 10k


@dataclass
class SealedHandle:
    segment: Segment
    index: VectorIndex | None = None
    index_kind: str | None = None


@dataclass
class GrowingState:
    segment: Segment
    slice_index_built: dict[int, VectorIndex] = field(default_factory=dict)


class QueryNode:
    def __init__(
        self,
        node_id: str,
        broker: LogBroker,
        store: ObjectStore,
        tso=None,
        slice_rows: int = TEMP_INDEX_SLICE_ROWS,
    ):
        self.node_id = node_id
        self.broker = broker
        self.store = store
        self.tso = tso
        self.slice_rows = slice_rows
        self.subscriptions: dict[str, Subscription] = {}
        self.coord_sub = Subscription(broker, "coord") if broker.has_channel("coord") else None
        self.sealed: dict[tuple[str, int], SealedHandle] = {}
        self.growing: dict[tuple[str, int], GrowingState] = {}
        # Delta deletes for rows living in sealed segments: coll -> pk -> ts
        self.delta_deletes: dict[str, dict[object, int]] = {}
        self.alive = True
        self.search_count = 0
        self.inject_delay_s = 0.0  # straggler fault injection (tests/benches)

    # --------------------------------------------------------- subscriptions
    def subscribe(self, channel: str, from_position: int = 0) -> None:
        if channel not in self.subscriptions:
            self.subscriptions[channel] = Subscription(self.broker, channel, from_position)

    def unsubscribe(self, channel: str) -> None:
        self.subscriptions.pop(channel, None)

    def watermark(self, collection: str) -> int:
        """Min last-time-tick over this node's channels for the collection."""
        marks = [
            sub.last_tick_seen
            for ch, sub in self.subscriptions.items()
            if ch.startswith(f"dml/{collection}/")
        ]
        return min(marks) if marks else 0

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        if not self.alive:
            return False
        progress = False
        if self.coord_sub is not None:
            for entry in self.coord_sub.poll():
                progress |= self._handle_coord(entry)
        for sub in list(self.subscriptions.values()):
            for entry in sub.poll():
                progress |= self._consume(entry)
        progress |= self._build_slice_indexes()
        return progress

    def _handle_coord(self, entry: LogEntry) -> bool:
        if entry.type is not EntryType.COORD:
            return False
        p = entry.payload
        msg = p.get("msg")
        if msg == "segment_loaded":
            # Another node owns the sealed copy now: hand off our growing rows.
            if p.get("node_id") != self.node_id:
                self.drop_growing(p["collection"], p["segment_id"])
            return True
        if p.get("node_id") != self.node_id:
            return False
        if msg == "load_segment":
            self.load_sealed(p["collection"], p["segment_id"])
            if self.tso is not None:
                self.broker.publish(
                    "coord",
                    LogEntry(
                        ts=self.tso.next(),
                        type=EntryType.COORD,
                        payload={
                            "msg": "segment_loaded",
                            "node_id": self.node_id,
                            "collection": p["collection"],
                            "segment_id": p["segment_id"],
                        },
                    ),
                )
            return True
        if msg == "load_index":
            self.load_index(
                p["collection"], p["segment_id"], p["index_kind"], p["index_key"]
            )
            return True
        if msg == "release_segment":
            self.release_segment(p["collection"], p["segment_id"])
            return True
        if msg == "subscribe_channel":
            self.subscribe(p["channel"], p.get("from_position", 0))
            return True
        if msg == "unsubscribe_channel":
            self.unsubscribe(p["channel"])
            return True
        return False

    def _consume(self, entry: LogEntry) -> bool:
        if entry.type is EntryType.INSERT:
            p = entry.payload
            key = (p["collection"], p["segment_id"])
            if key in self.sealed:
                return False  # already have the sealed (authoritative) copy
            gs = self.growing.get(key)
            if gs is None:
                extra_fields = tuple(sorted(p.get("extras", {})))
                seg = Segment(
                    p["segment_id"], p["collection"], p["shard"],
                    p["vector"].shape[1], slice_rows=self.slice_rows,
                    extra_fields=extra_fields,
                )
                gs = GrowingState(seg)
                self.growing[key] = gs
            n = len(p["pk"])
            gs.segment.append(
                p["pk"], p["vector"], np.full(n, entry.ts, np.int64), p.get("extras")
            )
            return True
        if entry.type is EntryType.DELETE:
            p = entry.payload
            coll = p["collection"]
            dd = self.delta_deletes.setdefault(coll, {})
            for pk in np.asarray(p["pk"]).tolist():
                dd.setdefault(pk, entry.ts)
            for (c, _sid), gs in self.growing.items():
                if c == coll:
                    gs.segment.delete(p["pk"], entry.ts)
            return True
        return False

    def _build_slice_indexes(self) -> bool:
        """Temporary IVF-FLAT per full slice of growing segments (paper §3.6)."""
        progress = False
        for gs in self.growing.values():
            for s in gs.segment.full_slices():
                if s in gs.slice_index_built:
                    continue
                lo, hi = gs.segment.slice_bounds(s)
                idx = IVFFlatIndex(metric=Metric.L2, nlist=16, nprobe=4)
                idx.build(gs.segment.vectors()[lo:hi])
                gs.slice_index_built[s] = idx
                progress = True
        return progress

    # ---------------------------------------------------------- assignments
    def load_sealed(self, collection: str, segment_id: int) -> None:
        key = (collection, segment_id)
        if key in self.sealed:
            return
        seg = load_segment(self.store, collection, segment_id)
        self.sealed[key] = SealedHandle(seg)
        # Hand-off: drop our growing copy of the same segment.
        self.growing.pop(key, None)

    def load_index(self, collection: str, segment_id: int, kind: str, index_key: str) -> None:
        handle = self.sealed.get((collection, segment_id))
        if handle is None:
            self.load_sealed(collection, segment_id)
            handle = self.sealed[(collection, segment_id)]
        index = VectorIndex.load(self.store.get(index_key))
        handle.index = index
        handle.index_kind = kind

    def release_segment(self, collection: str, segment_id: int) -> None:
        self.sealed.pop((collection, segment_id), None)
        self.growing.pop((collection, segment_id), None)

    def drop_growing(self, collection: str, segment_id: int) -> None:
        """Hand-off after another node loaded the sealed copy."""
        self.growing.pop((collection, segment_id), None)

    def held_segments(self, collection: str) -> list[int]:
        return sorted(sid for (c, sid) in self.sealed if c == collection)

    def memory_rows(self) -> int:
        rows = sum(h.segment.num_rows for h in self.sealed.values())
        rows += sum(g.segment.num_rows for g in self.growing.values())
        return rows

    # --------------------------------------------------------------- search
    def _delta_delete_mask(self, collection: str, seg: Segment, ts: int) -> np.ndarray | None:
        dd = self.delta_deletes.get(collection)
        if not dd:
            return None
        pks = seg.pks()
        doomed_pks = np.array([pk for pk, dts in dd.items() if dts <= ts])
        if len(doomed_pks) == 0:
            return None
        return ~np.isin(pks, doomed_pks)

    def _visible(self, collection: str, seg: Segment, ts: int) -> np.ndarray:
        mask = seg.visible_mask(ts)
        dd = self._delta_delete_mask(collection, seg, ts)
        if dd is not None:
            mask = mask & dd
        return mask

    def search(
        self,
        collection: str,
        queries: np.ndarray,
        k: int,
        metric: Metric,
        guarantee: GuaranteeTs,
        filter_masks: "dict[int, np.ndarray] | None" = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Node-wise top-k.  Returns (scores [nq,k], pks [nq,k]; -1 = empty).

        ``filter_masks`` optionally maps segment_id -> row mask (attribute
        filtering, resolved by the proxy per segment).
        """
        if not self.alive:
            raise RuntimeError(f"query node {self.node_id} is down")
        if self.inject_delay_s > 0:
            import time as _t

            _t.sleep(self.inject_delay_s)
        self.search_count += 1
        ts = guarantee.query_ts
        nq = len(queries)
        pool_s: list[np.ndarray] = []
        pool_p: list[np.ndarray] = []

        from ..kernels import ops

        def scan_metric_str() -> str:
            return "l2" if metric is Metric.L2 else "ip"

        # ---- sealed segments (indexed or brute) ----
        for (coll, sid), handle in self.sealed.items():
            if coll != collection:
                continue
            seg = handle.segment
            if seg.num_rows == 0:
                continue
            mask = self._visible(collection, seg, ts)
            if filter_masks and sid in filter_masks:
                mask = mask & filter_masks[sid]
            if not mask.any():
                continue
            if handle.index is not None:
                s, i = handle.index.search(queries, k, valid=mask)
            else:
                s, i = ops.topk_scan(
                    queries, seg.vectors(), k, metric=scan_metric_str(), valid=mask
                )
            pks = seg.pks()
            p = np.where(i >= 0, pks[np.clip(i, 0, len(pks) - 1)], -1)
            pool_s.append(s)
            pool_p.append(p)

        # ---- growing segments (slice temp indexes + brute tail) ----
        for (coll, sid), gs in self.growing.items():
            if coll != collection:
                continue
            seg = gs.segment
            if seg.num_rows == 0:
                continue
            mask = self._visible(collection, seg, ts)
            if filter_masks and sid in filter_masks:
                mask = mask & filter_masks[sid]
            pks = seg.pks()
            vecs = seg.vectors()
            for s_idx, temp in gs.slice_index_built.items():
                lo, hi = seg.slice_bounds(s_idx)
                if not mask[lo:hi].any():
                    continue
                s, i = temp.search(queries, k, valid=mask[lo:hi])
                p = np.where(i >= 0, pks[lo:hi][np.clip(i, 0, hi - lo - 1)], -1)
                pool_s.append(s)
                pool_p.append(p)
            # tail (and any slice without a temp index yet)
            built = set(gs.slice_index_built)
            covered = np.zeros(seg.num_rows, dtype=bool)
            for s_idx in built:
                lo, hi = seg.slice_bounds(s_idx)
                covered[lo:hi] = True
            tail_mask = mask & ~covered
            if tail_mask.any():
                s, i = ops.topk_scan(
                    queries, vecs, k, metric=scan_metric_str(), valid=tail_mask
                )
                p = np.where(i >= 0, pks[np.clip(i, 0, len(pks) - 1)], -1)
                pool_s.append(s)
                pool_p.append(p)

        if not pool_s:
            fill = np.inf if metric is Metric.L2 else -np.inf
            return (
                np.full((nq, k), fill, np.float32),
                np.full((nq, k), -1, np.int64),
            )

        s = np.concatenate(pool_s, axis=1)
        p = np.concatenate(pool_p, axis=1)
        # node-wise merge with pk dedup (keep best occurrence)
        out_s = np.full((nq, k), np.inf if metric is Metric.L2 else -np.inf, np.float32)
        out_p = np.full((nq, k), -1, np.int64)
        order = np.argsort(s if metric is Metric.L2 else -s, axis=1, kind="stable")
        for r in range(nq):
            seen: set[int] = set()
            slot = 0
            for j in order[r]:
                pk = int(p[r, j])
                if pk < 0 or pk in seen:
                    continue
                if not np.isfinite(s[r, j]):
                    continue
                seen.add(pk)
                out_s[r, slot] = s[r, j]
                out_p[r, slot] = pk
                slot += 1
                if slot >= k:
                    break
        return out_s, out_p
