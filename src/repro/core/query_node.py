"""Query nodes: the search workers (paper §3.6).

A query node gets data from three sources:

* **WAL** — it subscribes to DML channels and keeps its own growing
  segments so freshly inserted rows are searchable within one time-tick;
  full slices get a light-weight temporary index (IVF-FLAT), the tail is
  brute-force scanned.
* **index files** — sealed segments' indexes, loaded from the object store
  when the query coordinator assigns the segment to this node.
* **binlog** — sealed segment columns, loaded on assignment/failover.

Searches run under MVCC: a query pinned at ``ts`` sees exactly the rows
with LSN <= ts that are not deleted as of ts.  Node-level results are the
node-wise top-k of the two-phase reduce; the proxy performs the global
merge and pk-dedup.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..index.base import IndexSpec, VectorIndex, normalize_if_cosine
from ..index.flat import FlatIndex
from ..index.ivf import IVFFlatIndex
from ..index.registry import create_index
from .binlog import load_segment
from .collection import Metric
from .consistency import GuaranteeTs
from .log import EntryType, LogBroker, LogEntry, Subscription, shard_of_channel
from .object_store import ObjectStore
from .request import PRIMARY_VECTOR_COLUMN, AnnsQuery, NodeSearchRequest
from .segment import DEFAULT_PARTITION, Segment, add_tombstone, flatten_tombstones
from .telemetry import MetricsRegistry

TEMP_INDEX_SLICE_ROWS = 2_048  # scaled-down default of the paper's 10k

# Selectivity-adaptive filtered-search thresholds.  ``n_comb`` is the
# surviving row count (visible AND matching), ``n_vis`` the visible count.
# brute (gather survivors, per-unit scan) wins while the survivor set is a
# small fraction of the segment — its per-row cost is higher (gather copy +
# unfused dispatch) but it touches only the survivors; the crossover vs the
# fused masked full scan sits around 1/3, so 0.25 leaves margin.  post
# (visibility-only scan at inflated k, cut after) is only ever chosen for
# index-backed units: a loose filter barely inflates k there and the index
# keeps its recall, while a tight mask fed INTO an ANN index starves its
# candidate pools.  Everything else pre-filters: bitmap ∧ visibility as the
# scan's validity mask.
FILTER_BRUTE_FRAC = 0.25
FILTER_BRUTE_MIN_ROWS = 64
FILTER_POST_FRAC = 0.5


def choose_filter_strategy(
    override: str | None, n_vis: int, n_comb: int, k: int, has_index: bool
) -> str:
    if override is not None:
        return override
    if n_comb <= max(2 * k, FILTER_BRUTE_MIN_ROWS) or n_comb <= FILTER_BRUTE_FRAC * n_vis:
        return "brute"
    if has_index and n_comb >= FILTER_POST_FRAC * n_vis:
        return "post"
    return "pre"


class StalePlanError(Exception):
    """The dispatch plan references segments this node can no longer serve
    at the request timestamp — a compaction swap or placement change landed
    between the proxy's planning and this scan.  The proxy catches this and
    re-plans from fresh placement (never a node failure)."""


def _seg_column(seg: Segment, column: str) -> np.ndarray | None:
    """A segment's stored column for one vector field (None if absent)."""
    if column == PRIMARY_VECTOR_COLUMN:
        return seg.vectors()
    if column in seg.extra_fields:
        return seg.extra(column)
    return None


def _scalar_columns(seg: Segment) -> dict[str, np.ndarray]:
    """The segment's filterable columns (pk + 1-D extras) for row-wise
    FilterExpr evaluation; vector extras are not filterable."""
    cols: dict[str, np.ndarray] = {"pk": seg.pks()}
    for f in seg.extra_fields:
        arr = np.asarray(seg.extra(f))
        if arr.ndim == 1:
            cols[f] = arr
    return cols


@dataclass
class SealedHandle:
    segment: Segment
    index: VectorIndex | None = None  # index on the primary vector column
    index_kind: str | None = None
    # Segment-map epoch gating (compaction hot-swap): a handle serves the
    # MVCC window [visible_from_ts, retired_at_ts).  Freshly sealed segments
    # cover everything; a compacted replacement starts at its compact_ts and
    # the sources it replaces end there, so a query pinned before the swap
    # keeps reading the old version until the retention horizon releases it.
    visible_from_ts: int = 0
    retired_at_ts: int | None = None
    # Indexes on additional vector columns (multi-vector schemas), keyed by
    # segment column name.
    extra_indexes: dict[str, VectorIndex] = field(default_factory=dict)
    extra_index_kinds: dict[str, str] = field(default_factory=dict)
    # Attribute indexes over scalar columns (pk + 1-D extras), loaded from
    # the segment's attr satellites (or rebuilt locally when absent); the
    # filtered-search planner resolves FilterExpr bitmaps through these.
    attr_indexes: dict[str, object] = field(default_factory=dict)

    def covers_ts(self, ts: int) -> bool:
        if ts < self.visible_from_ts:
            return False
        return self.retired_at_ts is None or ts < self.retired_at_ts

    def index_for(self, column: str) -> VectorIndex | None:
        if column == PRIMARY_VECTOR_COLUMN:
            return self.index
        return self.extra_indexes.get(column)

    def set_index(self, column: str, index: VectorIndex, kind: str) -> None:
        if column == PRIMARY_VECTOR_COLUMN:
            self.index, self.index_kind = index, kind
        else:
            self.extra_indexes[column] = index
            self.extra_index_kinds[column] = kind


@dataclass
class ScanUnit:
    """One plannable piece of search work: rows, masks, and how to run them.

    ``index`` set -> the unit executes through that index; otherwise
    ``vectors`` holds the rows for a brute-force scan.  ``pks`` maps the
    unit's local row indices back to primary keys.
    """

    segment_id: int
    pks: np.ndarray
    mask: np.ndarray  # visibility & delta-delete & attribute filter
    index: VectorIndex | None = None
    vectors: np.ndarray | None = None
    # Post-filter strategy state: ``post_mask`` is the attribute-filter
    # bitmap applied AFTER the scan (``mask`` then carries visibility only)
    # and ``k_extra`` is this unit's worst-case interloper count — rows
    # that pass visibility but fail the filter — so scanning top
    # (k + k_extra) provably contains the filtered top-k.
    post_mask: np.ndarray | None = None
    k_extra: int = 0


@dataclass
class SearchPlan:
    """Planner output: candidate units grouped by execution class.

    The two brute classes run as ONE fused scan each
    (``ops.topk_scan_segmented``); index-backed classes dispatch per
    unit since every index owns its own structure.
    """

    indexed: list[ScanUnit] = field(default_factory=list)  # sealed, index loaded
    brute_sealed: list[ScanUnit] = field(default_factory=list)  # sealed, no index
    growing_slice: list[ScanUnit] = field(default_factory=list)  # temp slice index
    brute_tail: list[ScanUnit] = field(default_factory=list)  # growing tail rows
    # Filtered-search strategy classes (empty without a filter):
    # post-filter units scan with visibility-only masks at an inflated k
    # and cut failing candidates afterwards; brute_filtered units gather
    # the surviving rows and scan just those, one dispatch per unit.
    post_indexed: list[ScanUnit] = field(default_factory=list)
    post_brute: list[ScanUnit] = field(default_factory=list)
    brute_filtered: list[ScanUnit] = field(default_factory=list)
    # Per-unit planning report for observability: dicts with segment_id,
    # strategy, estimated and actual selectivity.
    filter_info: list = field(default_factory=list)

    def units(self) -> "list[ScanUnit]":
        return (
            self.indexed + self.brute_sealed + self.growing_slice
            + self.brute_tail + self.post_indexed + self.post_brute
            + self.brute_filtered
        )


def _map_pks(idx: np.ndarray, pks: np.ndarray) -> np.ndarray:
    """Local row indices -> primary keys; -1 slots pass through."""
    pks = np.asarray(pks)
    return np.where(idx >= 0, pks[np.clip(idx, 0, len(pks) - 1)], -1)


@dataclass
class GrowingState:
    segment: Segment
    slice_index_built: dict[int, VectorIndex] = field(default_factory=dict)


class QueryNode:
    def __init__(
        self,
        node_id: str,
        broker: LogBroker,
        store: ObjectStore,
        tso=None,
        slice_rows: int = TEMP_INDEX_SLICE_ROWS,
        metrics: MetricsRegistry | None = None,
    ):
        self.node_id = node_id
        self.broker = broker
        self.store = store
        self.tso = tso
        self.slice_rows = slice_rows
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.subscriptions: dict[str, Subscription] = {}
        self.coord_sub = Subscription(broker, "coord") if broker.has_channel("coord") else None
        # LSN-keyed dedup: highest applied position per channel ("coord"
        # included).  The broker is at-least-once — duplicate delivery is an
        # injectable fault — so re-delivered entries must be no-ops.
        self._applied_pos: dict[str, int] = {}
        self.sealed: dict[tuple[str, int], SealedHandle] = {}
        self.growing: dict[tuple[str, int], GrowingState] = {}
        # Delta deletes for rows living in sealed segments:
        # coll -> pk -> delete ts (or a sorted ts list when the same pk was
        # deleted/upserted more than once).  Tombstones are row-ts aware: a
        # (pk, dts) pair kills only versions with row_ts < dts, so the
        # insert half of an upsert at the same LSN survives its own delete.
        self.delta_deletes: dict[str, dict[object, object]] = {}
        # Partitions dropped while this node serves the collection: WAL
        # replays must not resurrect their rows into growing segments.
        self.dropped_partitions: set[tuple[str, str]] = set()
        # Tombstones folded into compacted segments, pending removal from
        # ``delta_deletes`` once the retention horizon passes (the old
        # segment versions still need them until then).
        self._pending_prunes: list[dict] = []
        self.alive = True
        self.search_count = 0
        # Hedge-aware accounting: hedged duplicates are booked separately so
        # least-loaded replica picks (and the admin API) see primary load
        # only — a straggler's bail-out copy is not organic demand.
        self.searches_primary = 0
        self.searches_hedged = 0
        self.inflight = 0  # concurrent search_request count (any kind)
        self.inflight_primary = 0  # dispatch-load key used by the picker
        self.inject_delay_s = 0.0  # straggler fault injection (tests/benches)

    # --------------------------------------------------------- subscriptions
    def subscribe(self, channel: str, from_position: int = 0) -> None:
        if channel not in self.subscriptions:
            self.subscriptions[channel] = Subscription(self.broker, channel, from_position)
            # A (re-)subscription is an intentional replay: accept entries
            # from its start position even if we consumed further before.
            self._applied_pos[channel] = from_position - 1

    def unsubscribe(self, channel: str) -> None:
        self.subscriptions.pop(channel, None)
        self._applied_pos.pop(channel, None)

    def watermark(self, collection: str) -> int:
        """Min last-time-tick over this node's channels for the collection."""
        marks = [
            sub.last_tick_seen
            for ch, sub in self.subscriptions.items()
            if ch.startswith(f"dml/{collection}/")
        ]
        return min(marks) if marks else 0

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        if not self.alive:
            return False
        progress = False
        if self.coord_sub is not None:
            watermark = self._applied_pos.get("coord", -1)
            for entry in self.coord_sub.poll():
                if entry.position <= watermark:
                    self.metrics.inc("log_dedup_skipped_total",
                                     labels={"node": self.node_id})
                    continue
                progress |= self._handle_coord(entry)
                watermark = entry.position
            self._applied_pos["coord"] = watermark
        for sub in list(self.subscriptions.values()):
            watermark = self._applied_pos.get(sub.channel, -1)
            for entry in sub.poll():
                if entry.position <= watermark:
                    self.metrics.inc("log_dedup_skipped_total",
                                     labels={"node": self.node_id})
                    continue
                progress |= self._consume(entry)
                watermark = entry.position
            if sub.channel in self.subscriptions:
                self._applied_pos[sub.channel] = watermark
        progress |= self._build_slice_indexes()
        return progress

    def _handle_coord(self, entry: LogEntry) -> bool:
        if entry.type is not EntryType.COORD:
            return False
        p = entry.payload
        msg = p.get("msg")
        if msg == "segment_loaded":
            # Another node owns the sealed copy now: hand off our growing rows.
            if p.get("node_id") != self.node_id:
                self.drop_growing(p["collection"], p["segment_id"])
            return True
        if msg == "tombstones":
            # Broadcast mirror of a delete/upsert-delete-half (same LSN as
            # the per-shard DML entries): placement is not shard-affine, so
            # a node can serve a sealed segment of a shard whose DML channel
            # it does not own — this is how it still learns about the kills.
            # add_tombstone/Segment.delete dedup (pk, ts), so nodes that DO
            # own the channel apply the pair of deliveries idempotently.
            self._apply_delete(p["collection"], p["pk"], entry.ts)
            return True
        if msg == "tombstones_folded":
            # Broadcast: a compaction folded these tombstones into a rewritten
            # segment.  Every node remembers them for pruning at the horizon.
            self._pending_prunes.append(
                {
                    "collection": p["collection"],
                    "folded_pks": np.asarray(p["folded_pks"]),
                    "compact_ts": p["compact_ts"],
                }
            )
            return True
        if msg == "retention_advance":
            return self.apply_retention(p["horizon_ts"], p.get("collection"))
        if msg == "partition_dropped":
            # Broadcast: drop the partition's segments everywhere at once
            # (sealed copies by id, growing copies by tag) and remember the
            # drop so later WAL replays don't resurrect its rows.
            coll, part = p["collection"], p["partition"]
            self.dropped_partitions.add((coll, part))
            for sid in p.get("segment_ids", ()):
                self.sealed.pop((coll, sid), None)
                self.growing.pop((coll, sid), None)
            for key, gs in list(self.growing.items()):
                if key[0] == coll and gs.segment.partition == part:
                    del self.growing[key]
            for key, handle in list(self.sealed.items()):
                if key[0] == coll and handle.segment.partition == part:
                    del self.sealed[key]
            return True
        if p.get("node_id") != self.node_id:
            return False
        if msg == "load_segment":
            self.load_sealed(
                p["collection"], p["segment_id"],
                visible_from_ts=p.get("visible_from_ts", 0),
            )
            if self.tso is not None:
                self.broker.publish(
                    "coord",
                    LogEntry(
                        ts=self.tso.next(),
                        type=EntryType.COORD,
                        payload={
                            "msg": "segment_loaded",
                            "node_id": self.node_id,
                            "collection": p["collection"],
                            "segment_id": p["segment_id"],
                        },
                    ),
                )
            return True
        if msg == "load_index":
            self.load_index(
                p["collection"], p["segment_id"], p["index_kind"], p["index_key"],
                column=p.get("column", PRIMARY_VECTOR_COLUMN),
            )
            return True
        if msg == "release_segment":
            self.release_segment(p["collection"], p["segment_id"])
            return True
        if msg == "retire_segment":
            self.retire_segment(
                p["collection"], p["segment_id"], p["retired_at_ts"]
            )
            return True
        if msg == "subscribe_channel":
            self.subscribe(p["channel"], p.get("from_position", 0))
            return True
        if msg == "unsubscribe_channel":
            self.unsubscribe(p["channel"])
            return True
        return False

    def _apply_delete(self, collection: str, pks, ts: int) -> None:
        """Record tombstones for sealed rows and growing copies alike."""
        dd = self.delta_deletes.setdefault(collection, {})
        for pk in np.asarray(pks).tolist():
            add_tombstone(dd, pk, ts)
        for (c, _sid), gs in self.growing.items():
            if c == collection:
                gs.segment.delete(pks, ts)

    def _consume(self, entry: LogEntry) -> bool:
        if entry.type in (EntryType.INSERT, EntryType.UPSERT):
            p = entry.payload
            if entry.type is EntryType.UPSERT:
                # Delete half of the atomic record: older versions of these
                # pks die at this LSN; the insert half below lands at the
                # SAME LSN, so visibility flips in one step.
                self._apply_delete(p["collection"], p["pk"], entry.ts)
            key = (p["collection"], p["segment_id"])
            partition = p.get("partition", DEFAULT_PARTITION)
            if (p["collection"], partition) in self.dropped_partitions:
                return True  # replay of a dropped partition: insert half void
            if key in self.sealed:
                # already have the sealed (authoritative) copy of the rows;
                # the upsert's delete half above still applies
                return entry.type is EntryType.UPSERT
            gs = self.growing.get(key)
            if gs is None:
                extra_fields = tuple(sorted(p.get("extras", {})))
                seg = Segment(
                    p["segment_id"], p["collection"], p["shard"],
                    p["vector"].shape[1], slice_rows=self.slice_rows,
                    extra_fields=extra_fields, partition=partition,
                )
                gs = GrowingState(seg)
                self.growing[key] = gs
            n = len(p["pk"])
            gs.segment.append(
                p["pk"], p["vector"], np.full(n, entry.ts, np.int64), p.get("extras")
            )
            return True
        if entry.type is EntryType.DELETE:
            p = entry.payload
            self._apply_delete(p["collection"], p["pk"], entry.ts)
            return True
        return False

    def _build_slice_indexes(self) -> bool:
        """Temporary IVF-FLAT per full slice of growing segments (paper §3.6).

        Built L2 (the WAL carries no collection metric); the planner only
        uses a temp index whose metric matches the request and leaves
        mismatched slices to the brute tail, so IP/cosine growing reads
        stay exact."""
        progress = False
        for gs in self.growing.values():
            for s in gs.segment.full_slices():
                if s in gs.slice_index_built:
                    continue
                lo, hi = gs.segment.slice_bounds(s)
                idx = IVFFlatIndex(metric=Metric.L2, nlist=16, nprobe=4)
                idx.build(gs.segment.vectors()[lo:hi])
                gs.slice_index_built[s] = idx
                progress = True
        return progress

    # ---------------------------------------------------------- assignments
    def load_sealed(
        self, collection: str, segment_id: int, visible_from_ts: int = 0
    ) -> None:
        key = (collection, segment_id)
        if key in self.sealed:
            return
        seg = load_segment(self.store, collection, segment_id)
        self.sealed[key] = SealedHandle(
            seg,
            visible_from_ts=visible_from_ts,
            attr_indexes=self._attr_indexes_for(seg),
        )
        # Hand-off: drop our growing copy of the same segment.
        self.growing.pop(key, None)

    def _attr_indexes_for(self, seg: Segment) -> dict[str, object]:
        """Attribute indexes for a sealed segment's scalar columns.

        Satellites are loaded from the object store; a missing satellite or
        one whose row count disagrees with the loaded segment (a stale
        bitmap must never serve a filtered read) is rebuilt locally from
        the columns — query nodes never write the store, so the healed
        copy stays node-local until recovery repairs the satellite.
        """
        from ..index.attribute import build_attribute_index
        from .binlog import load_attr_satellites

        columns: dict[str, np.ndarray] = {"pk": seg.pks()}
        for f in seg.extra_fields:
            arr = np.asarray(seg.extra(f))
            if arr.ndim == 1:
                columns[f] = arr
        loaded = load_attr_satellites(
            self.store, seg.collection, seg.segment_id, columns
        )
        out: dict[str, object] = {}
        for f, col in columns.items():
            idx = loaded.get(f)
            if idx is None or idx.n != seg.num_rows:
                idx = build_attribute_index(col)
                self.metrics.inc(
                    "query_node_attr_local_builds_total",
                    labels={"node": self.node_id},
                )
            out[f] = idx
        return out

    def load_index(
        self,
        collection: str,
        segment_id: int,
        kind: str,
        index_key: str,
        column: str = PRIMARY_VECTOR_COLUMN,
    ) -> None:
        handle = self.sealed.get((collection, segment_id))
        if handle is None:
            self.load_sealed(collection, segment_id)
            handle = self.sealed[(collection, segment_id)]
        index = VectorIndex.load(self.store.get(index_key))
        handle.set_index(column, index, kind)

    def release_segment(self, collection: str, segment_id: int) -> None:
        self.sealed.pop((collection, segment_id), None)
        self.growing.pop((collection, segment_id), None)

    def retire_segment(
        self, collection: str, segment_id: int, retired_at_ts: int
    ) -> None:
        """MVCC retirement: the segment was replaced by a compacted rewrite.

        The handle keeps serving queries pinned before ``retired_at_ts``
        until ``apply_retention`` drops it at the retention horizon.
        """
        handle = self.sealed.get((collection, segment_id))
        if handle is not None and handle.retired_at_ts is None:
            handle.retired_at_ts = retired_at_ts
        self.growing.pop((collection, segment_id), None)

    def apply_retention(
        self, horizon_ts: int, collection: str | None = None
    ) -> bool:
        """Drop retired segment versions and fold-pruned tombstones whose
        compaction timestamp fell behind the retention horizon
        (``collection=None`` applies to every collection)."""
        changed = False
        for key, handle in list(self.sealed.items()):
            if collection is not None and key[0] != collection:
                continue
            if handle.retired_at_ts is not None and handle.retired_at_ts <= horizon_ts:
                del self.sealed[key]
                changed = True
        still_pending: list[dict] = []
        for prune in self._pending_prunes:
            if (collection is not None and prune["collection"] != collection) or (
                prune["compact_ts"] > horizon_ts
            ):
                still_pending.append(prune)
                continue
            from .compaction import prune_folded

            pruned = prune_folded(
                self.delta_deletes.get(prune["collection"]) or {},
                prune["folded_pks"],
                prune["compact_ts"],
            )
            if pruned is not None:
                self.delta_deletes[prune["collection"]] = pruned
                changed = True
        self._pending_prunes = still_pending
        return changed

    def drop_growing(self, collection: str, segment_id: int) -> None:
        """Hand-off after another node loaded the sealed copy."""
        self.growing.pop((collection, segment_id), None)

    def held_segments(self, collection: str) -> list[int]:
        return sorted(sid for (c, sid) in self.sealed if c == collection)

    def memory_rows(self, collection: str | None = None) -> int:
        rows = sum(
            h.segment.num_rows
            for (c, _sid), h in self.sealed.items()
            if collection is None or c == collection
        )
        rows += sum(
            g.segment.num_rows
            for (c, _sid), g in self.growing.items()
            if collection is None or c == collection
        )
        return rows

    def segment_rows(self, collection: str) -> "dict[tuple[str, int, bool], int]":
        """(collection, segment_id, is_sealed) -> live row count; used by
        the per-collection entity count (replicated segments dedup upstream)."""
        out: dict[tuple[str, int, bool], int] = {}
        for (c, sid), h in self.sealed.items():
            if c == collection and h.retired_at_ts is None:
                out[(c, sid, True)] = h.segment.num_rows
        for (c, sid), g in self.growing.items():
            if c == collection:
                out[(c, sid, False)] = g.segment.num_rows
        return out

    # --------------------------------------------------------------- search
    def _request_doomed_pks(
        self, collection: str, ts: int
    ) -> "tuple[np.ndarray, np.ndarray] | None":
        """Materialize the delta-delete tombstone set ONCE per request.

        Returns ``(sorted pks, per-pk effective delete ts at query time)``
        or None.  Every segment then probes it with one vectorized binary
        search (``ops.tombstone_mask``) instead of rebuilding and
        re-sorting the set once per segment per query.  The effective-ts
        half makes the kill row-version aware: rows written at or after
        their pk's delete (upsert insert halves, re-inserts) survive.
        """
        dd = self.delta_deletes.get(collection)
        if not dd:
            return None
        from ..kernels import ops

        pks, dts = flatten_tombstones(dd)
        return ops.eff_tombstones(pks, dts, ts)

    _DOOMED_UNSET = object()  # sentinel: standalone call, derive the set here

    def _visible(
        self,
        collection: str,
        seg: Segment,
        ts: int,
        doomed=_DOOMED_UNSET,
    ) -> np.ndarray:
        from ..kernels import ops

        if doomed is QueryNode._DOOMED_UNSET:
            doomed = self._request_doomed_pks(collection, ts)
        mask = seg.visible_mask(ts)
        if doomed is not None:
            mask &= ~ops.tombstone_mask(
                seg.pks(), seg.timestamps(), doomed[0], doomed[1]
            )
        return mask

    def plan_search(
        self,
        collection: str,
        ts: int,
        filter_masks: "dict[int, np.ndarray] | None" = None,
        column: str = PRIMARY_VECTOR_COLUMN,
        metric: Metric | None = None,
        doomed=_DOOMED_UNSET,
        partitions: "tuple[str, ...] | None" = None,
        segments: "tuple[int, ...] | None" = None,
        shards: "tuple[int, ...] | None" = None,
        filter=None,
        filter_strategy: str | None = None,
        k: int = 10,
    ) -> SearchPlan:
        """Gather every candidate (segment, visibility, filter) unit for a
        request pinned at ``ts`` and group it by execution class.

        ``column`` selects the vector column being searched (multi-vector
        schemas); temporary slice indexes only exist for the primary
        column, so other columns scan growing segments brute-force.  For
        cosine requests pass ``metric`` so brute units take the segments'
        cached row-normalized columns (indexes normalize at build).
        ``doomed`` lets multi-field requests share one materialized
        delta-delete set across sub-requests.  ``partitions`` prunes the
        plan to segments tagged with one of the named partitions BEFORE
        any distance work happens (None = no pruning).  ``segments``
        scopes the *live* sealed scan to a replica-dispatch plan unit
        (None = everything the node holds); retired MVCC versions are
        exempt — they only exist on the nodes that served the pre-swap
        epoch, so pinned queries must always reach them.  ``shards``
        scopes the *growing* scan the same way: only growing segments fed
        by those shards' DML channels enter the plan (None = all, () =
        sealed data only) — a replica-aware dispatch must not serve a
        lagging growing copy of a channel routed to a fresher node.

        ``filter`` is the compiled :class:`FilterExpr`: sealed units
        resolve it through their attribute-index satellites and pick a
        selectivity-adaptive strategy per (segment, filter) unit —
        pre-filter / post-filter / brute (``filter_strategy`` forces one;
        ``k`` feeds the brute threshold and post inflation).  Growing rows
        have no satellites and always pre-filter via row-wise evaluation.
        """
        plan = SearchPlan()
        if doomed is QueryNode._DOOMED_UNSET:
            doomed = self._request_doomed_pks(collection, ts)
        prune = set(partitions) if partitions is not None else None
        scope = set(segments) if segments is not None else None
        unit_cols = metric is Metric.COSINE

        def brute_column(seg: Segment) -> np.ndarray | None:
            raw = _seg_column(seg, column)
            if raw is None:
                return None
            return seg.unit_column(column) if unit_cols else raw

        # ---- sealed segments: indexed or brute ----
        served: set[int] = set()
        for (coll, sid), handle in self.sealed.items():
            if coll != collection:
                continue
            if scope is not None and sid in scope:
                # A scoped live handle serves its unit even when it does
                # not cover ``ts`` (a rewrite pinned after this query: the
                # exempt retired sources carry the rows).  A scoped retired
                # handle only serves queries pinned before its swap.
                if handle.retired_at_ts is None or handle.covers_ts(ts):
                    served.add(sid)
            if not handle.covers_ts(ts):
                continue  # wrong segment-map epoch for this MVCC timestamp
            if (
                scope is not None
                and handle.retired_at_ts is None
                and sid not in scope
            ):
                continue  # another replica owns this plan unit
            seg = handle.segment
            if prune is not None and seg.partition not in prune:
                continue  # partition pruning: skip before any scan work
            if seg.num_rows == 0:
                continue
            mask = self._visible(collection, seg, ts, doomed)
            if filter_masks and sid in filter_masks:
                mask = mask & filter_masks[sid]
            if not mask.any():
                continue
            index = handle.index_for(column)
            if filter is not None:
                self._plan_filtered_unit(
                    plan, sid, seg, handle.attr_indexes, mask, index,
                    filter, filter_strategy, k, brute_column,
                )
                continue
            if index is not None:
                plan.indexed.append(
                    ScanUnit(sid, seg.pks(), mask, index=index)
                )
            else:
                vectors = brute_column(seg)
                if vectors is None:
                    continue  # segment predates the field; nothing to scan
                plan.brute_sealed.append(
                    ScanUnit(sid, seg.pks(), mask, vectors=vectors)
                )
        if scope is not None and scope - served:
            raise StalePlanError(
                f"{self.node_id}: scoped segments {sorted(scope - served)} "
                f"of '{collection}' are not serveable at ts={ts} "
                "(placement changed between plan and scan)"
            )

        # ---- growing segments: temp slice indexes + brute tail ----
        shard_scope = set(shards) if shards is not None else None
        for (coll, sid), gs in self.growing.items():
            if coll != collection:
                continue
            seg = gs.segment
            if shard_scope is not None and seg.shard not in shard_scope:
                continue  # another replica serves this channel's rows
            if prune is not None and seg.partition not in prune:
                continue
            if seg.num_rows == 0:
                continue
            mask = self._visible(collection, seg, ts, doomed)
            if filter_masks and sid in filter_masks:
                mask = mask & filter_masks[sid]
            pks = seg.pks()
            if filter is not None:
                fmask = np.asarray(
                    filter.evaluate(_scalar_columns(seg), seg.num_rows), bool
                )
                n_vis = int(mask.sum())
                mask = mask & fmask
                plan.filter_info.append({
                    "segment_id": sid, "strategy": "pre",
                    "est": float(fmask.mean()) if seg.num_rows else 0.0,
                    "actual": (int(mask.sum()) / n_vis) if n_vis else 0.0,
                })
                self.metrics.inc(
                    "filter_strategy_total", labels={"strategy": "pre"}
                )
            vectors = brute_column(seg)
            if vectors is None:
                continue
            covered = np.zeros(seg.num_rows, dtype=bool)
            if column == PRIMARY_VECTOR_COLUMN:
                for s_idx, temp in gs.slice_index_built.items():
                    if metric is not None and temp.metric is not metric:
                        # metric-mismatched temp index (built L2 off the
                        # WAL): leave the slice in the brute tail so the
                        # request's metric stays exact
                        continue
                    lo, hi = seg.slice_bounds(s_idx)
                    covered[lo:hi] = True
                    if not mask[lo:hi].any():
                        continue
                    plan.growing_slice.append(
                        ScanUnit(sid, pks[lo:hi], mask[lo:hi], index=temp)
                    )
            # tail = rows not covered by any temp index yet
            tail_mask = mask & ~covered
            if tail_mask.any():
                plan.brute_tail.append(
                    ScanUnit(sid, pks, tail_mask, vectors=vectors)
                )
        return plan

    def _plan_filtered_unit(
        self,
        plan: SearchPlan,
        sid: int,
        seg: Segment,
        attr_indexes: dict,
        mask: np.ndarray,
        index: VectorIndex | None,
        fexpr,
        override: str | None,
        k: int,
        brute_column,
    ) -> None:
        """Resolve the filter bitmap for one sealed unit and place it in
        the strategy class the selectivity estimate calls for."""
        from ..kernels import ops

        n = seg.num_rows
        try:
            fmask = fexpr.bitmap(attr_indexes, n)
            est = fexpr.estimate_selectivity(attr_indexes, n)
        except KeyError:
            # A filter field without a satellite (late-added schema field):
            # row-wise fallback keeps semantics identical.
            fmask = np.asarray(fexpr.evaluate(_scalar_columns(seg), n), bool)
            est = float(fmask.mean()) if n else 0.0
        n_vis = int(mask.sum())
        combined = ops.mask_intersect(mask, fmask)
        n_comb = int(combined.sum())
        actual = (n_comb / n_vis) if n_vis else 0.0
        strategy = choose_filter_strategy(
            override, n_vis, n_comb, k, index is not None
        )
        plan.filter_info.append({
            "segment_id": sid, "strategy": strategy,
            "est": est, "actual": actual, "rows": n_comb,
        })
        self.metrics.inc("filter_strategy_total", labels={"strategy": strategy})
        self.metrics.set_gauge(
            "filter_selectivity_est", est,
            labels={"collection": seg.collection, "segment": str(sid)},
        )
        self.metrics.set_gauge(
            "filter_selectivity_actual", actual,
            labels={"collection": seg.collection, "segment": str(sid)},
        )
        if n_comb == 0:
            return
        pks = seg.pks()
        if strategy == "brute":
            vectors = brute_column(seg)
            if vectors is None:
                return
            rows = np.nonzero(combined)[0]
            plan.brute_filtered.append(
                ScanUnit(
                    sid, pks[rows], np.ones(len(rows), dtype=bool),
                    vectors=np.ascontiguousarray(vectors[rows]),
                )
            )
        elif strategy == "post":
            unit = ScanUnit(
                sid, pks, mask, post_mask=fmask, k_extra=n_vis - n_comb
            )
            if index is not None:
                unit.index = index
                plan.post_indexed.append(unit)
            else:
                vectors = brute_column(seg)
                if vectors is None:
                    return
                unit.vectors = vectors
                plan.post_brute.append(unit)
        else:  # pre
            unit = ScanUnit(sid, pks, combined)
            if index is not None:
                unit.index = index
                plan.indexed.append(unit)
            else:
                vectors = brute_column(seg)
                if vectors is None:
                    return
                unit.vectors = vectors
                plan.brute_sealed.append(unit)

    def _execute_plan(
        self,
        plan: SearchPlan,
        queries: np.ndarray,
        k: int,
        metric: Metric,
        trace: tuple | None = None,
    ) -> tuple["list[np.ndarray]", "list[np.ndarray]"]:
        """Run a plan's units and return per-unit top-k candidate pools.

        ``trace`` is the optional ``(TraceContext, parent Span)`` pair: one
        child span per execution-class dispatch, carrying the segment ids
        and live-row count it actually scanned.
        """
        import time as _t

        from ..kernels import ops

        metric_str = "l2" if metric is Metric.L2 else "ip"
        pool_s: list[np.ndarray] = []
        pool_p: list[np.ndarray] = []

        def record_class(cls: str, units, t0: float) -> None:
            elapsed_us = (_t.perf_counter() - t0) * 1e6
            rows = int(sum(int(u.mask.sum()) for u in units))
            self.metrics.observe(
                "query_node_scan_us", elapsed_us, labels={"class": cls}
            )
            self.metrics.inc(
                "query_node_rows_scanned_total", rows, labels={"class": cls}
            )
            if trace is not None:
                ctx, parent = trace
                span = ctx.span(
                    f"scan_{cls}", parent=parent, node_id=self.node_id,
                    segment_ids=sorted({u.segment_id for u in units}),
                )
                span.duration_us = elapsed_us
                span.rows_scanned = rows

        # Index-backed units group by spec: all co-located segments sharing
        # an index configuration execute as ONE batched candidate-pool
        # dispatch (IVF runs its vectorized probe-gather-scan across the
        # group; other kinds fall back to per-index search inside).
        indexed_ids = {id(u) for u in plan.indexed}
        index_groups: dict = {}
        for unit in plan.indexed + plan.growing_slice:
            index_groups.setdefault(unit.index.batch_spec(), []).append(unit)
        for units in index_groups.values():
            t0 = _t.perf_counter()
            s, i, splits = type(units[0].index).search_batched(
                [u.index for u in units],
                queries,
                k,
                valids=[u.mask for u in units],
            )
            for j, unit in enumerate(units):
                blk = slice(splits[j], splits[j + 1])
                pool_s.append(s[:, blk])
                pool_p.append(_map_pks(i[:, blk], unit.pks))
            cls = "indexed" if id(units[0]) in indexed_ids else "growing_slice"
            record_class(cls, units, t0)
        # Brute classes run as one fused scan per class: a single shared
        # distance contraction, per-segment top-k extracted from it.
        # Cosine scans normalize both sides: the planner handed us the
        # segments' cached unit columns, only the queries normalize here
        # (indexes normalize at build and take raw queries).
        q_brute = normalize_if_cosine(metric, np.asarray(queries, np.float32))
        for cls, units in (
            ("brute_sealed", plan.brute_sealed),
            ("brute_tail", plan.brute_tail),
        ):
            if not units:
                continue
            t0 = _t.perf_counter()
            s, i = ops.topk_scan_segmented(
                q_brute,
                [u.vectors for u in units],
                k,
                metric=metric_str,
                valids=[u.mask for u in units],
            )
            for j, unit in enumerate(units):
                blk = slice(j * k, (j + 1) * k)
                pool_s.append(s[:, blk])
                pool_p.append(_map_pks(i[:, blk], unit.pks))
            record_class(cls, units, t0)
        # Post-filter classes scan with VISIBILITY-only valids at the
        # inflated class width k' = k + max(k_extra): each unit's k_extra is
        # its worst-case interloper count (visible rows failing the filter),
        # so the widened top-k' provably contains the filtered top-k. The
        # cut zeroes interlopers to (fill, -1); merge_topk drops them.
        if plan.post_indexed:
            post_groups: dict = {}
            for unit in plan.post_indexed:
                post_groups.setdefault(unit.index.batch_spec(), []).append(unit)
            for units in post_groups.values():
                t0 = _t.perf_counter()
                k_class = k + max(u.k_extra for u in units)
                s, i, splits = type(units[0].index).search_batched(
                    [u.index for u in units],
                    queries,
                    k_class,
                    valids=[u.mask for u in units],
                )
                for j, unit in enumerate(units):
                    blk = slice(splits[j], splits[j + 1])
                    cs, ci = ops.post_filter_cut(
                        s[:, blk], i[:, blk], unit.post_mask, metric=metric_str
                    )
                    pool_s.append(cs)
                    pool_p.append(_map_pks(ci, unit.pks))
                record_class("post_indexed", units, t0)
        if plan.post_brute:
            units = plan.post_brute
            t0 = _t.perf_counter()
            k_class = k + max(u.k_extra for u in units)
            s, i = ops.topk_scan_segmented(
                q_brute,
                [u.vectors for u in units],
                k_class,
                metric=metric_str,
                valids=[u.mask for u in units],
            )
            for j, unit in enumerate(units):
                blk = slice(j * k_class, (j + 1) * k_class)
                cs, ci = ops.post_filter_cut(
                    s[:, blk], i[:, blk], unit.post_mask, metric=metric_str
                )
                pool_s.append(cs)
                pool_p.append(_map_pks(ci, unit.pks))
            record_class("post_brute", units, t0)
        # Brute-filtered units already gathered their surviving rows: each
        # scans its own tiny vector block unfused (the gathers are ragged,
        # so a shared contraction buys nothing at these sizes).
        if plan.brute_filtered:
            t0 = _t.perf_counter()
            for unit in plan.brute_filtered:
                s, i = ops.topk_scan(
                    q_brute, unit.vectors, k, metric=metric_str
                )
                pool_s.append(s)
                pool_p.append(_map_pks(i, unit.pks))
            record_class("brute_filtered", plan.brute_filtered, t0)
        return pool_s, pool_p

    def search_request(
        self, request: NodeSearchRequest
    ) -> "list[tuple[np.ndarray, np.ndarray]]":
        """Execute a node-level request: one planned pipeline per vector
        column, returning the node-wise top-k candidate list PER sub-request
        (fusion of hybrid sub-requests happens at the proxy, where global
        per-field ranks exist; the radius cut is applied there too — cutting
        node-local lists would make results depend on segment placement
        whenever an inner ``range_filter`` bound is set).

        Each returned pair is (scores [nq,k], pks [nq,k]; -1 = empty).
        """
        if not self.alive:
            raise RuntimeError(f"query node {self.node_id} is down")
        import time as _t

        if self.inject_delay_s > 0:
            _t.sleep(self.inject_delay_s)
        self.search_count += 1
        if request.hedged:
            self.searches_hedged += 1
        else:
            self.searches_primary += 1
            self.inflight_primary += 1
        self.inflight += 1
        t0 = _t.perf_counter()
        try:
            return self._search_request(request)
        finally:
            self.inflight -= 1
            if not request.hedged:
                self.inflight_primary -= 1
            self.metrics.observe(
                "query_node_search_latency_us",
                (_t.perf_counter() - t0) * 1e6,
                labels={"node": self.node_id},
            )
            self.metrics.set_gauge(
                "node_searches_primary", self.searches_primary,
                labels={"node": self.node_id},
            )
            self.metrics.set_gauge(
                "node_searches_hedged", self.searches_hedged,
                labels={"node": self.node_id},
            )

    def _search_request(
        self, request: NodeSearchRequest
    ) -> "list[tuple[np.ndarray, np.ndarray]]":
        from ..kernels import ops

        metric = request.metric
        metric_str = "l2" if metric is Metric.L2 else "ip"
        ts = request.guarantee.query_ts
        fill = np.inf if metric is Metric.L2 else -np.inf
        # Materialize the delta-delete set ONCE for the whole request; every
        # sub-request's plan probes the same sorted array.
        doomed = self._request_doomed_pks(request.collection, ts)
        shards = (
            None
            if request.channels is None
            else tuple(sorted({shard_of_channel(c) for c in request.channels}))
        )
        trace = request.trace  # (TraceContext, parent Span) | None
        results: list[tuple[np.ndarray, np.ndarray]] = []
        for a in request.anns:
            queries = a.queries
            nq = len(queries)
            if trace is not None:
                ctx, parent = trace
                pspan = ctx.span(
                    "plan_search", parent=parent, node_id=self.node_id,
                    detail=f"column={a.field}",
                )
                with ctx.timed(pspan):
                    plan = self.plan_search(
                        request.collection, ts, request.filter_masks,
                        column=a.field, metric=metric, doomed=doomed,
                        partitions=request.partitions,
                        segments=request.segments,
                        shards=shards,
                        filter=request.filter,
                        filter_strategy=request.filter_strategy,
                        k=request.k,
                    )
                pspan.segment_ids = tuple(
                    sorted({u.segment_id for u in plan.units()})
                )
                if request.filter is not None and plan.filter_info:
                    fspan = ctx.span(
                        "filter_plan", parent=parent, node_id=self.node_id,
                        detail=",".join(
                            f"{fi['segment_id']}:{fi['strategy']}"
                            f"@{fi['actual']:.3f}"
                            for fi in plan.filter_info
                        ),
                    )
                    fspan.segment_ids = tuple(
                        fi["segment_id"] for fi in plan.filter_info
                    )
            else:
                plan = self.plan_search(
                    request.collection, ts, request.filter_masks,
                    column=a.field, metric=metric, doomed=doomed,
                    partitions=request.partitions, segments=request.segments,
                    shards=shards,
                    filter=request.filter,
                    filter_strategy=request.filter_strategy,
                    k=request.k,
                )
            pool_s, pool_p = self._execute_plan(
                plan, queries, request.k, metric, trace=trace
            )
            if not pool_s:
                out = (
                    np.full((nq, request.k), fill, np.float32),
                    np.full((nq, request.k), -1, np.int64),
                )
            else:
                if trace is not None:
                    ctx, parent = trace
                    mspan = ctx.span(
                        "node_merge_topk", parent=parent, node_id=self.node_id
                    )
                    with ctx.timed(mspan):
                        out = ops.merge_topk(
                            np.concatenate(pool_s, axis=1),
                            np.concatenate(pool_p, axis=1),
                            request.k,
                            metric=metric_str,
                        )
                else:
                    out = ops.merge_topk(
                        np.concatenate(pool_s, axis=1),
                        np.concatenate(pool_p, axis=1),
                        request.k,
                        metric=metric_str,
                    )
            results.append(out)
        return results

    def search(
        self,
        collection: str,
        queries: np.ndarray,
        k: int,
        metric: Metric,
        guarantee: GuaranteeTs,
        filter_masks: "dict[int, np.ndarray] | None" = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Node-wise top-k over the primary vector column (the legacy
        kwarg surface).  Returns (scores [nq,k], pks [nq,k]; -1 = empty).

        This is a thin facade: the call is packed into a single-field
        :class:`NodeSearchRequest` and executed by :meth:`search_request`,
        so both surfaces share one planned pipeline.
        """
        request = NodeSearchRequest(
            collection=collection,
            k=k,
            metric=metric,
            guarantee=guarantee,
            anns=[AnnsQuery(PRIMARY_VECTOR_COLUMN, queries)],
            filter_masks=filter_masks,
        )
        return self.search_request(request)[0]

    # ----------------------------------------------------------- hydration
    def fetch_fields(
        self,
        collection: str,
        pks: np.ndarray,
        columns: "list[str]",
        ts: int,
    ) -> "dict[str, tuple[np.ndarray, np.ndarray]]":
        """Gather stored column values for result pks (output-field
        hydration).  ``columns`` holds segment column names ("pk", the
        primary "vector" column, or any extras column).  Returns
        column -> (found_pks [n], values [n, ...]) over the rows visible
        at ``ts`` on this node; the proxy assembles the [nq, k] view.
        """
        from ..kernels import ops

        want = np.unique(np.asarray(pks))
        want = want[want >= 0]
        # Per column: list of (pks_hit, values) pairs.  Collected per
        # column (not with one shared pk list) so a segment lacking a
        # column simply contributes nothing for it and the pk/value
        # alignment of the other columns stays intact.
        out: dict[str, list] = {c: [] for c in columns}
        if want.size:
            doomed = self._request_doomed_pks(collection, ts)
            sources: list[Segment] = [
                h.segment
                for (c, _sid), h in self.sealed.items()
                if c == collection and h.covers_ts(ts)
            ]
            sources += [
                g.segment for (c, _sid), g in self.growing.items() if c == collection
            ]
            for seg in sources:
                if seg.num_rows == 0:
                    continue
                hit = self._visible(collection, seg, ts, doomed)
                hit &= ops.isin_sorted(seg.pks(), want)
                if not hit.any():
                    continue
                hit_pks = seg.pks()[hit]
                for c in columns:
                    col = seg.pks() if c == "pk" else _seg_column(seg, c)
                    if col is None:
                        continue  # segment predates the column
                    out[c].append((hit_pks, np.asarray(col)[hit]))
        return {
            c: (
                (np.concatenate([p for p, _v in out[c]]),
                 np.concatenate([v for _p, v in out[c]]))
                if out[c]
                else (np.empty(0, np.int64), np.empty(0))
            )
            for c in columns
        }
