"""Binlog: the column-based *base* part of the log (paper §3.3).

Data nodes convert row-based WAL entries into per-field column objects so
downstream readers fetch exactly the columns they need — index nodes read
only the vector column ("free from read amplification"), query nodes read
pk/vector/ts.

Object layout in the store:

    binlog/<collection>/<segment_id>/meta                 (segment header)
    binlog/<collection>/<segment_id>/col/<field>          (one object per column)
    index/<collection>/<segment_id>/<field>/<index_kind>  (built index files)
"""

from __future__ import annotations

import io
import json

import numpy as np

from .object_store import ObjectStore
from .segment import DEFAULT_PARTITION, Segment


def _col_key(collection: str, segment_id: int, field: str) -> str:
    return f"binlog/{collection}/{segment_id}/col/{field}"


def _meta_key(collection: str, segment_id: int) -> str:
    return f"binlog/{collection}/{segment_id}/meta"


def index_key(collection: str, segment_id: int, field: str, kind: str) -> str:
    return f"index/{collection}/{segment_id}/{field}/{kind}"


def _dump_array(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _load_array(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


def write_segment_binlog(store: ObjectStore, seg: Segment) -> dict[str, str]:
    """Persist a sealed segment as columnar binlog objects; returns keys."""
    keys: dict[str, str] = {}
    columns = {"pk": seg.pks(), "vector": seg.vectors(), "ts": seg.timestamps()}
    for f in seg.extra_fields:
        columns[f] = seg.extra(f)
    for field, arr in columns.items():
        key = _col_key(seg.collection, seg.segment_id, field)
        store.put(key, _dump_array(np.ascontiguousarray(arr)))
        keys[field] = key
    meta = {
        "segment_id": seg.segment_id,
        "collection": seg.collection,
        "shard": seg.shard,
        "partition": seg.partition,
        "dim": seg.dim,
        "num_rows": seg.num_rows,
        "checkpoint_pos": seg.checkpoint_pos,
        "fields": sorted(columns),
        "extra_fields": list(seg.extra_fields),
        "min_ts": seg.min_ts(),
        "max_ts": seg.max_ts(),
    }
    mk = _meta_key(seg.collection, seg.segment_id)
    store.put(mk, json.dumps(meta).encode())
    keys["meta"] = mk
    return keys


def read_binlog_meta(store: ObjectStore, collection: str, segment_id: int) -> dict:
    return json.loads(store.get(_meta_key(collection, segment_id)).decode())


def read_binlog_column(
    store: ObjectStore, collection: str, segment_id: int, field: str
) -> np.ndarray:
    """Fetch exactly one column — the no-read-amplification path."""
    return _load_array(store.get(_col_key(collection, segment_id, field)))


def load_segment(
    store: ObjectStore, collection: str, segment_id: int
) -> Segment:
    """Reconstruct a sealed segment from its binlog columns."""
    meta = read_binlog_meta(store, collection, segment_id)
    seg = Segment(
        segment_id=meta["segment_id"],
        collection=collection,
        shard=meta["shard"],
        dim=meta["dim"],
        extra_fields=tuple(meta.get("extra_fields", ())),
        partition=meta.get("partition", DEFAULT_PARTITION),
    )
    n = meta["num_rows"]
    if n:
        pks = read_binlog_column(store, collection, segment_id, "pk")
        vec = read_binlog_column(store, collection, segment_id, "vector")
        ts = read_binlog_column(store, collection, segment_id, "ts")
        extras = {
            f: read_binlog_column(store, collection, segment_id, f)
            for f in meta.get("extra_fields", ())
        }
        seg.append(pks, vec, ts, extras)
    seg.checkpoint_pos = meta["checkpoint_pos"]
    seg.seal()
    return seg


def list_segments(store: ObjectStore, collection: str) -> list[int]:
    ids = set()
    for m in store.list(f"binlog/{collection}/"):
        parts = m.key.split("/")
        if len(parts) >= 3 and parts[-1] == "meta":
            ids.add(int(parts[2]))
    return sorted(ids)
