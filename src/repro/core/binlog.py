"""Binlog: the column-based *base* part of the log (paper §3.3).

Data nodes convert row-based WAL entries into per-field column objects so
downstream readers fetch exactly the columns they need — index nodes read
only the vector column ("free from read amplification"), query nodes read
pk/vector/ts.

Object layout in the store:

    binlog/<collection>/<segment_id>/meta                 (segment header)
    binlog/<collection>/<segment_id>/col/<field>          (one object per column)
    index/<collection>/<segment_id>/<field>/<index_kind>  (built index files)
    attr/<collection>/<segment_id>/<field>                (attribute-index satellites)
"""

from __future__ import annotations

import io
import json

import numpy as np

from .object_store import ObjectStore
from .segment import DEFAULT_PARTITION, Segment


def _col_key(collection: str, segment_id: int, field: str) -> str:
    return f"binlog/{collection}/{segment_id}/col/{field}"


def _meta_key(collection: str, segment_id: int) -> str:
    return f"binlog/{collection}/{segment_id}/meta"


def index_key(collection: str, segment_id: int, field: str, kind: str) -> str:
    return f"index/{collection}/{segment_id}/{field}/{kind}"


def attr_key(collection: str, segment_id: int, field: str) -> str:
    return f"attr/{collection}/{segment_id}/{field}"


def _dump_array(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _load_array(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


def write_segment_binlog(store: ObjectStore, seg: Segment) -> dict[str, str]:
    """Persist a sealed segment as columnar binlog objects; returns keys."""
    keys: dict[str, str] = {}
    columns = {"pk": seg.pks(), "vector": seg.vectors(), "ts": seg.timestamps()}
    for f in seg.extra_fields:
        columns[f] = seg.extra(f)
    for field, arr in columns.items():
        key = _col_key(seg.collection, seg.segment_id, field)
        store.put(key, _dump_array(np.ascontiguousarray(arr)))
        keys[field] = key
    meta = {
        "segment_id": seg.segment_id,
        "collection": seg.collection,
        "shard": seg.shard,
        "partition": seg.partition,
        "dim": seg.dim,
        "num_rows": seg.num_rows,
        "checkpoint_pos": seg.checkpoint_pos,
        "fields": sorted(columns),
        "extra_fields": list(seg.extra_fields),
        "min_ts": seg.min_ts(),
        "max_ts": seg.max_ts(),
    }
    mk = _meta_key(seg.collection, seg.segment_id)
    store.put(mk, json.dumps(meta).encode())
    keys["meta"] = mk
    return keys


def read_binlog_meta(store: ObjectStore, collection: str, segment_id: int) -> dict:
    return json.loads(store.get(_meta_key(collection, segment_id)).decode())


def read_binlog_column(
    store: ObjectStore, collection: str, segment_id: int, field: str
) -> np.ndarray:
    """Fetch exactly one column — the no-read-amplification path."""
    return _load_array(store.get(_col_key(collection, segment_id, field)))


def load_segment(
    store: ObjectStore, collection: str, segment_id: int
) -> Segment:
    """Reconstruct a sealed segment from its binlog columns."""
    meta = read_binlog_meta(store, collection, segment_id)
    seg = Segment(
        segment_id=meta["segment_id"],
        collection=collection,
        shard=meta["shard"],
        dim=meta["dim"],
        extra_fields=tuple(meta.get("extra_fields", ())),
        partition=meta.get("partition", DEFAULT_PARTITION),
    )
    n = meta["num_rows"]
    if n:
        pks = read_binlog_column(store, collection, segment_id, "pk")
        vec = read_binlog_column(store, collection, segment_id, "vector")
        ts = read_binlog_column(store, collection, segment_id, "ts")
        extras = {
            f: read_binlog_column(store, collection, segment_id, f)
            for f in meta.get("extra_fields", ())
        }
        seg.append(pks, vec, ts, extras)
    seg.checkpoint_pos = meta["checkpoint_pos"]
    seg.seal()
    return seg


# -- attribute-index satellites ---------------------------------------------
# Built from scalar columns (pk + 1-D extras) at seal/compaction and persisted
# next to the binlog; 2-D extras are vector columns and carry no attr index.


def _write_attr_satellites(
    store: ObjectStore, collection: str, segment_id: int, columns: dict[str, np.ndarray]
) -> dict[str, str]:
    from ..index.attribute import build_attribute_index  # local: avoid cycle

    keys: dict[str, str] = {}
    for field, arr in columns.items():
        arr = np.asarray(arr)
        if arr.ndim != 1:
            continue
        key = attr_key(collection, segment_id, field)
        store.put(key, build_attribute_index(arr).save())
        keys[field] = key
    return keys


def write_attr_satellites(store: ObjectStore, seg: Segment) -> dict[str, str]:
    """Build + persist attribute indexes for a sealed segment's scalar columns."""
    columns: dict[str, np.ndarray] = {"pk": seg.pks()}
    for f in seg.extra_fields:
        columns[f] = seg.extra(f)
    return _write_attr_satellites(store, seg.collection, seg.segment_id, columns)


def rebuild_attr_satellites(
    store: ObjectStore, collection: str, segment_id: int
) -> dict[str, str]:
    """(Re)build attr satellites straight from binlog columns (recovery path)."""
    meta = read_binlog_meta(store, collection, segment_id)
    columns = {"pk": read_binlog_column(store, collection, segment_id, "pk")}
    for f in meta.get("extra_fields", ()):
        columns[f] = read_binlog_column(store, collection, segment_id, f)
    return _write_attr_satellites(store, collection, segment_id, columns)


def load_attr_satellites(
    store: ObjectStore, collection: str, segment_id: int, fields
) -> dict[str, object]:
    """Load whichever attr satellites exist for ``fields`` (missing ones are
    simply absent from the result; callers rebuild locally)."""
    from ..index.attribute import load_attribute_index  # local: avoid cycle

    out: dict[str, object] = {}
    for f in fields:
        key = attr_key(collection, segment_id, f)
        if store.exists(key):
            out[f] = load_attribute_index(store.get(key))
    return out


def list_segments(store: ObjectStore, collection: str) -> list[int]:
    ids = set()
    for m in store.list(f"binlog/{collection}/"):
        parts = m.key.split("/")
        if len(parts) >= 3 and parts[-1] == "meta":
            ids.add(int(parts[2]))
    return sorted(ids)
