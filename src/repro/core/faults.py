"""Deterministic fault injection at the infrastructure boundaries (§6.3).

Production Manu sits on three failure-prone substrates — object store, meta
store, log broker — and its nodes die.  This module makes all of those
failures *reproducible*: a seeded ``FaultInjector`` holds step-addressable
fault rules, and thin ``Faulty*`` wrappers installed at the store/meta/broker
boundaries (plus the node entry points in ``ManuSystem.pump``) consult it on
every call.  Supported fault kinds:

- ``transient``     — raise the matching ``Transient*Error`` (absorbed by the
                      retry plane in ``core/retry.py``)
- ``latency``       — inject a delay spike (ManualClock advance or real sleep)
- ``duplicate``     — re-deliver already-consumed log entries on ``read`` (an
                      at-least-once broker), exercising LSN-keyed dedup
- ``cas_conflict``  — make ``MetaStore.cas`` lose the race without applying,
                      exercising every CAS loop (placement, claims, maps)
- ``crash``         — raise ``Crash``, which ``ManuSystem.pump`` converts into
                      a node kill; recovery is then a ``restart_*`` call

Every injected fault is recorded in the PR 7 ``EventLog``/``MetricsRegistry``
so chaos runs leave an auditable trail.  Rules are deterministic: same seed,
same workload, same faults — which is what lets the chaos suite and the
crash-at-every-step tests replay bit-for-bit.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass, field

from .object_store import ObjectStore
from .retry import (
    TransientLogError,
    TransientMetaError,
    TransientStoreError,
)


class Crash(BaseException):
    """Simulated process kill.

    Deliberately a ``BaseException`` (like ``KeyboardInterrupt``): a real
    ``kill -9`` runs no ``except Exception`` cleanup, so claim releases and
    rollback paths must NOT fire — recovery has to cope with the leaked
    state instead.  Only ``ManuSystem.pump`` (and tests) catch it, turning
    it into a node death."""

    def __init__(self, site: str, step: int, key: str = ""):
        super().__init__(f"injected crash at {site} step {step} key={key!r}")
        self.site = site
        self.step = step
        self.key = key


KINDS = ("transient", "latency", "duplicate", "cas_conflict", "crash")


@dataclass
class FaultRule:
    """One fault to inject; matched by call site + optional key substring."""

    site: str  # "" matches every site (for global-op addressing)
    kind: str
    match: str = ""  # substring of the key/channel, "" matches all
    prob: float = 0.0  # probabilistic firing (seeded)
    at_steps: frozenset[int] = frozenset()  # 1-based indices of *matching* calls
    at_ops: frozenset[int] = frozenset()  # 1-based global op indices
    max_fires: int | None = None  # total budget, None = unbounded
    burst: int = 2  # max consecutive fires (keeps retries convergent)
    delay_ms: float = 5.0  # for kind="latency"
    rewind: int = 2  # for kind="duplicate": entries re-delivered
    seen: int = 0  # matching invocations so far
    fires: int = 0
    _consec: int = 0

    def matches(self, site: str, key: str) -> bool:
        if self.site and self.site != site:
            return False
        return self.match in key


class FaultInjector:
    """Seeded, step-addressable fault plans, consulted by the Faulty* wrappers."""

    def __init__(self, seed: int = 0, *, metrics=None, event_log=None, clock=None):
        self.seed = seed
        self.rng = random.Random(seed)
        self.rules: list[FaultRule] = []
        self.metrics = metrics
        self.event_log = event_log
        self.clock = clock  # ManualClock -> latency advances it; else real sleep
        self.armed = True
        self.ops = 0  # global invocation counter across all sites

    # -- wiring (ManuSystem attaches its telemetry after construction) -----
    def bind(self, *, metrics=None, event_log=None, clock=None) -> "FaultInjector":
        if metrics is not None:
            self.metrics = metrics
        if event_log is not None:
            self.event_log = event_log
        if clock is not None:
            self.clock = clock
        return self

    # -- rule construction -------------------------------------------------
    def add(self, rule: FaultRule) -> FaultRule:
        if rule.kind not in KINDS:
            raise ValueError(f"unknown fault kind {rule.kind!r}")
        self.rules.append(rule)
        return rule

    def transient(self, site: str, prob: float, *, match: str = "",
                  burst: int = 2, max_fires: int | None = None) -> FaultRule:
        return self.add(FaultRule(site, "transient", match=match, prob=prob,
                                  burst=burst, max_fires=max_fires))

    def latency(self, site: str, prob: float, *, delay_ms: float = 5.0,
                match: str = "", max_fires: int | None = None) -> FaultRule:
        return self.add(FaultRule(site, "latency", match=match, prob=prob,
                                  delay_ms=delay_ms, max_fires=max_fires))

    def duplicates(self, prob: float, *, match: str = "", rewind: int = 2,
                   max_fires: int | None = None) -> FaultRule:
        return self.add(FaultRule("log.read", "duplicate", match=match, prob=prob,
                                  rewind=rewind, max_fires=max_fires))

    def cas_conflicts(self, prob: float, *, match: str = "", burst: int = 2,
                      max_fires: int | None = None) -> FaultRule:
        return self.add(FaultRule("meta.cas", "cas_conflict", match=match,
                                  prob=prob, burst=burst, max_fires=max_fires))

    def crash_at(self, site: str, step: int, *, match: str = "") -> FaultRule:
        return self.add(FaultRule(site, "crash", match=match,
                                  at_steps=frozenset({step}), max_fires=1,
                                  burst=1))

    def crash_at_op(self, op: int) -> FaultRule:
        """Crash at the N-th faultable operation anywhere in the system."""
        return self.add(FaultRule("", "crash", at_ops=frozenset({op}),
                                  max_fires=1, burst=1))

    def disarm(self) -> None:
        """Stop injecting (e.g. after the recovery phase of a chaos test)."""
        self.armed = False

    def arm(self) -> None:
        self.armed = True

    # -- the hot path --------------------------------------------------------
    def check(self, site: str, key: str = "") -> FaultRule | None:
        """Called by wrappers on every operation; returns the firing rule."""
        self.ops += 1
        if not self.armed or not self.rules:
            return None
        fired: FaultRule | None = None
        for rule in self.rules:
            if not rule.matches(site, key):
                continue
            rule.seen += 1
            if fired is not None:
                continue  # still count `seen` on later rules, fire first only
            if rule.max_fires is not None and rule.fires >= rule.max_fires:
                rule._consec = 0
                continue
            hit = (
                rule.seen in rule.at_steps
                or self.ops in rule.at_ops
                or (rule.prob > 0.0 and self.rng.random() < rule.prob)
            )
            if hit and rule._consec >= rule.burst:
                hit = False  # bounded consecutive fires: let retries converge
            if not hit:
                rule._consec = 0
                continue
            rule.fires += 1
            rule._consec += 1
            fired = rule
        if fired is not None:
            self._record(fired, site, key)
        return fired

    def _record(self, rule: FaultRule, site: str, key: str) -> None:
        if self.metrics is not None:
            self.metrics.inc("faults_injected_total",
                             labels={"site": site, "kind": rule.kind})
        if self.event_log is not None:
            self.event_log.emit("fault_injected", source="faults", site=site,
                                fault_kind=rule.kind, key=key, step=rule.seen,
                                op=self.ops)

    # -- fault realizations shared by the wrappers ---------------------------
    def sleep_ms(self, ms: float) -> None:
        if self.clock is not None and hasattr(self.clock, "advance"):
            self.clock.advance(ms)
        else:
            time.sleep(ms / 1e3)

    def apply(self, rule: FaultRule | None, site: str, key: str,
              error: type[Exception]) -> None:
        """Standard realization: latency sleeps, transient raises, crash kills."""
        if rule is None:
            return
        if rule.kind == "latency":
            self.sleep_ms(rule.delay_ms)
        elif rule.kind == "transient":
            raise error(f"injected transient at {site} key={key!r}")
        elif rule.kind == "crash":
            raise Crash(site, rule.seen, key)
        # duplicate / cas_conflict are realized by the specific wrapper


# --------------------------------------------------------------------------
# Boundary wrappers
# --------------------------------------------------------------------------


class FaultyObjectStore(ObjectStore):
    """Injects faults in front of any ``ObjectStore``."""

    def __init__(self, inner: ObjectStore, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    def _gate(self, op: str, key: str) -> None:
        site = f"object_store.{op}"
        rule = self.injector.check(site, key)
        self.injector.apply(rule, site, key, TransientStoreError)

    def put(self, key: str, data: bytes):
        self._gate("put", key)
        return self.inner.put(key, data)

    def get(self, key: str) -> bytes:
        self._gate("get", key)
        return self.inner.get(key)

    def exists(self, key: str) -> bool:
        self._gate("exists", key)
        return self.inner.exists(key)

    def delete(self, key: str) -> bool:
        self._gate("delete", key)
        return self.inner.delete(key)

    def list(self, prefix: str = ""):
        self._gate("list", prefix)
        return self.inner.list(prefix)

    def __getattr__(self, name: str):
        return getattr(self.inner, name)


class FaultyMetaStore:
    """Injects faults (incl. CAS conflict storms) in front of ``MetaStore``."""

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    def _gate(self, op: str, key: str) -> FaultRule | None:
        site = f"meta.{op}"
        rule = self.injector.check(site, key)
        self.injector.apply(rule, site, key, TransientMetaError)
        return rule

    def put(self, key, value, lease_id=None):
        self._gate("put", key)
        return self.inner.put(key, value, lease_id=lease_id)

    def get(self, key, default=None):
        self._gate("get", key)
        return self.inner.get(key, default)

    def get_rev(self, key):
        self._gate("get_rev", key)
        return self.inner.get_rev(key)

    def delete(self, key):
        self._gate("delete", key)
        return self.inner.delete(key)

    def cas(self, key, expected_rev, value):
        rule = self._gate("cas", key)
        if rule is not None and rule.kind == "cas_conflict":
            return False  # lost the race; nothing applied
        return self.inner.cas(key, expected_rev, value)

    def scan(self, prefix):
        self._gate("scan", prefix)
        return self.inner.scan(prefix)

    def __getattr__(self, name: str):
        return getattr(self.inner, name)


class FaultyLogBroker:
    """Injects faults in front of ``LogBroker``; ``duplicate`` rules turn
    ``read`` into an at-least-once delivery (entries below ``from_position``
    are re-delivered), which is exactly what Kafka/Pulsar consumers must
    tolerate and what the subscribers' LSN-keyed dedup absorbs."""

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    def publish(self, channel, entry):
        site = "log.publish"
        rule = self.injector.check(site, channel)
        self.injector.apply(rule, site, channel, TransientLogError)
        return self.inner.publish(channel, entry)

    def read(self, channel, from_position, max_entries=None):
        site = "log.read"
        rule = self.injector.check(site, channel)
        self.injector.apply(rule, site, channel, TransientLogError)
        if rule is not None and rule.kind == "duplicate" and from_position > 0:
            start = max(0, from_position - rule.rewind)
            return self.inner.read(channel, start, max_entries)
        return self.inner.read(channel, from_position, max_entries)

    def __getattr__(self, name: str):
        return getattr(self.inner, name)
