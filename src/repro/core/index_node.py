"""Index nodes: asynchronous index builders (paper §3.5).

Index nodes receive build tasks from the index coordinator over the
coordination channel, claim them with a meta-store CAS (so concurrent index
nodes never duplicate work), read **only the vector column** of the binlog
(no read amplification), build the index, persist it to the object store,
and announce ``index_built``.
"""

from __future__ import annotations

from ..index.base import IndexSpec
from ..index.registry import create_index
from .binlog import index_key, read_binlog_column
from .collection import Metric
from .log import COORD_CHANNEL, EntryType, LogBroker, LogEntry, Subscription
from .meta_store import MetaStore
from .object_store import ObjectStore
from .telemetry import MetricsRegistry
from .timestamp import TSO


class IndexNode:
    def __init__(
        self,
        node_id: str,
        broker: LogBroker,
        store: ObjectStore,
        meta: MetaStore,
        tso: TSO,
        metrics: MetricsRegistry | None = None,
    ):
        self.node_id = node_id
        self.broker = broker
        self.store = store
        self.meta = meta
        self.tso = tso
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.sub = Subscription(broker, COORD_CHANNEL)
        self.alive = True
        self.builds_completed = 0
        self.busy_fraction = 0.0  # bookkeeping for the idle-shutdown policy

    def step(self) -> bool:
        if not self.alive:
            return False
        progress = False
        for entry in self.sub.poll():
            if entry.type is not EntryType.COORD:
                continue
            p = entry.payload
            if p.get("msg") != "index_build_task":
                continue
            progress |= self._try_build(p)
        return progress

    def _try_build(self, task: dict) -> bool:
        coll = task["collection"]
        sid = task["segment_id"]
        kind = task["index_kind"]
        # Per-field builds: the task names the schema field and the binlog
        # column backing it (the first vector field is stored as "vector").
        field = task.get("field", "vector")
        column = task.get("column", field)
        # Replay safety: a task re-read from the coord channel after a crash
        # may name a segment GC already reclaimed — nothing to build.
        if not self.store.exists(f"binlog/{coll}/{sid}/meta"):
            return False
        claim_key = f"index_claim/{coll}/{sid}/{field}/{kind}"
        # CAS claim: only one index node builds a given task.
        if not self.meta.cas(claim_key, None, {"owner": self.node_id}):
            return False

        import time as _t

        t0 = _t.perf_counter()
        try:
            vectors = read_binlog_column(self.store, coll, sid, column)
            spec = IndexSpec(
                kind=kind,
                metric=Metric(task.get("metric", "l2")),
                params=task.get("params") or {},
                field=field,
            )
            index = create_index(spec)
            index.build(vectors)
            key = index_key(coll, sid, field, kind)
            self.store.put(key, index.save())
        except Exception:
            # Release the claim so the task stays takeable.  (A simulated
            # Crash is a BaseException: the claim leaks, as with a real
            # kill — IndexCoordinator.recover_state clears it.)
            self.meta.delete(claim_key)
            raise
        self.builds_completed += 1
        self.metrics.observe(
            "index_build_us", (_t.perf_counter() - t0) * 1e6,
            labels={"kind": kind},
        )
        self.metrics.inc("index_builds_total", labels={"kind": kind})

        self.broker.publish(
            COORD_CHANNEL,
            LogEntry(
                ts=self.tso.next(),
                type=EntryType.COORD,
                payload={
                    "msg": "index_built",
                    "collection": coll,
                    "segment_id": sid,
                    "field": field,
                    "column": column,
                    "index_kind": kind,
                    "index_key": key,
                    "built_by": self.node_id,
                },
            ),
        )
        return True
