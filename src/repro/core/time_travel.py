"""Time travel: checkpoint + WAL replay restore (paper §4.3).

Each checkpoint stores the collection's *segment map* — routes, not data:
sealed segment ids (their binlog objects are immutable in the object store)
and the per-shard WAL replay positions.  Restoring to physical time T:

  1. load the closest checkpoint at or before T,
  2. load the mapped sealed segments from binlog (cheap: segments are
     shared between checkpoints, nothing is copied),
  3. replay the WAL from each shard's checkpointed position, applying only
     entries with LSN <= T,
  4. MVCC does the rest: visibility at T filters rows inserted later and
     resurrects rows deleted later.

``expire(before_ts)`` implements the paper's retention policy: drop WAL
entries and checkpoints older than the expiration horizon.
"""

from __future__ import annotations

import json
from dataclasses import dataclass

import numpy as np

from .binlog import load_segment
from .log import EntryType, LogBroker, dml_channel
from .object_store import ObjectStore
from .segment import Segment
from .timestamp import physical_of


def _ckpt_key(collection: str, ts: int) -> str:
    return f"checkpoint/{collection}/{ts:020d}"


@dataclass
class Checkpoint:
    collection: str
    ts: int
    sealed_segment_ids: list[int]
    replay_positions: dict[str, int]  # channel -> position


class TimeTravel:
    def __init__(self, broker: LogBroker, store: ObjectStore):
        self.broker = broker
        self.store = store

    # ----------------------------------------------------------- checkpoint
    def checkpoint(
        self,
        collection: str,
        ts: int,
        sealed_segment_ids: list[int],
        num_shards: int,
        replay_positions: dict[str, int] | None = None,
    ) -> Checkpoint:
        if replay_positions is None:
            replay_positions = {
                dml_channel(collection, s): 0 for s in range(num_shards)
            }
        ckpt = Checkpoint(collection, ts, sorted(sealed_segment_ids), replay_positions)
        self.store.put(
            _ckpt_key(collection, ts),
            json.dumps(
                {
                    "collection": ckpt.collection,
                    "ts": ckpt.ts,
                    "sealed_segment_ids": ckpt.sealed_segment_ids,
                    "replay_positions": ckpt.replay_positions,
                }
            ).encode(),
        )
        return ckpt

    def checkpoints(self, collection: str) -> list[Checkpoint]:
        out = []
        for m in self.store.list(f"checkpoint/{collection}/"):
            d = json.loads(self.store.get(m.key).decode())
            out.append(
                Checkpoint(
                    d["collection"], d["ts"], d["sealed_segment_ids"], d["replay_positions"]
                )
            )
        return sorted(out, key=lambda c: c.ts)

    def closest_before(self, collection: str, target_ts: int) -> Checkpoint | None:
        best = None
        for c in self.checkpoints(collection):
            if c.ts <= target_ts:
                best = c
        return best

    # -------------------------------------------------------------- restore
    def restore(
        self, collection: str, target_ts: int, num_shards: int, dim: int
    ) -> "RestoredCollection":
        ckpt = self.closest_before(collection, target_ts)
        segments: list[Segment] = []
        replay_from: dict[str, int] = {}
        if ckpt is not None:
            for sid in ckpt.sealed_segment_ids:
                segments.append(load_segment(self.store, collection, sid))
            replay_from = dict(ckpt.replay_positions)
        for shard in range(num_shards):
            replay_from.setdefault(dml_channel(collection, shard), 0)

        # Replay WAL into a single reconstruction segment per shard.
        recon: dict[int, Segment] = {}
        deletes: list[tuple[np.ndarray, int]] = []
        known_sealed = {s.segment_id for s in segments}
        for channel, pos in replay_from.items():
            shard = int(channel.rsplit("/", 1)[1])
            for entry in self.broker.read(channel, pos):
                if entry.ts > target_ts:
                    break
                if entry.type in (EntryType.INSERT, EntryType.UPSERT):
                    p = entry.payload
                    if entry.type is EntryType.UPSERT:
                        # Delete half of the atomic record applies even when
                        # the insert half is already materialized from a
                        # sealed binlog (the OLD versions may live anywhere);
                        # row-ts-aware tombstones make the replay order-free.
                        deletes.append((p["pk"], entry.ts))
                    if p["segment_id"] in known_sealed:
                        continue  # already materialized from binlog
                    seg = recon.get(shard)
                    if seg is None:
                        seg = Segment(-1000 - shard, collection, shard, dim)
                        recon[shard] = seg
                    n = len(p["pk"])
                    seg.append(p["pk"], p["vector"], np.full(n, entry.ts, np.int64))
                elif entry.type is EntryType.DELETE:
                    deletes.append((entry.payload["pk"], entry.ts))
        segments.extend(recon.values())
        for pks, ts in deletes:
            for seg in segments:
                seg.delete(pks, ts)
        return RestoredCollection(collection, target_ts, segments)

    # ------------------------------------------------------------ retention
    def expire(self, collection: str, before_ts: int, num_shards: int) -> int:
        dropped = 0
        for shard in range(num_shards):
            dropped += self.broker.truncate_before(dml_channel(collection, shard), before_ts)
        for c in self.checkpoints(collection):
            if c.ts < before_ts:
                self.store.delete(_ckpt_key(collection, c.ts))
        return dropped


class RestoredCollection:
    """A standalone, queryable snapshot of the collection at ``target_ts``."""

    def __init__(self, name: str, ts: int, segments: list[Segment]):
        self.name = name
        self.ts = ts
        self.segments = segments

    def num_rows(self) -> int:
        return int(sum(s.visible_mask(self.ts).sum() for s in self.segments))

    def pks(self) -> np.ndarray:
        parts = [s.pks()[s.visible_mask(self.ts)] for s in self.segments]
        return np.sort(np.concatenate(parts)) if parts else np.empty(0, np.int64)

    def search(self, queries: np.ndarray, k: int, metric_str: str = "l2"):
        from ..kernels import ops

        pools_s, pools_p = [], []
        for seg in self.segments:
            mask = seg.visible_mask(self.ts)
            if not mask.any():
                continue
            s, i = ops.topk_scan(queries, seg.vectors(), k, metric=metric_str, valid=mask)
            pks = seg.pks()
            pools_s.append(s)
            pools_p.append(np.where(i >= 0, pks[np.clip(i, 0, len(pks) - 1)], -1))
        nq = len(queries)
        fill = np.inf if metric_str == "l2" else -np.inf
        out_s = np.full((nq, k), fill, np.float32)
        out_p = np.full((nq, k), -1, np.int64)
        if not pools_s:
            return out_s, out_p
        s = np.concatenate(pools_s, axis=1)
        p = np.concatenate(pools_p, axis=1)
        order = np.argsort(s if metric_str == "l2" else -s, axis=1, kind="stable")
        for r in range(nq):
            seen, slot = set(), 0
            for j in order[r]:
                pk = int(p[r, j])
                if pk < 0 or pk in seen or not np.isfinite(s[r, j]):
                    continue
                seen.add(pk)
                out_s[r, slot] = s[r, j]
                out_p[r, slot] = pk
                slot += 1
                if slot >= k:
                    break
        return out_s, out_p


def physical_time_of(ts: int) -> int:
    return physical_of(ts)
