"""Production serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --shape decode_32k --dry-run
    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --local --tokens 16

``--dry-run`` lowers + compiles prefill/decode steps for the production
mesh (sequence-sharded KV + flash-decode).  ``--local`` runs real batched
decode of a reduced config on local devices as a smoke demo.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    if args.dry_run:
        import os

        os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
        from .dryrun import run_cell

        res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod)
        r = res["roofline"]
        print(f"{args.arch} x {args.shape} [{res['mesh']}]: compiled OK; "
              f"mem/dev={res['memory_analysis']['peak_bytes_per_device']/2**30:.2f} GiB; "
              f"bound={r['bound']} (c={r['compute_s']*1e3:.2f}ms "
              f"m={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms)")
        return

    if args.local:
        import jax
        import jax.numpy as jnp
        import numpy as np

        from ..configs import get_arch
        from ..models import model as M

        cfg = get_arch(args.arch).reduced()
        params = M.init_params(cfg, jax.random.key(0))
        B = 4
        prompt = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab_size)
        prefix = None
        if cfg.frontend == "vlm_stub":
            prefix = jax.random.normal(
                jax.random.key(2), (B, cfg.num_prefix_embeddings, cfg.d_model))
        total = 8 + (cfg.num_prefix_embeddings if prefix is not None else 0)
        cache = M.init_cache(cfg, B, total + args.tokens)
        logits, cache = M.prefill(cfg, params, prompt, cache, prefix)
        step = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for _ in range(args.tokens - 1):
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        dt = time.time() - t0
        seq = np.concatenate([np.asarray(t) for t in out], axis=1)
        print(f"{args.arch}-reduced: decoded {args.tokens} tokens x{B} seqs "
              f"in {dt:.2f}s ({args.tokens*B/max(dt,1e-9):.1f} tok/s)")
        print("sample:", seq[0][:12])
        return

    ap.error("choose --dry-run or --local in this container (no TPU attached)")


if __name__ == "__main__":
    main()
