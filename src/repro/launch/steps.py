"""Step builders + input specs for every (arch × shape) cell.

``build_cell(cfg, shape, mesh)`` returns (step_fn, in_shardings,
input ShapeDtypeStructs, donate_argnums) ready for
``jax.jit(...).lower(...).compile()`` — the single entry the dry-run,
trainer and server all share.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import act_sharding, partition
from ..distributed.decode_attn import make_gqa_flash_decode, make_mla_flash_decode
from ..models import model as M
from ..models.config import ModelConfig, ShapeConfig
from ..train.optimizer import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
    opt_state_shape,
)

Params = Any


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs; no allocation)
# ---------------------------------------------------------------------------


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode: one new token against a seq_len KV cache
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    if cfg.frontend == "vlm_stub" and shape.kind != "decode":
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_embeddings, cfg.d_model), jnp.bfloat16
        )
    return out


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict[str, P]:
    bspec = partition.batch_spec(mesh, shape.global_batch)
    structs = batch_struct(cfg, shape)
    return {k: P(*bspec) if v.ndim >= 1 else P() for k, v in structs.items()}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """Public helper used by dryrun/tests: all model inputs for a cell."""
    return batch_struct(cfg, shape)


def _norm_batch_axes(bspec: P):
    axes = bspec[0] if len(bspec) else None
    if axes is None:
        return None
    return (axes,) if isinstance(axes, str) else tuple(axes)


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------


def build_train_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
    adamw: AdamWConfig | None = None, remat: bool = True, moe_impl: str = "shard_map",
    microbatches: int = 1,
):
    """``microbatches`` > 1 enables gradient accumulation: the global batch
    is split into N sequential microbatches whose f32 grads accumulate
    before one optimizer update.  Divides live activation memory by ~N (the
    remat residuals dominate large-model training peaks) at the cost of
    N x FSDP weight-gather traffic — the memory/collective trade measured
    in EXPERIMENTS.md §Perf."""
    adamw = adamw or AdamWConfig()
    p_shape = M.params_shape(cfg)
    o_shape = opt_state_shape(p_shape)
    p_specs = partition.param_specs(cfg, mesh, p_shape, fsdp=True)
    o_specs = {
        "step": P(),
        "m": p_specs,
        "v": p_specs,
    }
    b_specs = batch_specs(cfg, shape, mesh)
    b_struct = batch_struct(cfg, shape)

    b_axes = _norm_batch_axes(partition.batch_spec(mesh, shape.global_batch))

    def train_step(params, opt_state, batch):
        with act_sharding.policy(mesh, b_axes, moe_impl):
            def loss_fn(p, mb):
                return M.lm_loss(
                    cfg, p, mb["tokens"], mb["labels"],
                    mb.get("prefix_embeds"), remat=remat,
                )

            if microbatches <= 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                n = microbatches
                split = jax.tree_util.tree_map(
                    lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch
                )
                # accumulator carry must start param-sharded: a replicated
                # scan carry would force the whole loop body unsharded
                zero = jax.tree_util.tree_map(
                    lambda p, spec: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, jnp.float32), NamedSharding(mesh, spec)
                    ),
                    params, p_specs,
                )

                def mb_step(carry, mb):
                    acc, loss_acc = carry
                    mb = act_sharding.constrain_tree_batch(mb)
                    loss, g = jax.value_and_grad(loss_fn)(params, mb)
                    acc = jax.tree_util.tree_map(
                        lambda a, gi: a + gi.astype(jnp.float32) / n, acc, g
                    )
                    return (acc, loss_acc + loss / n), None

                from ..models import scan_util

                (grads, loss), _ = scan_util.scan(
                    mb_step, (zero, jnp.zeros((), jnp.float32)), split
                )
            grads, gnorm = clip_by_global_norm(grads, adamw.grad_clip)
            new_params, new_opt = adamw_update(adamw, params, grads, opt_state)
            return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    in_shardings = (
        partition.tree_shardings(mesh, p_specs),
        partition.tree_shardings(mesh, o_specs),
        partition.tree_shardings(mesh, b_specs),
    )
    in_structs = (p_shape, o_shape, b_struct)
    return train_step, in_shardings, in_structs, (0, 1)


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, remat: bool = True,
                       moe_impl: str = "shard_map"):
    p_shape = M.params_shape(cfg)
    p_specs = partition.param_specs(cfg, mesh, p_shape, fsdp=False)
    b_specs = batch_specs(cfg, shape, mesh)
    b_struct = batch_struct(cfg, shape)
    total_seq = shape.seq_len + (
        cfg.num_prefix_embeddings if cfg.frontend == "vlm_stub" else 0
    )
    c_shape = M.cache_shape(cfg, shape.global_batch, total_seq)
    c_specs = partition.cache_specs(cfg, mesh, c_shape, shape.global_batch)

    b_axes = _norm_batch_axes(partition.batch_spec(mesh, shape.global_batch))

    def prefill_step(params, batch, cache):
        with act_sharding.policy(mesh, b_axes, moe_impl):
            logits, cache = M.prefill(
                cfg, params, batch["tokens"], cache, batch.get("prefix_embeds"),
                remat=remat, last_only=True,
            )
            return logits, cache

    in_shardings = (
        partition.tree_shardings(mesh, p_specs),
        partition.tree_shardings(mesh, b_specs),
        partition.tree_shardings(mesh, c_specs),
    )
    return prefill_step, in_shardings, (p_shape, b_struct, c_shape), (2,)


def build_decode_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
    flash_decode: bool = True, moe_impl: str = "shard_map",
    cache_mode: str = "carry",
):
    p_shape = M.params_shape(cfg)
    p_specs = partition.param_specs(cfg, mesh, p_shape, fsdp=False)
    b_specs = batch_specs(cfg, shape, mesh)
    b_struct = batch_struct(cfg, shape)
    c_shape = M.cache_shape(cfg, shape.global_batch, shape.seq_len)
    c_specs = partition.cache_specs(cfg, mesh, c_shape, shape.global_batch)

    if flash_decode and "model" in mesh.shape and mesh.shape["model"] > 1:
        bspec = partition.batch_spec(mesh, shape.global_batch)
        gqa_impl = make_gqa_flash_decode(mesh, "model", bspec)
        mla_impl = make_mla_flash_decode(mesh, "model", bspec)
    else:
        gqa_impl = M.dense_gqa_decode_attn
        mla_impl = M.dense_mla_decode_attn

    b_axes = _norm_batch_axes(partition.batch_spec(mesh, shape.global_batch))

    def serve_step(params, cache, batch):
        with act_sharding.policy(mesh, b_axes, moe_impl):
            logits, cache = M.decode_step(
                cfg, params, cache, batch["tokens"],
                gqa_attn_impl=gqa_impl, mla_attn_impl=mla_impl,
                cache_mode=cache_mode,
            )
            return logits, cache

    in_shardings = (
        partition.tree_shardings(mesh, p_specs),
        partition.tree_shardings(mesh, c_specs),
        partition.tree_shardings(mesh, b_specs),
    )
    return serve_step, in_shardings, (p_shape, c_shape, b_struct), (1,)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, **kw):
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, **kw)
    return build_decode_cell(cfg, shape, mesh, **kw)
