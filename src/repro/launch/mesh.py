"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run forces 512 host devices while tests/benches must see 1.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod pass."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced host devices — used by multi-device tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1
