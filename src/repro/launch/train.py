"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --shape train_4k \
        --dry-run                         # lower+compile on the 16x16 mesh
    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --local --steps 50
        # real execution of a reduced config on local devices (CPU demo)

On a real TPU pod the same ``build_train_cell`` artifacts execute under the
production mesh; this launcher adds checkpoint/restart (object store) and a
synthetic data pipeline.  ``--multi-pod`` selects the 2x16x16 mesh.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--local", action="store_true",
                    help="run a reduced config for real on local devices")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpts")
    args = ap.parse_args()

    if args.dry_run:
        import os

        os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
        from .dryrun import run_cell

        res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod)
        r = res["roofline"]
        print(f"{args.arch} x {args.shape} [{res['mesh']}]: compiled OK; "
              f"mem/dev={res['memory_analysis']['peak_bytes_per_device']/2**30:.2f} GiB; "
              f"roofline compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
              f"collective={r['collective_s']*1e3:.1f}ms bound={r['bound']}")
        return

    if args.local:
        from ..configs import get_arch
        from ..core.object_store import FileObjectStore
        from ..train.loop import TrainConfig, train

        cfg = get_arch(args.arch).reduced()
        store = FileObjectStore(args.ckpt_dir)
        tc = TrainConfig(steps=args.steps, run_name=f"local-{args.arch}")
        t0 = time.time()
        _p, _o, losses = train(cfg, store, tc)
        print(f"{args.arch}-reduced: {len(losses)} steps in {time.time()-t0:.1f}s; "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        return

    ap.error("choose --dry-run or --local in this container (no TPU attached)")


if __name__ == "__main__":
    main()
