"""Render EXPERIMENTS.md tables from the dry-run result JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os


def load_all(d: str) -> list[dict]:
    out = []
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            with open(os.path.join(d, fn)) as f:
                out.append(json.load(f))
    return out


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def roofline_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | kind | mem/dev GiB | compute ms | memory ms | "
           "collective ms | bound | useful-FLOP ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    order = {"train": 0, "prefill": 1, "decode": 2}
    for r in sorted(rows, key=lambda r: (r["arch"], order.get(r.get("kind", ""), 3), r["shape"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | FAILED: {r.get('error','?')} | | | | | | |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {fmt_bytes(r['memory_analysis']['peak_bytes_per_device'])} "
            f"| {t['compute_s']*1e3:.1f} | {t['memory_s']*1e3:.1f} "
            f"| {t['collective_s']*1e3:.1f} | {t['bound']} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return hdr + "\n".join(lines)


def fit_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compile s | mem/dev GiB | collectives in rolled HLO |\n"
           "|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | |")
            continue
        coll = r.get("collectives_rolled_module", r.get("collectives", {}))
        ops = coll.get("collective-ops", "?")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('compile_s','?')} "
            f"| {fmt_bytes(r['memory_analysis']['peak_bytes_per_device'])} "
            f"| {ops} ops / {coll.get('total',0)/2**20:.0f} MiB |"
        )
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    args = ap.parse_args()
    single = load_all(os.path.join(args.dir, "16x16"))
    multi = load_all(os.path.join(args.dir, "2x16x16"))
    print("## Roofline (single pod, 16x16 = 256 chips, per-device terms)\n")
    print(roofline_table(single))
    print("\n## Multi-pod fit pass (2x16x16 = 512 chips)\n")
    print(fit_table(multi))
    ok_s = sum(1 for r in single if r.get("ok"))
    ok_m = sum(1 for r in multi if r.get("ok"))
    print(f"\nsingle-pod: {ok_s}/{len(single)} cells pass; "
          f"multi-pod: {ok_m}/{len(multi)} cells pass")


if __name__ == "__main__":
    main()
