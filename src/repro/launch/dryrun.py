import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell against the production meshes and extract the roofline inputs.

For each cell we record:
  * ``memory_analysis()``     — per-device bytes (proves it fits HBM),
  * ``cost_analysis()``       — HLO FLOPs + bytes accessed,
  * collective bytes          — parsed from the optimized HLO: operand sizes
    of all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute instructions,
  * roofline terms at TPU v5e constants (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI) and MODEL_FLOPS = 6·N·D (6·N_active·D for MoE).

Results stream into ``results/dryrun/<mesh>/<arch>--<shape>.json`` so the
sweep is resumable; EXPERIMENTS.md §Dry-run / §Roofline are generated from
these files.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, cells, get_arch, skipped_cells
from ..distributed.compat import cost_analysis_dict
from .mesh import make_production_mesh
from .steps import build_cell

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes of every collective instruction in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["collective-ops"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # instruction lines look like:  %name = TYPE[dims] op-name(...)
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        if "-done(" in rhs:
            continue  # avoid double counting start/done pairs
        # operand bytes: shapes inside the parens; result shape(s) precede op
        paren = rhs[opm.end() :]
        operand_bytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(paren)
        )
        if operand_bytes == 0:
            # fallback: use result shape (e.g. operands spelled as %refs only)
            pre = rhs[: opm.start()]
            operand_bytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(pre))
        out[op] += operand_bytes
        out["collective-ops"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(
    flops: float, bytes_accessed: float, collective_bytes: float
) -> dict[str, float]:
    """All inputs are PER-DEVICE (cost_analysis & the optimized HLO are the
    per-device SPMD module — calibrated empirically; see EXPERIMENTS.md)."""
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_accessed / HBM_BW
    t_collective = collective_bytes / ICI_BW_PER_LINK
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_collective}
    terms["bound"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1).replace("_s", "")
    return terms


def _compile_cell(cfg, shape, mesh, kw):
    step, in_shardings, in_structs, donate = build_cell(cfg, shape, mesh, **kw)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_shardings, donate_argnums=donate)
        lowered = jitted.lower(*in_structs)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled) -> dict[str, float]:
    cost = cost_analysis_dict(compiled)
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
        "coll_breakdown": coll,
    }


def extrapolated_cost(cfg, shape, mesh, kw) -> dict:
    """Depth-extrapolated per-device cost.

    XLA's cost_analysis counts while-loop bodies once (no trip-count
    multiplication), so scan-structured models undercount by ~num_layers.
    We compile shallow variants (1 and 2 effective periods) with EVERY scan
    unrolled and extrapolate:  C(L) = C1 + (periods-1) * (C2 - C1).
    """
    import dataclasses

    from ..models import scan_util
    from ..models.model import effective_pattern, num_periods

    period = len(effective_pattern(cfg))
    periods = num_periods(cfg)
    scan_util.set_unroll(True)
    try:
        cfg1 = dataclasses.replace(cfg, num_layers=period)
        c1 = _cost_of(_compile_cell(cfg1, shape, mesh, kw))
        if periods == 1:
            return {**c1, "method": "exact-unrolled"}
        cfg2 = dataclasses.replace(cfg, num_layers=2 * period)
        c2 = _cost_of(_compile_cell(cfg2, shape, mesh, kw))
    finally:
        scan_util.set_unroll(False)
    # clamp: for near-zero-cost cells the linear fit can dip below C1
    out = {
        k: max(c1[k] + (periods - 1) * (c2[k] - c1[k]), c1[k], 0.0)
        for k in ("flops", "bytes", "coll")
    }
    out["coll_breakdown"] = {
        k: c1["coll_breakdown"].get(k, 0) + (periods - 1) * (
            c2["coll_breakdown"].get(k, 0) - c1["coll_breakdown"].get(k, 0)
        )
        for k in c1["coll_breakdown"]
    }
    out["method"] = f"extrapolated(1,2)x{periods}"
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None = None,
             flash_decode: bool = True, remat: bool = True,
             cost_pass: bool = True, variant: str = "",
             **cell_kw) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    kw = dict(cell_kw)
    if shape.kind == "decode":
        kw["flash_decode"] = flash_decode
    else:
        kw["remat"] = remat
    t0 = time.time()
    step, in_shardings, in_structs, donate = build_cell(cfg, shape, mesh, **kw)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_shardings, donate_argnums=donate)
        lowered = jitted.lower(*in_structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)

    if cost_pass:
        cost = extrapolated_cost(cfg, shape, mesh, kw)
        coll_total = cost["coll"]
        cost_method = cost["method"]
        coll_breakdown = cost["coll_breakdown"]
    else:
        raw = cost_analysis_dict(compiled)
        cost = {
            "flops": float(raw.get("flops", 0.0)),
            "bytes": float(raw.get("bytes accessed", 0.0)),
        }
        coll_total = float(coll["total"])
        coll_breakdown = coll
        cost_method = "raw-rolled (loop bodies counted once)"

    flops = cost["flops"]  # per-device
    bytes_accessed = cost["bytes"]  # per-device
    model_flops_per_tok = 2.0 * cfg.active_params()  # fwd 2ND
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 3.0 * model_flops_per_tok * tokens  # fwd+bwd = 6ND
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = model_flops_per_tok * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = model_flops_per_tok * tokens
    model_flops_per_device = model_flops / n_chips

    terms = roofline_terms(flops, bytes_accessed, coll_total)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes_per_device": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        },
        "cost_analysis": {
            "flops_per_device": flops,
            "bytes_accessed_per_device": bytes_accessed,
            "method": cost_method,
        },
        "collectives": coll_breakdown,
        "collectives_rolled_module": coll,
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops_per_device,
        "useful_flops_ratio": model_flops_per_device / flops if flops else 0.0,
        "roofline": terms,
        "step_time_bound_s": max(terms["compute_s"], terms["memory_s"], terms["collective_s"]),
        # roofline fraction = (ideal model-FLOP time) / (roofline-bound step
        # time): how close the compiled program is to the hardware ceiling.
        "roofline_fraction": (
            (model_flops_per_device / PEAK_FLOPS_BF16)
            / max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
            if max(terms["compute_s"], terms["memory_s"], terms["collective_s"]) > 0
            else 0.0
        ),
    }
    if variant:
        result["variant"] = variant
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"--{variant}" if variant else ""
        path = os.path.join(out_dir, f"{arch}--{shape_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--no-flash-decode", action="store_true",
                    help="baseline: dense decode attention (paper-faithful direct port)")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-cost-pass", action="store_true",
                    help="skip the unrolled shallow cost extrapolation")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--resume", action="store_true", help="skip cells with existing results")
    args = ap.parse_args()

    if args.all:
        todo = cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    failures = []
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        out_dir = os.path.join(args.out, mesh_name)
        for arch, shape_name in todo:
            tag = f"[{mesh_name}] {arch} x {shape_name}"
            path = os.path.join(out_dir, f"{arch}--{shape_name}.json")
            if args.resume and os.path.exists(path):
                print(f"{tag}: cached, skipping", flush=True)
                continue
            try:
                res = run_cell(
                    arch, shape_name, multi_pod, out_dir,
                    flash_decode=not args.no_flash_decode,
                    remat=not args.no_remat,
                    # roofline table is single-pod; multi-pod pass proves fit
                    cost_pass=not multi_pod and not args.no_cost_pass,
                )
                r = res["roofline"]
                print(
                    f"{tag}: OK compile={res['compile_s']:.0f}s "
                    f"mem/dev={res['memory_analysis']['peak_bytes_per_device']/2**30:.2f}GiB "
                    f"compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
                    f"coll={r['collective_s']*1e3:.1f}ms bound={r['bound']} "
                    f"roofline_frac={res['roofline_fraction']:.2f}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 - report and continue the sweep
                failures.append((tag, repr(e)))
                print(f"{tag}: FAIL {e!r}", flush=True)
                traceback.print_exc()
                os.makedirs(out_dir, exist_ok=True)
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape_name, "mesh": mesh_name,
                               "ok": False, "error": repr(e)}, f, indent=1)

    for arch, shape_name, reason in skipped_cells():
        print(f"[skip] {arch} x {shape_name}: {reason}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        sys.exit(1)
    print("\nALL CELLS PASS")


if __name__ == "__main__":
    main()
