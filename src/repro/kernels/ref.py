"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: tests sweep shapes/dtypes and assert
``assert_allclose(kernel(...), ref(...))``.  They are also the fallback
execution path on platforms without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "l2_topk_ref",
    "ip_topk_ref",
    "pq_adc_topk_ref",
    "sq_encode_ref",
    "sq_decode_ref",
    "sq_l2_topk_ref",
    "kmeans_assign_ref",
    "merge_topk_ref",
]


def _mask_scores(scores: jnp.ndarray, valid: jnp.ndarray | None, fill: float) -> jnp.ndarray:
    if valid is None:
        return scores
    return jnp.where(valid[None, :], scores, fill)


def l2_topk_ref(
    queries: jnp.ndarray,
    base: jnp.ndarray,
    k: int,
    valid: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact squared-L2 top-k.  Returns (dists [nq,k], idx [nq,k]) ascending."""
    q = queries.astype(jnp.float32)
    x = base.astype(jnp.float32)
    d2 = (
        jnp.sum(q * q, axis=1, keepdims=True)
        - 2.0 * q @ x.T
        + jnp.sum(x * x, axis=1)[None, :]
    )
    d2 = _mask_scores(d2, valid, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def ip_topk_ref(
    queries: jnp.ndarray,
    base: jnp.ndarray,
    k: int,
    valid: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Max inner-product top-k.  Returns (scores [nq,k], idx) descending."""
    s = queries.astype(jnp.float32) @ base.astype(jnp.float32).T
    s = _mask_scores(s, valid, -jnp.inf)
    return jax.lax.top_k(s, k)


def pq_adc_topk_ref(
    luts: jnp.ndarray,  # [nq, m, ksub] f32 per-query ADC tables
    codes: jnp.ndarray,  # [n, m] integer codes
    k: int,
    valid: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """PQ asymmetric-distance top-k: dist[q,i] = sum_m lut[q,m,codes[i,m]]."""
    nq, m, ksub = luts.shape
    c = codes.astype(jnp.int32)
    # [nq, n, m] gather then sum over m
    gathered = jnp.take_along_axis(
        luts[:, None, :, :].repeat(c.shape[0], axis=1),
        c[None, :, :, None],
        axis=3,
    )[..., 0]
    d = gathered.sum(axis=2)  # [nq, n]
    d = _mask_scores(d, valid, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


def sq_encode_ref(x: jnp.ndarray, vmin: jnp.ndarray, vmax: jnp.ndarray) -> jnp.ndarray:
    """Scalar quantization to uint8 codes with per-dim affine range."""
    scale = jnp.maximum(vmax - vmin, 1e-12) / 255.0
    q = jnp.round((x - vmin[None, :]) / scale[None, :])
    return jnp.clip(q, 0, 255).astype(jnp.uint8)


def sq_decode_ref(codes: jnp.ndarray, vmin: jnp.ndarray, vmax: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.maximum(vmax - vmin, 1e-12) / 255.0
    return codes.astype(jnp.float32) * scale[None, :] + vmin[None, :]


def sq_l2_topk_ref(
    queries: jnp.ndarray,
    codes: jnp.ndarray,
    vmin: jnp.ndarray,
    vmax: jnp.ndarray,
    k: int,
    valid: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """L2 top-k computed against SQ-compressed base (dequant fused)."""
    return l2_topk_ref(queries, sq_decode_ref(codes, vmin, vmax), k, valid)


def merge_topk_ref(
    scores: jnp.ndarray,  # [nq, m] pooled candidate scores
    pks: jnp.ndarray,  # [nq, m] integer pks, -1 = empty slot
    k: int,
    metric: str = "l2",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Segmented k-way top-k merge with pk-dedup (two-phase reduce, §3.6).

    Pools of per-segment/per-node top-k candidates are merged into the
    final per-query top-k, keeping the best occurrence of each pk.
    Candidates with pk < 0 or a non-finite score are ignored.  Output is
    ascending distance for L2, descending similarity for IP; empty output
    slots carry pk == -1 and the metric's fill score (+inf L2 / -inf IP).

    Ties are broken by pool column order (stable), matching a stable
    per-row selection over the concatenated pools.
    """
    s = scores.astype(jnp.float32)
    p = pks
    nq, m = s.shape
    fill = jnp.inf if metric == "l2" else -jnp.inf
    if m == 0:
        return (
            jnp.full((nq, k), fill, jnp.float32),
            jnp.full((nq, k), -1, p.dtype),
        )
    alive = (p >= 0) & jnp.isfinite(s)
    key = jnp.where(alive, s if metric == "l2" else -s, jnp.inf)
    # Group rows by (pk, key, column) via two stable argsorts; the first
    # element of each pk group is its best occurrence.
    ord_key = jnp.argsort(key, axis=1, stable=True)
    ord_pk = jnp.argsort(jnp.take_along_axis(p, ord_key, 1), axis=1, stable=True)
    perm = jnp.take_along_axis(ord_key, ord_pk, 1)
    p_grouped = jnp.take_along_axis(p, perm, 1)
    dup = jnp.concatenate(
        [jnp.zeros((nq, 1), bool), p_grouped[:, 1:] == p_grouped[:, :-1]], axis=1
    )
    # Scatter the duplicate flags back to pool-column order.
    killed = jnp.take_along_axis(dup, jnp.argsort(perm, axis=1, stable=True), 1)
    key = jnp.where(killed, jnp.inf, key)
    order = jnp.argsort(key, axis=1, stable=True)[:, : min(k, m)]
    sel_alive = jnp.take_along_axis(alive & ~killed, order, 1)
    out_s = jnp.where(sel_alive, jnp.take_along_axis(s, order, 1), fill)
    out_p = jnp.where(sel_alive, jnp.take_along_axis(p, order, 1), -1)
    if m < k:
        out_s = jnp.concatenate(
            [out_s, jnp.full((nq, k - m), fill, jnp.float32)], axis=1
        )
        out_p = jnp.concatenate([out_p, jnp.full((nq, k - m), -1, p.dtype)], axis=1)
    return out_s.astype(jnp.float32), out_p


def kmeans_assign_ref(
    x: jnp.ndarray, centroids: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest centroid per row: returns (assignment [n] int32, sq-dist [n])."""
    xf = x.astype(jnp.float32)
    cf = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(xf * xf, axis=1, keepdims=True)
        - 2.0 * xf @ cf.T
        + jnp.sum(cf * cf, axis=1)[None, :]
    )
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
