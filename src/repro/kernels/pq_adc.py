"""PQ asymmetric-distance (ADC) scan + top-k Pallas TPU kernel.

Product quantization stores each vector as ``m`` sub-codes; query-time
distance is a table lookup: ``d(q, x) = sum_m LUT[m, code_m(x)]``.  The GPU
version keeps the LUT in shared memory and gathers; on TPU there is no fast
per-lane gather, so we replace the lookup with a **one-hot MXU contraction**
per subquantizer:

    onehot(codes[:, m]) [TN, KSUB]  @  LUT[:, m, :].T [KSUB, NQ]  ->  [TN, NQ]

which is exactly the hardware-adaptation pattern DESIGN.md §3 describes:
LUT pinned in VMEM, codes streamed in int tiles, gathers turned into
systolic matmuls.  Running top-k identical to ``l2_topk``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .topk_util import BIG_F32, NEG_I32, merge_topk, tile_base_indices

DEFAULT_TN = 512


def _adc_kernel(
    lut_ref,  # [NQ, M, KSUB] f32 — whole query batch resident in VMEM
    codes_ref,  # [TN, M] int32 tile
    valid_ref,  # [1, TN] int32
    out_v_ref,  # [NQ, K]
    out_i_ref,  # [NQ, K]
    acc_v,
    acc_i,
    *,
    k: int,
    n_base_tiles: int,
):
    jt = pl.program_id(0)

    @pl.when(jt == 0)
    def _init():
        acc_v[...] = jnp.full_like(acc_v[...], BIG_F32)
        acc_i[...] = jnp.full_like(acc_i[...], NEG_I32)

    lut = lut_ref[...]  # [NQ, M, KSUB]
    codes = codes_ref[...].astype(jnp.int32)  # [TN, M]
    nq, m, ksub = lut.shape
    tn = codes.shape[0]

    def per_sub(mi, acc):
        code_col = jax.lax.dynamic_slice(codes, (0, mi), (tn, 1))[:, 0]  # [TN]
        iota = jax.lax.broadcasted_iota(jnp.int32, (tn, ksub), 1)
        onehot = (iota == code_col[:, None]).astype(jnp.float32)  # [TN, KSUB]
        lut_m = jax.lax.dynamic_slice(lut, (0, mi, 0), (nq, 1, ksub))[:, 0, :]
        part = jax.lax.dot_general(
            onehot, lut_m, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [TN, NQ]
        return acc + part

    dist_tn_nq = jax.lax.fori_loop(
        0, m, per_sub, jnp.zeros((tn, nq), jnp.float32)
    )
    scores = dist_tn_nq.T  # [NQ, TN]
    live = valid_ref[0, :][None, :] > 0
    scores = jnp.where(live, scores, BIG_F32)

    idx = tile_base_indices(tn, jt, nq)
    new_v, new_i = merge_topk(acc_v[...], acc_i[...], scores, idx, k)
    acc_v[...] = new_v
    acc_i[...] = new_i

    @pl.when(jt == n_base_tiles - 1)
    def _emit():
        out_v_ref[...] = acc_v[...]
        out_i_ref[...] = acc_i[...]


@functools.partial(jax.jit, static_argnames=("k", "tn", "interpret"))
def pq_adc_topk_pallas(
    luts: jnp.ndarray,  # [NQ, M, KSUB] f32
    codes: jnp.ndarray,  # [N, M] int32, N padded to TN multiple
    valid: jnp.ndarray,  # [N] int32
    k: int,
    tn: int = DEFAULT_TN,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    nq, m, ksub = luts.shape
    n = codes.shape[0]
    assert n % tn == 0
    n_b_tiles = n // tn

    kernel = functools.partial(_adc_kernel, k=k, n_base_tiles=n_b_tiles)
    out_v, out_i = pl.pallas_call(
        kernel,
        grid=(n_b_tiles,),
        in_specs=[
            pl.BlockSpec((nq, m, ksub), lambda j: (0, 0, 0)),
            pl.BlockSpec((tn, m), lambda j: (j, 0)),
            pl.BlockSpec((1, tn), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((nq, k), lambda j: (0, 0)),
            pl.BlockSpec((nq, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nq, k), jnp.float32),
            pltpu.VMEM((nq, k), jnp.int32),
        ],
        interpret=interpret,
    )(luts.astype(jnp.float32), codes.astype(jnp.int32), valid[None, :].astype(jnp.int32))
    return out_v, out_i
