"""Fused distance-scan + top-k Pallas TPU kernel.

This is Manu's hottest loop: brute-force scan of growing segments and the
inner loop of IVF-FLAT / bucket scans.  The GPU/SIMD implementation in the
paper becomes an MXU matmul here:

    L2:  d(q,x) = |q|^2 - 2 q.x + |x|^2        (ascending top-k)
    IP:  s(q,x) = q.x                          (descending; negated inside)

Tiling: the query block [TQ, D] stays resident in VMEM while base tiles
[TN, D] stream through HBM->VMEM via the grid; a running per-query top-k
buffer lives in VMEM scratch and is merged once per tile (see
``topk_util``).  Grid = (query_tiles, base_tiles), base axis innermost so
the scratch accumulates sequentially — the canonical Pallas reduction
pattern.

Alignment: TQ, TN, D should be multiples of the 128-lane VREG / MXU tile;
``ops.py`` pads inputs accordingly and strips padding from outputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .topk_util import BIG_F32, NEG_I32, merge_topk, tile_base_indices

DEFAULT_TQ = 128
DEFAULT_TN = 512


def _scan_kernel(
    q_ref,  # [TQ, D] queries (VMEM-resident across base tiles)
    x_ref,  # [TN, D] base tile
    valid_ref,  # [1, TN] int32 validity mask tile (1 = live row)
    out_v_ref,  # [TQ, K]
    out_i_ref,  # [TQ, K]
    acc_v,  # scratch [TQ, K] f32
    acc_i,  # scratch [TQ, K] i32
    *,
    k: int,
    metric: str,
    n_base_tiles: int,
):
    jt = pl.program_id(1)  # base-tile index (innermost)

    @pl.when(jt == 0)
    def _init():
        acc_v[...] = jnp.full_like(acc_v[...], BIG_F32)
        acc_i[...] = jnp.full_like(acc_i[...], NEG_I32)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    # MXU contraction with f32 accumulation.
    qx = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [TQ, TN]
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)[None, :]
        scores = qn - 2.0 * qx + xn
    elif metric == "ip":
        scores = -qx  # minimize negated similarity
    else:
        raise ValueError(f"unknown metric {metric}")

    live = valid_ref[0, :][None, :] > 0  # [1, TN]
    scores = jnp.where(live, scores, BIG_F32)

    idx = tile_base_indices(x.shape[0], jt, q.shape[0])
    new_v, new_i = merge_topk(acc_v[...], acc_i[...], scores, idx, k)
    acc_v[...] = new_v
    acc_i[...] = new_i

    @pl.when(jt == n_base_tiles - 1)
    def _emit():
        out = acc_v[...]
        if metric == "ip":
            out = -out  # back to similarity scale
        out_v_ref[...] = out
        out_i_ref[...] = acc_i[...]


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tq", "tn", "interpret")
)
def l2_topk_pallas(
    queries: jnp.ndarray,  # [NQ, D] padded to TQ multiple
    base: jnp.ndarray,  # [N, D] padded to TN multiple
    valid: jnp.ndarray,  # [N] int32
    k: int,
    metric: str = "l2",
    tq: int = DEFAULT_TQ,
    tn: int = DEFAULT_TN,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    nq, d = queries.shape
    n, _ = base.shape
    assert nq % tq == 0 and n % tn == 0, (nq, n, tq, tn)
    n_q_tiles, n_b_tiles = nq // tq, n // tn

    grid = (n_q_tiles, n_b_tiles)
    kernel = functools.partial(
        _scan_kernel, k=k, metric=metric, n_base_tiles=n_b_tiles
    )
    out_v, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, base, valid[None, :].astype(jnp.int32))
    return out_v, out_i
