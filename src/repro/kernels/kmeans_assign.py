"""Nearest-centroid assignment Pallas kernel (k-means E-step).

Index building (IVF coarse quantizer, PQ codebooks, the bucket index's
hierarchical k-means) is dominated by assignment: for every row find the
closest centroid.  Structure: centroids tiled over VMEM [TC, D], rows tiled
[TN, D]; running (min-dist, argmin) per row accumulates across centroid
tiles in VMEM scratch — the K=1 special case of the scan kernels, kept
separate because the reduction is a plain min (no selection loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .topk_util import BIG_F32

DEFAULT_TN = 512
DEFAULT_TC = 512


def _assign_kernel(
    x_ref,  # [TN, D]
    c_ref,  # [TC, D]
    out_a_ref,  # [TN, 1] int32
    out_d_ref,  # [TN, 1] f32
    best_d,  # scratch [TN, 1]
    best_a,  # scratch [TN, 1]
    *,
    n_c_tiles: int,
    tc: int,
):
    jc = pl.program_id(1)

    @pl.when(jc == 0)
    def _init():
        best_d[...] = jnp.full_like(best_d[...], BIG_F32)
        best_a[...] = jnp.zeros_like(best_a[...])

    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [TN, TC]
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)[None, :]
    d2 = xn - 2.0 * xc + cn

    tile_min = jnp.min(d2, axis=1, keepdims=True)  # [TN,1]
    tile_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None] + jc * tc

    better = tile_min < best_d[...]
    best_d[...] = jnp.where(better, tile_min, best_d[...])
    best_a[...] = jnp.where(better, tile_arg, best_a[...])

    @pl.when(jc == n_c_tiles - 1)
    def _emit():
        out_a_ref[...] = best_a[...]
        out_d_ref[...] = best_d[...]


@functools.partial(jax.jit, static_argnames=("tn", "tc", "interpret"))
def kmeans_assign_pallas(
    x: jnp.ndarray,  # [N, D] padded to TN
    centroids: jnp.ndarray,  # [C, D] padded to TC (pad rows = +inf-ish far away)
    tn: int = DEFAULT_TN,
    tc: int = DEFAULT_TC,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    n, d = x.shape
    c, _ = centroids.shape
    assert n % tn == 0 and c % tc == 0
    kernel = functools.partial(_assign_kernel, n_c_tiles=c // tc, tc=tc)
    out_a, out_d = pl.pallas_call(
        kernel,
        grid=(n // tn, c // tc),
        in_specs=[
            pl.BlockSpec((tn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tn, 1), jnp.float32),
            pltpu.VMEM((tn, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), centroids.astype(jnp.float32))
    return out_a[:, 0], out_d[:, 0]
