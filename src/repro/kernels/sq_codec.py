"""Scalar-quantization codec + fused SQ-domain distance scan (Pallas).

The paper's SSD design compresses vectors with scalar quantization so each
4 KB page holds more rows; our TPU adaptation keeps segments SQ-compressed
in HBM and dequantizes **inside the kernel**, right before the MXU
contraction — the bytes streamed from HBM are 4x smaller than f32, moving
the memory-roofline term down by the same factor.

Kernels:
  * ``sq_encode_pallas``  — f32 [N,D] -> int32 codes in [0,255]
  * ``sq_decode_pallas``  — codes -> f32
  * ``sq_l2_topk_pallas`` — fused dequant + L2/IP scan + running top-k
    (structure identical to ``l2_topk``; base tiles are int codes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .topk_util import BIG_F32, NEG_I32, merge_topk, tile_base_indices

DEFAULT_TN = 512
DEFAULT_TQ = 128


def _encode_kernel(x_ref, vmin_ref, vmax_ref, out_ref):
    x = x_ref[...]
    vmin = vmin_ref[0, :][None, :]
    vmax = vmax_ref[0, :][None, :]
    scale = jnp.maximum(vmax - vmin, 1e-12) / 255.0
    q = jnp.round((x - vmin) / scale)
    out_ref[...] = jnp.clip(q, 0.0, 255.0).astype(jnp.int32)


def _decode_kernel(c_ref, vmin_ref, vmax_ref, out_ref):
    c = c_ref[...].astype(jnp.float32)
    vmin = vmin_ref[0, :][None, :]
    vmax = vmax_ref[0, :][None, :]
    scale = jnp.maximum(vmax - vmin, 1e-12) / 255.0
    out_ref[...] = c * scale + vmin


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def sq_encode_pallas(
    x: jnp.ndarray, vmin: jnp.ndarray, vmax: jnp.ndarray,
    tn: int = DEFAULT_TN, interpret: bool = True,
) -> jnp.ndarray:
    n, d = x.shape
    assert n % tn == 0
    return pl.pallas_call(
        _encode_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn, d), lambda j: (j, 0)),
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((1, d), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, d), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.float32), vmin[None, :], vmax[None, :])


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def sq_decode_pallas(
    codes: jnp.ndarray, vmin: jnp.ndarray, vmax: jnp.ndarray,
    tn: int = DEFAULT_TN, interpret: bool = True,
) -> jnp.ndarray:
    n, d = codes.shape
    assert n % tn == 0
    return pl.pallas_call(
        _decode_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn, d), lambda j: (j, 0)),
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((1, d), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, d), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(codes.astype(jnp.int32), vmin[None, :], vmax[None, :])


def _sq_scan_kernel(
    q_ref,  # [TQ, D] f32 queries
    c_ref,  # [TN, D] int32 codes tile
    vmin_ref,  # [1, D]
    vmax_ref,  # [1, D]
    valid_ref,  # [1, TN]
    out_v_ref,
    out_i_ref,
    acc_v,
    acc_i,
    *,
    k: int,
    metric: str,
    n_base_tiles: int,
):
    jt = pl.program_id(1)

    @pl.when(jt == 0)
    def _init():
        acc_v[...] = jnp.full_like(acc_v[...], BIG_F32)
        acc_i[...] = jnp.full_like(acc_i[...], NEG_I32)

    q = q_ref[...].astype(jnp.float32)
    vmin = vmin_ref[0, :][None, :]
    vmax = vmax_ref[0, :][None, :]
    scale = jnp.maximum(vmax - vmin, 1e-12) / 255.0
    x = c_ref[...].astype(jnp.float32) * scale + vmin  # fused dequant in VMEM

    qx = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)[None, :]
        scores = qn - 2.0 * qx + xn
    else:
        scores = -qx

    live = valid_ref[0, :][None, :] > 0
    scores = jnp.where(live, scores, BIG_F32)
    idx = tile_base_indices(x.shape[0], jt, q.shape[0])
    new_v, new_i = merge_topk(acc_v[...], acc_i[...], scores, idx, k)
    acc_v[...] = new_v
    acc_i[...] = new_i

    @pl.when(jt == n_base_tiles - 1)
    def _emit():
        out = acc_v[...]
        if metric == "ip":
            out = -out
        out_v_ref[...] = out
        out_i_ref[...] = acc_i[...]


@functools.partial(jax.jit, static_argnames=("k", "metric", "tq", "tn", "interpret"))
def sq_l2_topk_pallas(
    queries: jnp.ndarray,  # [NQ, D]
    codes: jnp.ndarray,  # [N, D] int32
    vmin: jnp.ndarray,
    vmax: jnp.ndarray,
    valid: jnp.ndarray,
    k: int,
    metric: str = "l2",
    tq: int = DEFAULT_TQ,
    tn: int = DEFAULT_TN,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    nq, d = queries.shape
    n = codes.shape[0]
    assert nq % tq == 0 and n % tn == 0
    kernel = functools.partial(
        _sq_scan_kernel, k=k, metric=metric, n_base_tiles=n // tn
    )
    return pl.pallas_call(
        kernel,
        grid=(nq // tq, n // tn),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )(
        queries.astype(jnp.float32),
        codes.astype(jnp.int32),
        vmin[None, :].astype(jnp.float32),
        vmax[None, :].astype(jnp.float32),
        valid[None, :].astype(jnp.int32),
    )
