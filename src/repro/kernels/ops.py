"""Public jit'd entry points for the kernel layer.

Dispatch policy: on TPU backends the Pallas kernels run compiled; elsewhere
(this CPU container) the pure-jnp oracles in ``ref.py`` execute by default
for speed, while the Pallas bodies are validated under ``interpret=True`` in
the test suite.  Set ``REPRO_FORCE_PALLAS=1`` to force interpret-mode Pallas
everywhere (slow, but exercises the real kernels end to end).

All wrappers here accept un-padded shapes and handle the 128-alignment the
kernels require (pad rows, mask padding as invalid, strip outputs).
"""

from __future__ import annotations

import os
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kmeans_assign import kmeans_assign_pallas
from .l2_topk import l2_topk_pallas
from .pq_adc import pq_adc_topk_pallas
from .sq_codec import sq_decode_pallas, sq_encode_pallas, sq_l2_topk_pallas

__all__ = [
    "topk_scan",
    "pq_adc_topk",
    "sq_encode",
    "sq_decode",
    "sq_topk_scan",
    "kmeans_assign",
    "use_pallas",
]


@lru_cache(maxsize=1)
def use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    if os.environ.get("REPRO_FORCE_REF") == "1":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Host fast paths.  On CPU the jnp oracles pay dispatch/compile overhead per
# ragged shape (IVF lists are ragged); BLAS + argpartition is the idiomatic
# host implementation and is bit-compatible with the oracle semantics.
# ---------------------------------------------------------------------------


def _np_topk_min(scores: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    n = scores.shape[1]
    k = min(k, n)
    if k >= n:
        idx = np.argsort(scores, axis=1, kind="stable")[:, :k]
    else:
        part = np.argpartition(scores, k - 1, axis=1)[:, :k]
        sub = np.take_along_axis(scores, part, 1)
        order = np.argsort(sub, axis=1, kind="stable")
        idx = np.take_along_axis(part, order, 1)
    return np.take_along_axis(scores, idx, 1), idx


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(arr: jnp.ndarray, multiple: int, fill=0) -> jnp.ndarray:
    n = arr.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, widths, constant_values=fill)


def _choose_tiles(nq: int, n: int) -> tuple[int, int]:
    tq = 128 if nq >= 128 else max(8, 1 << (nq - 1).bit_length())
    tn = 512 if n >= 512 else max(128, 1 << (n - 1).bit_length())
    return tq, tn


def topk_scan(
    queries,
    base,
    k: int,
    metric: str = "l2",
    valid=None,
) -> tuple[np.ndarray, np.ndarray]:
    """Brute-force top-k scan (the growing-segment / FLAT search path).

    Returns (scores [nq,k], idx [nq,k]); ascending distance for L2,
    descending similarity for IP.  ``valid`` masks rows (MVCC visibility /
    delete bitmap).  Invalid or out-of-range results carry idx == -1.
    """
    n = base.shape[0]
    if n == 0:
        nq = len(queries)
        fill = np.inf if metric == "l2" else -np.inf
        return (
            np.full((nq, k), fill, np.float32),
            np.full((nq, k), -1, np.int64),
        )
    k_eff = min(k, n)

    if use_pallas():
        queries = jnp.asarray(queries, jnp.float32)
        base = jnp.asarray(base, jnp.float32)
        v = jnp.ones(n, jnp.int32) if valid is None else jnp.asarray(valid).astype(jnp.int32)
        tq, tn = _choose_tiles(queries.shape[0], n)
        qp = _pad_rows(queries, tq)
        bp = _pad_rows(base, tn)
        vp = _pad_rows(v, tn, fill=0)
        vals, idx = l2_topk_pallas(
            qp, bp, vp, k_eff, metric=metric, tq=tq, tn=tn, interpret=_interpret()
        )
        vals, idx = vals[: queries.shape[0]], idx[: queries.shape[0]]
        vals, idx = np.asarray(vals), np.asarray(idx, np.int64)
    else:
        qn = np.asarray(queries, np.float32)
        bn = np.asarray(base, np.float32)
        if metric == "l2":
            scores = (
                np.sum(qn * qn, axis=1, keepdims=True)
                - 2.0 * qn @ bn.T
                + np.sum(bn * bn, axis=1)[None, :]
            )
        else:
            scores = -(qn @ bn.T)
        if valid is not None:
            scores = np.where(np.asarray(valid, bool)[None, :], scores, np.float32(np.inf))
        vals, idx = _np_topk_min(scores, k_eff)
        if metric == "ip":
            vals = -vals
        idx = idx.astype(np.int64)

    vals = np.asarray(vals, np.float32)
    bad = np.abs(vals) >= 1e38
    idx = np.where(bad, -1, idx)
    if k_eff < k:  # pad out to requested k
        fill = np.inf if metric == "l2" else -np.inf
        vals = np.concatenate(
            [vals, np.full((vals.shape[0], k - k_eff), fill, np.float32)], axis=1
        )
        idx = np.concatenate(
            [idx, np.full((idx.shape[0], k - k_eff), -1, np.int64)], axis=1
        )
    return vals, idx


def pq_adc_topk(luts, codes, k: int, valid=None) -> tuple[np.ndarray, np.ndarray]:
    """ADC top-k over PQ codes.  luts: [nq, m, ksub]; codes: [n, m]."""
    n = codes.shape[0]
    nq = luts.shape[0]
    if n == 0:
        return np.full((nq, k), np.inf, np.float32), np.full((nq, k), -1, np.int64)
    k_eff = min(k, n)
    if use_pallas():
        luts = jnp.asarray(luts, jnp.float32)
        codes = jnp.asarray(codes, jnp.int32)
        v = jnp.ones(n, jnp.int32) if valid is None else jnp.asarray(valid).astype(jnp.int32)
        tn = 512 if n >= 512 else max(128, 1 << (n - 1).bit_length())
        cp = _pad_rows(codes, tn)
        vp = _pad_rows(v, tn, fill=0)
        vals, idx = pq_adc_topk_pallas(luts, cp, vp, k_eff, tn=tn, interpret=_interpret())
        vals, idx = np.asarray(vals), np.asarray(idx, np.int64)
    else:
        ln = np.asarray(luts, np.float32)
        cn = np.asarray(codes, np.int64)
        m = cn.shape[1]
        scores = np.zeros((nq, n), np.float32)
        for j in range(m):
            scores += ln[:, j, cn[:, j]]
        if valid is not None:
            scores = np.where(np.asarray(valid, bool)[None, :], scores, np.float32(np.inf))
        vals, idx = _np_topk_min(scores, k_eff)
        idx = idx.astype(np.int64)
    idx = np.where(np.abs(vals) >= 1e38, -1, idx)
    if k_eff < k:
        vals = np.concatenate(
            [vals, np.full((nq, k - k_eff), np.inf, np.float32)], axis=1
        )
        idx = np.concatenate([idx, np.full((nq, k - k_eff), -1, np.int64)], axis=1)
    return vals, idx


def sq_encode(x, vmin, vmax) -> np.ndarray:
    if use_pallas():
        x = jnp.asarray(x, jnp.float32)
        n = x.shape[0]
        tn = 512 if n >= 512 else max(128, 1 << max(0, (n - 1)).bit_length())
        xp = _pad_rows(x, tn)
        out = sq_encode_pallas(xp, jnp.asarray(vmin), jnp.asarray(vmax), tn=tn, interpret=_interpret())
        return np.asarray(out[:n], np.uint8)
    xn = np.asarray(x, np.float32)
    vmin = np.asarray(vmin, np.float32)
    vmax = np.asarray(vmax, np.float32)
    scale = np.maximum(vmax - vmin, 1e-12) / 255.0
    q = np.round((xn - vmin[None, :]) / scale[None, :])
    return np.clip(q, 0, 255).astype(np.uint8)


def sq_decode(codes, vmin, vmax) -> np.ndarray:
    if use_pallas():
        codes = jnp.asarray(codes)
        n = codes.shape[0]
        tn = 512 if n >= 512 else max(128, 1 << max(0, (n - 1)).bit_length())
        cp = _pad_rows(codes.astype(jnp.int32), tn)
        out = sq_decode_pallas(cp, jnp.asarray(vmin), jnp.asarray(vmax), tn=tn, interpret=_interpret())
        return np.asarray(out[:n])
    vmin = np.asarray(vmin, np.float32)
    vmax = np.asarray(vmax, np.float32)
    scale = np.maximum(vmax - vmin, 1e-12) / 255.0
    return np.asarray(codes, np.float32) * scale[None, :] + vmin[None, :]


def sq_topk_scan(
    queries, codes, vmin, vmax, k: int, metric: str = "l2", valid=None
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k against SQ-compressed base with fused dequantization."""
    n = codes.shape[0]
    nq = len(queries)
    if n == 0:
        fill = np.inf if metric == "l2" else -np.inf
        return np.full((nq, k), fill, np.float32), np.full((nq, k), -1, np.int64)
    k_eff = min(k, n)
    if use_pallas():
        queries = jnp.asarray(queries, jnp.float32)
        codes = jnp.asarray(codes)
        v = jnp.ones(n, jnp.int32) if valid is None else jnp.asarray(valid).astype(jnp.int32)
        tq, tn = _choose_tiles(nq, n)
        qp = _pad_rows(queries, tq)
        cp = _pad_rows(codes.astype(jnp.int32), tn)
        vp = _pad_rows(v, tn, fill=0)
        vals, idx = sq_l2_topk_pallas(
            qp, cp, jnp.asarray(vmin), jnp.asarray(vmax), vp, k_eff,
            metric=metric, tq=tq, tn=tn, interpret=_interpret(),
        )
        vals, idx = np.asarray(vals[:nq]), np.asarray(idx[:nq], np.int64)
    else:
        decoded = sq_decode(np.asarray(codes), vmin, vmax)
        return topk_scan(np.asarray(queries), decoded, k, metric=metric, valid=valid)
    vals, idx = np.asarray(vals), np.asarray(idx, np.int64)
    idx = np.where(np.abs(vals) >= 1e38, -1, idx)
    if k_eff < k:
        fill = np.inf if metric == "l2" else -np.inf
        vals = np.concatenate([vals, np.full((nq, k - k_eff), fill, np.float32)], axis=1)
        idx = np.concatenate([idx, np.full((nq, k - k_eff), -1, np.int64)], axis=1)
    return vals, idx


def kmeans_assign(x, centroids) -> tuple[np.ndarray, np.ndarray]:
    """Nearest-centroid assignment: returns (assign [n] int, sqdist [n])."""
    n, ncent = x.shape[0], centroids.shape[0]
    if use_pallas():
        x = jnp.asarray(x, jnp.float32)
        c = jnp.asarray(centroids, jnp.float32)
        tn = 512 if n >= 512 else max(128, 1 << (max(n, 2) - 1).bit_length())
        tc = 512 if ncent >= 512 else max(128, 1 << (max(ncent, 2) - 1).bit_length())
        xp = _pad_rows(x, tn)
        # pad centroids with far-away sentinels so they never win
        pad = (-ncent) % tc
        if pad:
            c = jnp.concatenate([c, jnp.full((pad, c.shape[1]), 1e18, jnp.float32)])
        a, d = kmeans_assign_pallas(xp, c, tn=tn, tc=tc, interpret=_interpret())
        return np.asarray(a[:n], np.int64), np.asarray(d[:n])
    xn = np.asarray(x, np.float32)
    cn = np.asarray(centroids, np.float32)
    d2 = (
        np.sum(xn * xn, axis=1, keepdims=True)
        - 2.0 * xn @ cn.T
        + np.sum(cn * cn, axis=1)[None, :]
    )
    return np.argmin(d2, axis=1).astype(np.int64), np.min(d2, axis=1)
