"""Public jit'd entry points for the kernel layer.

Dispatch policy: on TPU backends the Pallas kernels run compiled; elsewhere
(this CPU container) the pure-jnp oracles in ``ref.py`` execute by default
for speed, while the Pallas bodies are validated under ``interpret=True`` in
the test suite.  Set ``REPRO_FORCE_PALLAS=1`` to force interpret-mode Pallas
everywhere (slow, but exercises the real kernels end to end).

All wrappers here accept un-padded shapes and handle the 128-alignment the
kernels require (pad rows, mask padding as invalid, strip outputs).
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kmeans_assign import kmeans_assign_pallas
from .l2_topk import l2_topk_pallas
from .merge_topk import merge_topk_pallas
from .pq_adc import pq_adc_topk_pallas
from .sq_codec import sq_decode_pallas, sq_encode_pallas, sq_l2_topk_pallas

__all__ = [
    "topk_scan",
    "topk_scan_segmented",
    "merge_topk",
    "isin_sorted",
    "eff_tombstones",
    "tombstone_mask",
    "shard_split",
    "normalized_similarity",
    "hybrid_fuse",
    "range_cut",
    "mask_intersect",
    "post_filter_cut",
    "pq_adc_topk",
    "sq_scale",
    "sq_encode",
    "sq_decode",
    "sq_topk_scan",
    "kmeans_assign",
    "use_pallas",
    "ivf_probe_schedule",
    "ivf_gather_topk",
    "IVFBucket",
    "IVFSchedule",
]


@lru_cache(maxsize=1)
def use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    if os.environ.get("REPRO_FORCE_REF") == "1":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Host fast paths.  On CPU the jnp oracles pay dispatch/compile overhead per
# ragged shape (IVF lists are ragged); BLAS + argpartition is the idiomatic
# host implementation and is bit-compatible with the oracle semantics.
# ---------------------------------------------------------------------------


def _np_topk_min(scores: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """K smallest along the LAST axis (any leading batch dims)."""
    n = scores.shape[-1]
    k = min(k, n)
    if k >= n:
        idx = np.argsort(scores, axis=-1, kind="stable")[..., :k]
    else:
        part = np.argpartition(scores, k - 1, axis=-1)[..., :k]
        sub = np.take_along_axis(scores, part, -1)
        order = np.argsort(sub, axis=-1, kind="stable")
        idx = np.take_along_axis(part, order, -1)
    return np.take_along_axis(scores, idx, -1), idx


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(arr: jnp.ndarray, multiple: int, fill=0) -> jnp.ndarray:
    n = arr.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, widths, constant_values=fill)


def _choose_tiles(nq: int, n: int) -> tuple[int, int]:
    tq = 128 if nq >= 128 else max(8, 1 << (nq - 1).bit_length())
    tn = 512 if n >= 512 else max(128, 1 << (n - 1).bit_length())
    return tq, tn


def topk_scan(
    queries,
    base,
    k: int,
    metric: str = "l2",
    valid=None,
) -> tuple[np.ndarray, np.ndarray]:
    """Brute-force top-k scan (the growing-segment / FLAT search path).

    Returns (scores [nq,k], idx [nq,k]); ascending distance for L2,
    descending similarity for IP.  ``valid`` masks rows (MVCC visibility /
    delete bitmap).  Invalid or out-of-range results carry idx == -1.
    """
    n = base.shape[0]
    if n == 0:
        nq = len(queries)
        fill = np.inf if metric == "l2" else -np.inf
        return (
            np.full((nq, k), fill, np.float32),
            np.full((nq, k), -1, np.int64),
        )
    k_eff = min(k, n)

    if use_pallas():
        queries = jnp.asarray(queries, jnp.float32)
        base = jnp.asarray(base, jnp.float32)
        v = jnp.ones(n, jnp.int32) if valid is None else jnp.asarray(valid).astype(jnp.int32)
        tq, tn = _choose_tiles(queries.shape[0], n)
        qp = _pad_rows(queries, tq)
        bp = _pad_rows(base, tn)
        vp = _pad_rows(v, tn, fill=0)
        vals, idx = l2_topk_pallas(
            qp, bp, vp, k_eff, metric=metric, tq=tq, tn=tn, interpret=_interpret()
        )
        vals, idx = vals[: queries.shape[0]], idx[: queries.shape[0]]
        vals, idx = np.asarray(vals), np.asarray(idx, np.int64)
    else:
        qn = np.asarray(queries, np.float32)
        bn = np.asarray(base, np.float32)
        if metric == "l2":
            scores = (
                np.sum(qn * qn, axis=1, keepdims=True)
                - 2.0 * qn @ bn.T
                + np.sum(bn * bn, axis=1)[None, :]
            )
        else:
            scores = -(qn @ bn.T)
        if valid is not None:
            scores = np.where(np.asarray(valid, bool)[None, :], scores, np.float32(np.inf))
        vals, idx = _np_topk_min(scores, k_eff)
        if metric == "ip":
            vals = -vals
        idx = idx.astype(np.int64)

    vals = np.asarray(vals, np.float32)
    bad = np.abs(vals) >= 1e38
    idx = np.where(bad, -1, idx)
    if k_eff < k:  # pad out to requested k
        fill = np.inf if metric == "l2" else -np.inf
        vals = np.concatenate(
            [vals, np.full((vals.shape[0], k - k_eff), fill, np.float32)], axis=1
        )
        idx = np.concatenate(
            [idx, np.full((idx.shape[0], k - k_eff), -1, np.int64)], axis=1
        )
    return vals, idx


def topk_scan_segmented(
    queries,
    bases: "list",
    k: int,
    metric: str = "l2",
    valids: "list | None" = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Fused brute-force scan over one execution class of segments.

    Computes a single distance evaluation over the row-concatenation of
    ``bases`` and extracts the per-segment top-k from column slices of
    the shared score matrix — replacing S separate ``topk_scan``
    dispatches (S small gemms + S top-k passes) with one large gemm.
    This is the batched-scan half of the fused search engine; the merge
    half is :func:`merge_topk`.

    Returns (scores [nq, S*k], idx [nq, S*k]) where block
    ``idx[:, s*k:(s+1)*k]`` holds row indices LOCAL to ``bases[s]``
    (-1 = invalid slot), each block BIT-IDENTICAL to
    ``topk_scan(queries, bases[s], k, metric, valid=valids[s])``: the
    per-segment gemm shapes are preserved (cache-blocked execution), and
    the score combine runs as in-place passes whose float semantics match
    the expression in ``topk_scan`` exactly.  The fusion removes the
    per-dispatch overheads instead: query norms are computed once, the
    masking/combine passes allocate no broadcast temporaries, and the
    outputs land directly in the pooled candidate arrays.
    """
    n_seg = len(bases)
    nq = len(queries)
    fill = np.inf if metric == "l2" else -np.inf
    if n_seg == 0:
        return (
            np.full((nq, 0), fill, np.float32),
            np.full((nq, 0), -1, np.int64),
        )
    if valids is None:
        valids = [None] * n_seg
    if use_pallas():
        # TPU path: the scan kernel already streams base tiles through
        # VMEM; per-segment kernel launches keep the same semantics.
        parts = [
            topk_scan(queries, b, k, metric=metric, valid=v)
            for b, v in zip(bases, valids)
        ]
        return (
            np.concatenate([p[0] for p in parts], axis=1),
            np.concatenate([p[1] for p in parts], axis=1),
        )

    qn = np.ascontiguousarray(np.asarray(queries, np.float32))
    q_norm = np.sum(qn * qn, axis=1, keepdims=True) if metric == "l2" else None
    out_v = np.full((nq, n_seg * k), fill, np.float32)
    out_i = np.full((nq, n_seg * k), -1, np.int64)

    def emit_block(s_idx: int, vals: np.ndarray, idx: np.ndarray) -> None:
        if metric == "ip":
            vals = -vals
        vals = np.asarray(vals, np.float32)
        idx = np.where(np.abs(vals) >= 1e38, -1, idx.astype(np.int64))
        lo = s_idx * k
        out_v[:, lo : lo + vals.shape[-1]] = vals
        out_i[:, lo : lo + vals.shape[-1]] = idx

    # Group segments with equal row counts (the common case: slices and
    # seal-sized segments are uniform) so each group runs as ONE batched
    # gemm + ONE batched top-k instead of a per-segment dispatch chain.
    # Per-gemm shapes are preserved, so results stay bit-identical to the
    # per-segment scan.
    groups: dict[int, list[int]] = {}
    for s_idx, b in enumerate(bases):
        groups.setdefault(b.shape[0], []).append(s_idx)

    for n_s, members in groups.items():
        if n_s == 0:
            continue
        k_eff = min(k, n_s)
        # The batched cube pays off while it stays cache-resident (many
        # tiny segments, where per-dispatch overhead dominates); past
        # that, streaming the whole [S,nq,n_s] cube through each pass is
        # DRAM-bound and cache-blocked per-segment execution wins.
        if len(members) == 1 or len(members) * nq * n_s > 1 << 17:
            for s_idx in members:
                bn = np.asarray(bases[s_idx], np.float32)
                scores = qn @ bn.T
                if metric == "l2":
                    # In-place equivalent of q_norm - 2*q@b.T + b_norm (the
                    # float ops commute bitwise with topk_scan's expression).
                    scores *= -2.0
                    scores += q_norm
                    scores += np.sum(bn * bn, axis=1)[None, :]
                else:
                    np.negative(scores, out=scores)
                v = valids[s_idx]
                if v is not None:
                    scores[:, ~np.asarray(v, bool)] = np.float32(np.inf)
                vals, idx = _np_topk_min(scores, k_eff)
                emit_block(s_idx, vals, idx)
            continue
        # Per-slice BLAS gemms into one preallocated cube (numpy's stacked
        # matmul bypasses BLAS); all later passes are batched over [S,nq,n_s].
        n_grp = len(members)
        scores = np.empty((n_grp, nq, n_s), np.float32)
        b_norm = np.empty((n_grp, n_s), np.float32) if metric == "l2" else None
        for g, i in enumerate(members):
            bn = np.asarray(bases[i], np.float32)
            np.matmul(qn, bn.T, out=scores[g])
            if metric == "l2":
                b_norm[g] = np.sum(bn * bn, axis=1)
        if metric == "l2":
            scores *= -2.0
            scores += q_norm[None, :, :]
            scores += b_norm[:, None, :]
        else:
            np.negative(scores, out=scores)
        if any(valids[i] is not None for i in members):
            vstack = np.stack(
                [
                    np.ones(n_s, bool)
                    if valids[i] is None
                    else np.asarray(valids[i], bool)
                    for i in members
                ]
            )
            np.copyto(scores, np.float32(np.inf), where=~vstack[:, None, :])
        vals, idx = _np_topk_min(scores, k_eff)  # [S, nq, k_eff]
        for g, s_idx in enumerate(members):
            emit_block(s_idx, vals[g], idx[g])
    return out_v, out_i


def merge_topk(scores, pks, k: int, metric: str = "l2") -> tuple[np.ndarray, np.ndarray]:
    """Segmented k-way top-k merge with pk-dedup (two-phase reduce, §3.6).

    Merges pooled per-segment / per-node top-k candidates
    (scores [nq, m], pks [nq, m], -1 = empty slot) into the final
    per-query top-k, keeping the best occurrence of each pk.  Candidates
    with pk < 0 or a non-finite score are ignored.  Output slots beyond
    the number of distinct live pks carry pk == -1 and the metric's fill
    score (+inf for L2, -inf for IP).  Ties break by pool column order —
    bit-identical to a stable per-row selection over the pools.
    """
    s = np.asarray(scores, np.float32)
    p = np.asarray(pks)
    nq, m = s.shape
    fill = np.inf if metric == "l2" else -np.inf
    if m == 0 or nq == 0:
        return (
            np.full((nq, k), fill, np.float32),
            np.full((nq, k), -1, np.int64),
        )

    if use_pallas() and (p.size == 0 or np.abs(p).max() < 2**31 - 1):
        sp = jnp.asarray(s, jnp.float32)
        pp = jnp.asarray(p.astype(np.int32))
        tq = 128 if nq >= 128 else max(8, 1 << (nq - 1).bit_length())
        pad_m = (-m) % 128
        if pad_m:
            sp = jnp.pad(sp, ((0, 0), (0, pad_m)), constant_values=np.float32(fill))
            pp = jnp.pad(pp, ((0, 0), (0, pad_m)), constant_values=-1)
        sp = _pad_rows(sp, tq)
        pp = _pad_rows(pp, tq, fill=-1)
        k_eff = min(k, m)
        vals, opk = merge_topk_pallas(
            sp, pp, k_eff, metric=metric, tq=tq, interpret=_interpret()
        )
        vals = np.asarray(vals[:nq], np.float32)
        opk = np.asarray(opk[:nq], np.int64)
        bad = np.abs(vals) >= 1e38
        vals = np.where(bad, np.float32(fill), vals)
        opk = np.where(bad, -1, opk)
        if k_eff < k:
            vals = np.concatenate(
                [vals, np.full((nq, k - k_eff), fill, np.float32)], axis=1
            )
            opk = np.concatenate([opk, np.full((nq, k - k_eff), -1, np.int64)], axis=1)
        return vals, opk

    # Host fast path: optimistic top-k by packed integer key, then full
    # dedup only for the rows whose top-k actually contains a duplicate
    # pk.  The common case (disjoint pks across segments/nodes) never
    # pays for grouping sorts: one argpartition + one k-wide sort.
    p = p.astype(np.int64, copy=False)
    alive = (p >= 0) & np.isfinite(s)
    key = np.where(alive, s if metric == "l2" else -s, np.float32(np.inf))
    key += np.float32(0.0)  # canonicalize -0.0 -> +0.0 (they compare equal)
    # Order-preserving f32 -> uint32 bit twiddle (IEEE trick: flip all
    # bits of negatives, flip just the sign bit of non-negatives), with
    # the column index in the low bits so a NON-stable uint64 sort
    # reproduces the stable column tie-break.
    u = key.view(np.uint32)
    ub = (u ^ (np.uint32(0x80000000) | (u >> 31) * np.uint32(0x7FFFFFFF))).astype(
        np.uint64
    )
    if m >= 1 << 20:  # column bits would overflow the compound
        return _merge_topk_host_dedup(s, p, key, alive, ub, k, fill, metric)
    cc = (ub << np.uint64(20)) | np.arange(m, dtype=np.uint64)[None, :]
    order = _topk_by_compound(cc, min(k, m))
    out_s, out_p = _merge_gather(s, p, alive, order, nq, m, k, fill)
    # pk-dedup check: rows whose optimistic top-k holds a repeated pk
    # must re-merge with grouping (dropping a duplicate pulls in
    # candidates from beyond the cut).
    p_sorted = np.sort(out_p, axis=1)
    dup_rows = ((p_sorted[:, 1:] == p_sorted[:, :-1]) & (p_sorted[:, 1:] >= 0)).any(1)
    if dup_rows.any():
        r = np.nonzero(dup_rows)[0]
        out_s[r], out_p[r] = _merge_topk_host_widen(
            s[r], p[r], key[r], alive[r], ub[r], cc[r], k, fill, metric
        )
    return out_s, out_p


def _merge_topk_host_widen(s, p, key, alive, ub, cc, k: int, fill, metric: str):
    """Dedup'd merge via iterative widening: gather the k' globally-best
    candidates per row (cc order), dedup inside that small slice, and
    widen k' until every row has k distinct pks (or the pool is spent).

    The slice is gathered in (key, col) order, so positions inside it
    preserve the stable tie-break, and any candidate left outside has a
    strictly worse compound than everything inside — it can neither
    displace a survivor nor improve a kept score.
    """
    nq, m = s.shape
    k_pr = min(m, max(2 * k, k + 8))
    while True:
        order = _topk_by_compound(cc, k_pr)
        out_s, out_p = _merge_topk_host_dedup(
            np.take_along_axis(s, order, 1),
            np.take_along_axis(p, order, 1),
            np.take_along_axis(key, order, 1),
            np.take_along_axis(alive, order, 1),
            np.take_along_axis(ub, order, 1),
            k,
            fill,
            metric,
        )
        if k_pr >= m or not ((out_p >= 0).sum(1) < k).any():
            return out_s, out_p
        k_pr = min(m, 4 * k_pr)


def _merge_topk_host_dedup(s, p, key, alive, ub, k: int, fill, metric: str):
    """Full grouping merge: kill all but the best occurrence of each pk,
    then take the top-k of the survivors."""
    nq, m = s.shape
    if p.min() >= -1 and p.max() < 2**31 - 1:
        # Group by (pk, key): pk+1 in the high 32 bits, key bits low.  The
        # stable sort keeps equal (pk, key) pairs in column order, so the
        # surviving occurrence is the seed merge's (its column position
        # decides later equal-score tie-breaks across pks).
        perm = np.argsort(
            ((p + 1).astype(np.uint64) << np.uint64(32)) | ub, axis=1, kind="stable"
        )
    else:  # pks outside the packable range: two stable float/int sorts
        ord_key = np.argsort(key, axis=1, kind="stable")
        ord_pk = np.argsort(np.take_along_axis(p, ord_key, 1), axis=1, kind="stable")
        perm = np.take_along_axis(ord_key, ord_pk, 1)
    p_grouped = np.take_along_axis(p, perm, 1)
    dup = np.zeros((nq, m), bool)
    dup[:, 1:] = p_grouped[:, 1:] == p_grouped[:, :-1]
    killed = np.empty((nq, m), bool)
    np.put_along_axis(killed, perm, dup, axis=1)  # scatter to column order
    k_take = min(k, m)
    if m < 1 << 20:
        # survivors' top-k by (key, col) compound — argpartition beats a
        # stable float sort and the column bits keep the tie-break stable
        cc = np.where(killed, np.uint64(0xFFFFFFFF), ub)
        cc = (cc << np.uint64(20)) | np.arange(m, dtype=np.uint64)[None, :]
        order = _topk_by_compound(cc, k_take)
    else:
        key = np.where(killed, np.float32(np.inf), key)
        order = np.argsort(key, axis=1, kind="stable")[:, :k_take]
    return _merge_gather(s, p, alive & ~killed, order, nq, m, k, fill)


def _topk_by_compound(cc: np.ndarray, k_take: int) -> np.ndarray:
    """Row-wise indices of the k_take smallest compound keys, ascending.
    Compounds are unique per row (column bits), so the non-stable
    partition+sort reproduces the stable (key, col) order."""
    m = cc.shape[1]
    if k_take >= m:
        return np.argsort(cc, axis=1)
    part = np.argpartition(cc, k_take - 1, axis=1)[:, :k_take]
    sub = np.take_along_axis(cc, part, 1)
    return np.take_along_axis(part, np.argsort(sub, axis=1), 1)


def _merge_gather(s, p, live, order, nq, m, k, fill):
    sel_alive = np.take_along_axis(live, order, 1)
    out_s = np.where(sel_alive, np.take_along_axis(s, order, 1), fill).astype(np.float32)
    out_p = np.where(sel_alive, np.take_along_axis(p, order, 1), -1)
    if m < k:
        out_s = np.concatenate(
            [out_s, np.full((nq, k - m), fill, np.float32)], axis=1
        )
        out_p = np.concatenate([out_p, np.full((nq, k - m), -1, np.int64)], axis=1)
    return out_s, out_p


def isin_sorted(values, sorted_haystack) -> np.ndarray:
    """Vectorized membership of ``values`` in a SORTED 1-D haystack.

    The searchsorted probe replaces ``np.isin``'s internal sort of the
    haystack on every call — the hot shape is one doomed-pk set shared by
    many per-segment pk columns (delta-delete visibility masks, compaction
    rewrites), so the sort is paid once by the caller and each probe is a
    single binary-search pass.
    """
    v = np.asarray(values)
    hay = np.asarray(sorted_haystack)
    if hay.size == 0 or v.size == 0:
        return np.zeros(v.shape, bool)
    idx = np.searchsorted(hay, v)
    return hay[np.minimum(idx, hay.size - 1)] == v


def eff_tombstones(pks, dts, ts: int):
    """Reduce (pk, delete-ts) tombstone pairs to the per-pk *effective*
    delete timestamp at query time ``ts``.

    A row version ``(pk, row_ts)`` is dead at ``ts`` iff some tombstone of
    its pk has ``row_ts < dts <= ts``; that is equivalent to comparing
    against ``max(dts | dts <= ts)``, so one (sorted-unique pks, eff-dts)
    pair per pk captures the whole tombstone history for one query — the
    shape every segment then probes with :func:`tombstone_mask`.  Returns
    ``(pks_sorted, eff_dts)`` or ``None`` when no tombstone applies.
    """
    pks = np.asarray(pks)
    dts = np.asarray(dts, np.int64)
    sel = dts <= ts
    if not sel.any():
        return None
    p, d = pks[sel], dts[sel]
    order = np.lexsort((d, p))
    p, d = p[order], d[order]
    last = np.r_[p[1:] != p[:-1], True] if p.size > 1 else np.ones(1, bool)
    return p[last], d[last]


def tombstone_mask(seg_pks, seg_ts, doomed_pks, doomed_eff) -> np.ndarray:
    """Rows of a segment killed by a materialized tombstone set.

    ``doomed_pks`` is sorted with ``doomed_eff`` aligned (the output of
    :func:`eff_tombstones`); a row dies iff its pk is doomed AND its row
    timestamp predates the effective delete — so a row re-inserted (or
    upserted) after the delete survives, which is what makes one-LSN
    upserts possible.  One binary-search probe per segment, no re-sorts.
    """
    seg_pks = np.asarray(seg_pks)
    if seg_pks.size == 0 or np.asarray(doomed_pks).size == 0:
        return np.zeros(seg_pks.shape, bool)
    idx = np.searchsorted(doomed_pks, seg_pks)
    idx = np.minimum(idx, len(doomed_pks) - 1)
    hit = doomed_pks[idx] == seg_pks
    return hit & (np.asarray(seg_ts, np.int64) < doomed_eff[idx])


def shard_split(shards: np.ndarray, num_shards: int) -> tuple[np.ndarray, np.ndarray]:
    """Group a batch by shard id in one pass: hash once upstream, then
    ``bincount`` + stable ``argsort`` here — no per-row Python loops.

    Returns ``(order, offsets)``: ``order[offsets[s]:offsets[s+1]]`` are
    the original row indices of shard ``s``, in arrival order (the stable
    sort preserves WAL ordering within a shard).
    """
    shards = np.asarray(shards)
    counts = np.bincount(shards, minlength=num_shards)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    order = np.argsort(shards, kind="stable")
    return order, offsets


def normalized_similarity(scores, metric: str = "l2") -> np.ndarray:
    """Map raw metric scores onto a shared (0, 1] similarity scale.

    Hybrid fusion sums contributions across vector fields, so per-field
    scores must be commensurable and higher-is-better: L2 distances map
    through ``1/(1+d)`` (d clipped at 0 — the gemm expansion can go a few
    ulp negative), cosine through ``(1+s)/2``, and unbounded IP through
    the logistic ``1/(1+exp(-s))``.
    """
    s = np.asarray(scores, np.float32)
    if metric == "l2":
        return 1.0 / (1.0 + np.maximum(s, 0.0))
    if metric == "cosine":
        return (1.0 + s) / 2.0
    return 1.0 / (1.0 + np.exp(-s))


def hybrid_fuse(
    scores_list,
    pks_list,
    k: int,
    metrics,
    weights=None,
    kind: str = "weighted",
    rrf_k: float = 60.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Fuse per-field candidate lists into the hybrid top-k (one shot).

    ``scores_list[f]`` / ``pks_list[f]`` is vector field ``f``'s global
    result (best-first, [nq, m_f], pk < 0 = empty slot).  ``kind`` is
    ``"weighted"`` (weight-scaled sum of :func:`normalized_similarity`)
    or ``"rrf"`` (``w_f / (rrf_k + rank)``, 1-based ranks).  A pk absent
    from a field's list contributes nothing for that field.

    Returns (fused_scores [nq, k] descending, pks [nq, k]); slots beyond
    the number of distinct candidates carry pk == -1 and -inf.  The whole
    reduce is vectorized: per-field contributions are computed in one
    pass, per-(row, pk) sums via one flat-key ``bincount``, and the final
    ranking is one stable argsort — no per-row Python loops.
    """
    n_fields = len(scores_list)
    if n_fields == 0:
        raise ValueError("hybrid_fuse needs at least one field result")
    if weights is None:
        weights = [1.0] * n_fields
    if isinstance(metrics, str):
        metrics = [metrics] * n_fields
    nq = np.asarray(scores_list[0]).shape[0]

    contribs = []
    for f in range(n_fields):
        s = np.asarray(scores_list[f], np.float32)
        p = np.asarray(pks_list[f])
        live = (p >= 0) & np.isfinite(s)
        if kind == "rrf":
            ranks = np.arange(1, s.shape[1] + 1, dtype=np.float64)[None, :]
            c = np.float64(weights[f]) / (np.float64(rrf_k) + ranks)
            c = np.broadcast_to(c, s.shape).copy()
        elif kind == "weighted":
            c = np.float64(weights[f]) * normalized_similarity(s, metrics[f]).astype(
                np.float64
            )
        else:
            raise ValueError(f"unknown fusion kind '{kind}'")
        c[~live] = 0.0
        contribs.append(c)

    P = np.concatenate([np.asarray(p) for p in pks_list], axis=1).astype(np.int64)
    C = np.concatenate(contribs, axis=1)
    m = P.shape[1]
    if nq == 0 or m == 0:
        return (
            np.full((nq, k), -np.inf, np.float32),
            np.full((nq, k), -1, np.int64),
        )
    live = P >= 0
    # Per-(row, pk) sum: one flat-key bincount over all live slots.
    stride = np.int64(max(int(P.max()) + 1, 1))
    rows = np.repeat(np.arange(nq, dtype=np.int64)[:, None], m, axis=1)
    key = rows * stride + np.where(live, P, 0)
    flat_live = live.ravel()
    fused = np.full(nq * m, -np.inf, np.float64)
    if flat_live.any():
        uniq, inv = np.unique(key.ravel()[flat_live], return_inverse=True)
        sums = np.bincount(inv, weights=C.ravel()[flat_live], minlength=len(uniq))
        # Scatter each candidate's sum onto its FIRST occurrence slot; the
        # duplicates stay -inf and sort behind every live candidate.
        pos = np.nonzero(flat_live)[0]
        first = np.full(len(uniq), np.iinfo(np.int64).max, np.int64)
        np.minimum.at(first, inv, pos)
        fused[first] = sums
    fused = fused.reshape(nq, m)
    order = np.argsort(-fused, axis=1, kind="stable")[:, :k]
    out_s = np.take_along_axis(fused, order, axis=1)
    out_p = np.take_along_axis(P, order, axis=1)
    dead = ~np.isfinite(out_s)
    out_p[dead] = -1
    pad = k - out_s.shape[1]
    if pad > 0:
        out_s = np.concatenate([out_s, np.full((nq, pad), -np.inf)], axis=1)
        out_p = np.concatenate([out_p, np.full((nq, pad), -1, np.int64)], axis=1)
    return out_s.astype(np.float32), out_p


def range_cut(
    scores,
    pks,
    metric: str = "l2",
    radius=None,
    range_filter=None,
) -> tuple[np.ndarray, np.ndarray]:
    """Post-scan radius cut (range search).

    Milvus-convention bounds: for L2 (ascending) keep
    ``range_filter <= d < radius``; for IP/cosine (descending) keep
    ``radius < s <= range_filter``.  Either bound may be None.  Cut slots
    become (fill, -1); they are NOT compacted — downstream ``merge_topk``
    drops them.
    """
    s = np.asarray(scores, np.float32)
    p = np.asarray(pks)
    keep = (p >= 0) & np.isfinite(s)
    if metric == "l2":
        fill = np.float32(np.inf)
        if radius is not None:
            keep &= s < radius
        if range_filter is not None:
            keep &= s >= range_filter
    else:
        fill = np.float32(-np.inf)
        if radius is not None:
            keep &= s > radius
        if range_filter is not None:
            keep &= s <= range_filter
    return np.where(keep, s, fill), np.where(keep, p, -1)


def mask_intersect(*masks) -> np.ndarray | None:
    """Fold row bitmaps (filter ∩ visibility ∩ ...) into one validity mask.

    ``None`` operands mean all-visible and are skipped; returns ``None``
    when every operand is ``None``.  Single memory-bound boolean pass —
    the planner combines attribute-filter bitmaps with MVCC/tombstone
    masks through this one op so the cost is bookable.
    """
    out = None
    for m in masks:
        if m is None:
            continue
        m = np.asarray(m, bool)
        out = m.copy() if out is None else (out & m)
    return out


def post_filter_cut(scores, idx, keep, metric: str = "l2") -> tuple[np.ndarray, np.ndarray]:
    """Cut post-filter candidates whose row fails the filter bitmap.

    ``scores/idx [nq, m]`` are a candidate pool with segment-local row ids
    (-1 = empty slot); ``keep [n]`` is the filter bitmap over the segment's
    rows.  Failing slots become (fill, -1) — not compacted; downstream
    ``merge_topk`` drops them, mirroring ``range_cut``.
    """
    s = np.asarray(scores, np.float32)
    i = np.asarray(idx, np.int64)
    km = np.asarray(keep, bool)
    fill = np.float32(np.inf if metric == "l2" else -np.inf)
    alive = i >= 0
    ok = np.zeros(i.shape, bool)
    if km.size and alive.any():
        ok[alive] = km[i[alive]]
    dead = alive & ~ok
    return np.where(dead, fill, s), np.where(dead, -1, i)


def pq_adc_topk(luts, codes, k: int, valid=None) -> tuple[np.ndarray, np.ndarray]:
    """ADC top-k over PQ codes.  luts: [nq, m, ksub]; codes: [n, m]."""
    n = codes.shape[0]
    nq = luts.shape[0]
    if n == 0:
        return np.full((nq, k), np.inf, np.float32), np.full((nq, k), -1, np.int64)
    k_eff = min(k, n)
    if use_pallas():
        luts = jnp.asarray(luts, jnp.float32)
        codes = jnp.asarray(codes, jnp.int32)
        v = jnp.ones(n, jnp.int32) if valid is None else jnp.asarray(valid).astype(jnp.int32)
        tn = 512 if n >= 512 else max(128, 1 << (n - 1).bit_length())
        cp = _pad_rows(codes, tn)
        vp = _pad_rows(v, tn, fill=0)
        vals, idx = pq_adc_topk_pallas(luts, cp, vp, k_eff, tn=tn, interpret=_interpret())
        vals, idx = np.asarray(vals), np.asarray(idx, np.int64)
    else:
        ln = np.asarray(luts, np.float32)
        cn = np.asarray(codes, np.int64)
        m = cn.shape[1]
        scores = np.zeros((nq, n), np.float32)
        for j in range(m):
            scores += ln[:, j, cn[:, j]]
        if valid is not None:
            scores = np.where(np.asarray(valid, bool)[None, :], scores, np.float32(np.inf))
        vals, idx = _np_topk_min(scores, k_eff)
        idx = idx.astype(np.int64)
    idx = np.where(np.abs(vals) >= 1e38, -1, idx)
    if k_eff < k:
        vals = np.concatenate(
            [vals, np.full((nq, k - k_eff), np.inf, np.float32)], axis=1
        )
        idx = np.concatenate([idx, np.full((nq, k - k_eff), -1, np.int64)], axis=1)
    return vals, idx


def sq_scale(vmin, vmax) -> np.ndarray:
    """The SQ codec's per-dimension quantization step (single source of
    truth for encode/decode and any fused-dequant scan)."""
    vmin = np.asarray(vmin, np.float32)
    vmax = np.asarray(vmax, np.float32)
    return np.maximum(vmax - vmin, 1e-12) / 255.0


def sq_encode(x, vmin, vmax) -> np.ndarray:
    if use_pallas():
        x = jnp.asarray(x, jnp.float32)
        n = x.shape[0]
        tn = 512 if n >= 512 else max(128, 1 << max(0, (n - 1)).bit_length())
        xp = _pad_rows(x, tn)
        out = sq_encode_pallas(xp, jnp.asarray(vmin), jnp.asarray(vmax), tn=tn, interpret=_interpret())
        return np.asarray(out[:n], np.uint8)
    xn = np.asarray(x, np.float32)
    vmin = np.asarray(vmin, np.float32)
    scale = sq_scale(vmin, vmax)
    q = np.round((xn - vmin[None, :]) / scale[None, :])
    return np.clip(q, 0, 255).astype(np.uint8)


def sq_decode(codes, vmin, vmax) -> np.ndarray:
    if use_pallas():
        codes = jnp.asarray(codes)
        n = codes.shape[0]
        tn = 512 if n >= 512 else max(128, 1 << max(0, (n - 1)).bit_length())
        cp = _pad_rows(codes.astype(jnp.int32), tn)
        out = sq_decode_pallas(cp, jnp.asarray(vmin), jnp.asarray(vmax), tn=tn, interpret=_interpret())
        return np.asarray(out[:n])
    vmin = np.asarray(vmin, np.float32)
    scale = sq_scale(vmin, vmax)
    return np.asarray(codes, np.float32) * scale[None, :] + vmin[None, :]


def sq_topk_scan(
    queries, codes, vmin, vmax, k: int, metric: str = "l2", valid=None
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k against SQ-compressed base with fused dequantization."""
    n = codes.shape[0]
    nq = len(queries)
    if n == 0:
        fill = np.inf if metric == "l2" else -np.inf
        return np.full((nq, k), fill, np.float32), np.full((nq, k), -1, np.int64)
    k_eff = min(k, n)
    if use_pallas():
        queries = jnp.asarray(queries, jnp.float32)
        codes = jnp.asarray(codes)
        v = jnp.ones(n, jnp.int32) if valid is None else jnp.asarray(valid).astype(jnp.int32)
        tq, tn = _choose_tiles(nq, n)
        qp = _pad_rows(queries, tq)
        cp = _pad_rows(codes.astype(jnp.int32), tn)
        vp = _pad_rows(v, tn, fill=0)
        vals, idx = sq_l2_topk_pallas(
            qp, cp, jnp.asarray(vmin), jnp.asarray(vmax), vp, k_eff,
            metric=metric, tq=tq, tn=tn, interpret=_interpret(),
        )
        vals, idx = np.asarray(vals[:nq]), np.asarray(idx[:nq], np.int64)
    else:
        decoded = sq_decode(np.asarray(codes), vmin, vmax)
        return topk_scan(np.asarray(queries), decoded, k, metric=metric, valid=valid)
    vals, idx = np.asarray(vals), np.asarray(idx, np.int64)
    idx = np.where(np.abs(vals) >= 1e38, -1, idx)
    if k_eff < k:
        fill = np.inf if metric == "l2" else -np.inf
        vals = np.concatenate([vals, np.full((nq, k - k_eff), fill, np.float32)], axis=1)
        idx = np.concatenate([idx, np.full((nq, k - k_eff), -1, np.int64)], axis=1)
    return vals, idx


# ---------------------------------------------------------------------------
# Batched IVF execution: probe inversion + size-bucketed gather-scan.
#
# ``ivf_probe_schedule`` inverts a ``probes [nq, nprobe]`` matrix into a
# deduplicated (list -> query-group) schedule over the index's CSR layout:
# each probed list is scanned ONCE against the padded group of queries that
# probe it.  Scheduled lists are bucketed by quantized (list length, group
# size) levels so the number of distinct padded shapes stays bounded (the
# JIT recompilation budget on TPU; on host it bounds the number of batched
# dispatches), while the actual padded extents are the in-bucket maxima so
# no flops are wasted on the quantization ceiling.  ``ivf_gather_topk`` then
# runs one fused scan per bucket through a caller-supplied scorer and
# scatters per-(query, probe-slot) top-k candidates into a dense pool that
# feeds straight into ``merge_topk``.
# ---------------------------------------------------------------------------


@dataclass
class IVFBucket:
    """One fused-scan work item: B probed lists padded to a common
    (group G, width W) tile.  ``rows`` are absolute row indices into the
    permuted CSR storage (padding clipped to each list's first row, masked
    dead by ``wmask``); ``q_idx``/``slot_idx`` address the candidate pool,
    ``pair_idx`` addresses the schedule's flat pair arrays (per-pair state
    such as PQ residual LUTs)."""

    lists: np.ndarray  # [B] list ids
    lo: np.ndarray  # [B] CSR start offset per list
    lengths: np.ndarray  # [B] rows per list
    rows: np.ndarray  # [B, W] absolute (clipped) storage rows
    wmask: np.ndarray  # [B, W] True = real row
    q_idx: np.ndarray  # [B, G] query index per group slot
    slot_idx: np.ndarray  # [B, G] probe slot per group slot
    pair_idx: np.ndarray  # [B, G] index into schedule pair arrays
    gmask: np.ndarray  # [B, G] True = real (query, list) pair
    full: bool  # True when every [B, W] slot is a real row (no padding)


@dataclass
class IVFSchedule:
    """Inverted probe schedule: flat (query, list) pairs sorted by list id
    plus the bucketed scan work items derived from them."""

    buckets: "list[IVFBucket]"
    pair_q: np.ndarray  # [P] query index per kept pair (list-sorted order)
    pair_list: np.ndarray  # [P] list id per kept pair (sorted)
    nq: int
    nprobe: int


def _pow2_ceil(x: np.ndarray) -> np.ndarray:
    """Elementwise smallest power of two >= x (x >= 1)."""
    e = np.ceil(np.log2(np.maximum(np.asarray(x, np.int64), 1))).astype(np.int64)
    return np.left_shift(np.int64(1), e)


def _bucket_quantum(x: np.ndarray) -> np.ndarray:
    """Quantize up to {1, 2, 3, 4, 6, 8, 12, 16, ...}: powers of two plus
    their midpoints.  Finer than pow2-only buckets (padding waste <= 1.33x
    instead of 2x on the scan's hot axes) while the level count stays
    logarithmic, keeping the distinct padded shapes bounded."""
    p2 = _pow2_ceil(x)
    mid = (p2 >> 1) + (p2 >> 2)  # 0.75 * p2
    return np.where(np.asarray(x) <= mid, np.maximum(mid, 1), p2)


_SMALL_TILE_W = 128  # lists at or below this width share one tile class
_COARSE_W = 512  # below this width, padding is cheaper than dispatches


def _pow4_ceil(x: np.ndarray) -> np.ndarray:
    """Smallest power of FOUR >= x: the coarse group-size ladder."""
    e = np.ceil(np.log2(np.maximum(np.asarray(x, np.int64), 1))).astype(np.int64)
    return np.left_shift(np.int64(1), (e + 1) >> 1 << 1)


def ivf_probe_schedule(
    probes, list_offsets, max_tile_rows: int = 1 << 17
) -> IVFSchedule:
    """Invert ``probes [nq, nprobe]`` into a bucketed gather-scan schedule.

    Padded probe slots (id -1, emitted by ``topk_scan`` when fewer than
    ``nprobe`` lists exist) and empty lists are dropped up front — the
    corresponding pool slots simply stay at their fill value.  All steps
    are vectorized; the only Python iteration is over the bounded set of
    (length-bucket, group-bucket) keys and their ``max_tile_rows`` chunks.
    """
    probes = np.asarray(probes)
    offsets = np.asarray(list_offsets, np.int64)
    nq, nprobe = probes.shape
    lengths_all = np.diff(offsets)
    nlist = len(lengths_all)

    pair_list = probes.reshape(-1).astype(np.int64)
    pair_q = np.repeat(np.arange(nq, dtype=np.int64), nprobe)
    pair_slot = np.tile(np.arange(nprobe, dtype=np.int64), nq)
    ok = (pair_list >= 0) & (pair_list < nlist)
    if nlist:
        ok &= lengths_all[np.clip(pair_list, 0, nlist - 1)] > 0
    pair_list, pair_q, pair_slot = pair_list[ok], pair_q[ok], pair_slot[ok]

    order = np.argsort(pair_list, kind="stable")
    pl, pq, ps = pair_list[order], pair_q[order], pair_slot[order]
    sched = IVFSchedule([], pq, pl, nq, nprobe)
    if pl.size == 0:
        return sched

    ulists, starts, counts = np.unique(pl, return_index=True, return_counts=True)
    ulen = lengths_all[ulists]
    # Adaptive bucket granularity: for big tiles padding waste is the cost
    # (fine levels); for small ones the per-bucket dispatch is (coarse
    # levels + a shared width floor), keeping cells AND bucket count low.
    wq = np.maximum(_bucket_quantum(ulen), _SMALL_TILE_W)
    gq = np.where(
        wq <= _COARSE_W, _pow4_ceil(counts), _bucket_quantum(counts)
    )
    bkey = wq << np.int64(32) | gq
    for key in np.unique(bkey):  # bounded: one per (W, G) power-of-2 pair
        mem = np.nonzero(bkey == key)[0]
        w = int(ulen[mem].max())
        g = int(counts[mem].max())
        chunk = max(1, max_tile_rows // max(w, 1))
        for c0 in range(0, len(mem), chunk):
            mm = mem[c0 : c0 + chunk]
            lo = offsets[ulists[mm]]
            ln = ulen[mm]
            wmask = np.arange(w)[None, :] < ln[:, None]
            rows = np.where(wmask, lo[:, None] + np.arange(w)[None, :], lo[:, None])
            gpos = starts[mm][:, None] + np.arange(g)[None, :]
            gmask = np.arange(g)[None, :] < counts[mm][:, None]
            gpos = np.minimum(gpos, pl.size - 1)
            sched.buckets.append(
                IVFBucket(
                    lists=ulists[mm],
                    lo=lo,
                    lengths=ln,
                    rows=rows,
                    wmask=wmask,
                    q_idx=pq[gpos],
                    slot_idx=ps[gpos],
                    pair_idx=gpos,
                    gmask=gmask,
                    full=bool(ln.min() == w),
                )
            )
    return sched


def ivf_gather_topk(
    schedule: IVFSchedule, k: int, score_bucket
) -> tuple[np.ndarray, np.ndarray]:
    """Run a probe schedule's fused scans and pool per-pair top-k.

    ``score_bucket(bucket) -> scores [B, G, W]`` must return min-semantics
    scores (L2 distance, or negated similarity for IP) with dead slots
    (padding, invisible rows) at +inf.  Returns
    ``(pool_scores [nq, nprobe*k], pool_rows [nq, nprobe*k])`` where block
    ``[:, j*k:(j+1)*k]`` holds probe slot j's candidates: min-semantics
    scores (+inf fill) and absolute rows into the permuted CSR storage
    (-1 fill) — ready for a ``merge_topk`` reduce after the caller maps
    rows to ids and flips sign for descending metrics.
    """
    nq, nprobe = schedule.nq, schedule.nprobe
    pool_s = np.full((nq, nprobe, k), np.inf, np.float32)
    pool_r = np.full((nq, nprobe, k), -1, np.int64)
    for b in schedule.buckets:
        scores = score_bucket(b)  # [B, G, W]
        k_eff = min(k, scores.shape[2])
        vals, idx = _np_topk_min(scores, k_eff)
        idx += b.lo[:, None, None]  # local offsets -> absolute rows
        np.copyto(idx, -1, where=vals >= np.float32(1e38))  # dead slots
        sel = b.gmask
        qi, si = b.q_idx[sel], b.slot_idx[sel]
        pool_s[qi, si, :k_eff] = vals[sel]
        pool_r[qi, si, :k_eff] = idx[sel]
    return pool_s.reshape(nq, nprobe * k), pool_r.reshape(nq, nprobe * k)


def kmeans_assign(x, centroids) -> tuple[np.ndarray, np.ndarray]:
    """Nearest-centroid assignment: returns (assign [n] int, sqdist [n])."""
    n, ncent = x.shape[0], centroids.shape[0]
    if use_pallas():
        x = jnp.asarray(x, jnp.float32)
        c = jnp.asarray(centroids, jnp.float32)
        tn = 512 if n >= 512 else max(128, 1 << (max(n, 2) - 1).bit_length())
        tc = 512 if ncent >= 512 else max(128, 1 << (max(ncent, 2) - 1).bit_length())
        xp = _pad_rows(x, tn)
        # pad centroids with far-away sentinels so they never win
        pad = (-ncent) % tc
        if pad:
            c = jnp.concatenate([c, jnp.full((pad, c.shape[1]), 1e18, jnp.float32)])
        a, d = kmeans_assign_pallas(xp, c, tn=tn, tc=tc, interpret=_interpret())
        return np.asarray(a[:n], np.int64), np.asarray(d[:n])
    xn = np.asarray(x, np.float32)
    cn = np.asarray(centroids, np.float32)
    d2 = (
        np.sum(xn * xn, axis=1, keepdims=True)
        - 2.0 * xn @ cn.T
        + np.sum(cn * cn, axis=1)[None, :]
    )
    return np.argmin(d2, axis=1).astype(np.int64), np.min(d2, axis=1)
