"""Segmented k-way top-k merge with pk-dedup — Pallas TPU kernel.

The two-phase reduce (paper §3.6) pools per-segment / per-node top-k
candidates into [NQ, M] score+pk tiles; this kernel folds them into the
final per-query top-k while dropping duplicate primary keys (a row may
surface from both a growing copy and the sealed segment, or from two
nodes during segment hand-off).  Keep-best-occurrence semantics: for
each pk the minimum key (L2 distance, negated IP similarity) wins.

The body is the K-step min/argmin selection loop from ``topk_util`` with
one extension: after emitting a winner, EVERY candidate carrying the
same pk is masked out with a vectorized compare against the picked pk —
the per-row dedup the host merge used to run as a Python loop.  The
candidate pool [TQ, M] stays VMEM-resident across the whole loop (M is
n_partials * k, a few thousand lanes at most); the grid tiles queries
only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .topk_util import BIG_F32, NEG_I32

DEFAULT_TQ = 128


def _merge_kernel(
    s_ref,  # [TQ, M] pooled candidate scores
    p_ref,  # [TQ, M] int32 pks, -1 = empty slot
    out_v_ref,  # [TQ, K]
    out_p_ref,  # [TQ, K]
    *,
    k: int,
    metric: str,
):
    s = s_ref[...].astype(jnp.float32)
    p = p_ref[...]
    key = s if metric == "l2" else -s
    ok = (p >= 0) & (key < BIG_F32) & (key > -BIG_F32) & ~jnp.isnan(key)
    key = jnp.where(ok, key, BIG_F32)
    tq, m = key.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (tq, m), 1)

    def body(j, carry):
        cand, ov, op = carry
        row_min = jnp.min(cand, axis=1)
        row_arg = jnp.argmin(cand, axis=1).astype(jnp.int32)
        picked_oh = iota == row_arg[:, None]  # [TQ, M] one-hot
        # integer one-hot reduce: exact for any int32 pk (no f32 rounding)
        picked_pk = jnp.sum(jnp.where(picked_oh, p, 0), axis=1)
        have = row_min < BIG_F32
        picked_pk = jnp.where(have, picked_pk, NEG_I32)
        ov = jax.lax.dynamic_update_slice(
            ov, jnp.where(have, row_min, BIG_F32)[:, None], (0, j)
        )
        op = jax.lax.dynamic_update_slice(op, picked_pk[:, None], (0, j))
        # pk-dedup: retire every occurrence of the picked pk, not just the
        # winning slot (keep-best-occurrence)
        kill = (p == picked_pk[:, None]) & have[:, None]
        return jnp.where(kill, BIG_F32, cand), ov, op

    out_v = jnp.full((tq, k), BIG_F32, jnp.float32)
    out_p = jnp.full((tq, k), NEG_I32, jnp.int32)
    _, out_v, out_p = jax.lax.fori_loop(0, k, body, (key, out_v, out_p))
    if metric == "ip":
        out_v = -out_v  # back to similarity scale (empty slots -> -BIG)
    out_v_ref[...] = out_v
    out_p_ref[...] = out_p


@functools.partial(jax.jit, static_argnames=("k", "metric", "tq", "interpret"))
def merge_topk_pallas(
    scores: jnp.ndarray,  # [NQ, M] padded to TQ multiple, M lane-aligned
    pks: jnp.ndarray,  # [NQ, M] int32
    k: int,
    metric: str = "l2",
    tq: int = DEFAULT_TQ,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    nq, m = scores.shape
    assert nq % tq == 0, (nq, tq)
    kernel = functools.partial(_merge_kernel, k=k, metric=metric)
    out_v, out_p = pl.pallas_call(
        kernel,
        grid=(nq // tq,),
        in_specs=[
            pl.BlockSpec((tq, m), lambda i: (i, 0)),
            pl.BlockSpec((tq, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i: (i, 0)),
            pl.BlockSpec((tq, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        interpret=interpret,
    )(scores, pks.astype(jnp.int32))
    return out_v, out_p
