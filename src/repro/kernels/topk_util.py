"""Top-k maintenance helpers shared by the Pallas kernel bodies.

TPUs have no warp-shuffle top-k (the CUDA idiom Manu/Faiss use); the
idiomatic Mosaic equivalent is a K-step selection over a candidate tile
using only reductions, broadcasted iota, and one-hot arithmetic — all of
which lower cleanly to the VPU.  ``select_topk_small`` extracts the K
smallest entries of a [TQ, M] candidate tile; ``merge_topk`` folds a new
candidate tile into the running per-query buffer kept in VMEM scratch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_I32 = -1
BIG_F32 = 3.0e38


def _row_onehot(col_idx: jnp.ndarray, width: int) -> jnp.ndarray:
    """[TQ] int32 -> one-hot [TQ, width] float32 (Mosaic-safe gather substitute)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (col_idx.shape[0], width), 1)
    return (iota == col_idx[:, None]).astype(jnp.float32)


def select_topk_small(
    vals: jnp.ndarray, idx: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """K smallest of each row of ``vals`` [TQ, M] with carried indices.

    Pure min/argmin selection loop: K iterations, each picks the row-wise
    minimum, emits it, and masks it out with a one-hot.  Ascending output.
    """
    tq, m = vals.shape
    out_v = jnp.full((tq, k), BIG_F32, dtype=jnp.float32)
    out_i = jnp.full((tq, k), NEG_I32, dtype=jnp.int32)

    def body(j, carry):
        cv, ov, oi = carry
        row_min = jnp.min(cv, axis=1)  # [TQ]
        row_arg = jnp.argmin(cv, axis=1).astype(jnp.int32)  # [TQ]
        oh = _row_onehot(row_arg, m)  # [TQ, M]
        picked_idx = jnp.sum(oh * idx.astype(jnp.float32), axis=1).astype(jnp.int32)
        ov = jax.lax.dynamic_update_slice(ov, row_min[:, None], (0, j))
        oi = jax.lax.dynamic_update_slice(oi, picked_idx[:, None], (0, j))
        cv = jnp.where(oh > 0, BIG_F32, cv)
        return cv, ov, oi

    _, out_v, out_i = jax.lax.fori_loop(
        0, k, body, (vals.astype(jnp.float32), out_v, out_i)
    )
    return out_v, out_i


def merge_topk(
    acc_v: jnp.ndarray,
    acc_i: jnp.ndarray,
    new_v: jnp.ndarray,
    new_i: jnp.ndarray,
    k: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge running top-k [TQ,K] with a fresh candidate tile [TQ,M]."""
    cand_v = jnp.concatenate([acc_v, new_v.astype(jnp.float32)], axis=1)
    cand_i = jnp.concatenate([acc_i, new_i], axis=1)
    return select_topk_small(cand_v, cand_i, k)


def tile_base_indices(tile_rows: int, tile_idx: jnp.ndarray, tq: int) -> jnp.ndarray:
    """Global base-row indices for the current [TQ, TN] tile."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (tq, tile_rows), 1)
    return iota + (tile_idx * tile_rows).astype(jnp.int32)
