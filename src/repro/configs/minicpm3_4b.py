"""minicpm3-4b — dense with multi-head latent attention (MLA)
[hf:openbmb/MiniCPM3-4B; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,   # MLA: per-head latents; kv=40 per assignment table
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    rope_theta=10_000.0,
)
