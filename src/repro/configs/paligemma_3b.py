"""paligemma-3b — SigLIP + gemma VLM; the vision tower is a STUB per the
assignment: input_specs() supplies precomputed patch embeddings
[arXiv:2407.07726; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,    # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    rope_theta=10_000.0,
    frontend="vlm_stub",
    num_prefix_embeddings=256,  # 224px / 14 patch -> 16x16
)
