"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with 16-expert
top-2 MoE every other layer [arXiv:2403.19887; hf].  Deviation (DESIGN.md):
SSM layers use the Mamba2/SSD block (TPU-friendly chunked matmul form)
rather than Mamba1's selective scan."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    rope_theta=10_000.0,
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    # 1 attention layer per 8 (1:7 ratio), attention at slot 4 as in the paper
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
)
