"""deepseek-moe-16b — fine-grained MoE: 64 routed top-6 + 2 shared experts
[arXiv:2401.06066; hf].  Deviation (DESIGN.md): HF layer 0 is dense; we use
MoE on every layer for a uniform scan (<2% parameter delta)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    rope_theta=10_000.0,
    moe_num_experts=64,
    moe_top_k=6,
    moe_d_ff=1408,
    moe_num_shared=2,
    moe_every=1,
    moe_norm_topk=False,  # deepseek v1 does not renormalize top-k gates
)
