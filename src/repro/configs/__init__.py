"""Assigned architecture configs (one module per arch) + registry.

Every config is selectable via ``--arch <id>`` in the launchers; the exact
hyper-parameters follow the assignment table (sources inline per module).
"""

from __future__ import annotations

from ..models.config import SHAPES, ModelConfig, ShapeConfig
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .mamba2_370m import CONFIG as mamba2_370m
from .minicpm3_4b import CONFIG as minicpm3_4b
from .musicgen_medium import CONFIG as musicgen_medium
from .paligemma_3b import CONFIG as paligemma_3b
from .qwen1_5_4b import CONFIG as qwen1_5_4b
from .qwen3_32b import CONFIG as qwen3_32b
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from .yi_9b import CONFIG as yi_9b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        yi_9b,
        qwen3_32b,
        minicpm3_4b,
        qwen1_5_4b,
        paligemma_3b,
        qwen3_moe_30b_a3b,
        deepseek_moe_16b,
        mamba2_370m,
        musicgen_medium,
        jamba_v0_1_52b,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]


def cells() -> list[tuple[str, str]]:
    """All assigned (arch, shape) dry-run cells, with long_500k restricted to
    sub-quadratic archs per the assignment (skips recorded in DESIGN.md)."""
    out = []
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic():
                continue
            out.append((arch, shape.name))
    return out


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch, cfg in ARCHS.items():
        if not cfg.sub_quadratic():
            out.append(
                (arch, "long_500k",
                 "pure full-attention arch: O(S) KV per token at 524288 is "
                 "out of scope per assignment; see DESIGN.md §Arch-applicability")
            )
    return out


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_arch", "cells", "skipped_cells"]
