"""qwen3-moe-30b-a3b — 128-expert top-8 MoE, GQA, qk-norm
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,          # unused (all layers MoE); kept for table fidelity
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe_num_experts=128,
    moe_top_k=8,
    moe_d_ff=768,
    moe_every=1,
    moe_norm_topk=True,
)
