"""mamba2-370m — pure SSM (SSD, state-space duality) [arXiv:2405.21060]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,       # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,            # mamba blocks subsume the MLP; see layer_pattern
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    layer_pattern=("ssm",),
)
