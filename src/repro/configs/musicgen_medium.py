"""musicgen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284; hf].  Backbone only: the EnCodec frontend is a stub and
the 4 codebook streams are folded to a single token stream (DESIGN.md)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    rope_theta=10_000.0,
    frontend="audio_stub",
)
