"""Switchable scan: rolled (default) vs fully unrolled (cost-analysis mode).

XLA's ``cost_analysis()`` counts each while-loop body ONCE — it does not
multiply by trip count — so FLOPs/bytes of scan-structured models are
undercounted by ~num_layers (observed 28x on yi-9b).  The dry-run therefore
runs a second *cost pass*: shallow (1- and 2-period) model variants with
every scan unrolled, whose costs extrapolate linearly to full depth
(EXPERIMENTS.md §Dry-run describes the method).  ``set_unroll(True)``
switches every model-internal scan/map to ``unroll=True``.
"""

from __future__ import annotations

import threading

import jax

_STATE = threading.local()


def set_unroll(value: bool) -> None:
    _STATE.unroll = bool(value)


def unrolling() -> bool:
    return getattr(_STATE, "unroll", False)


def scan(body, init, xs, length=None):
    return jax.lax.scan(body, init, xs, length=length, unroll=unrolling() or 1)


def map_(fn, xs):
    if unrolling():
        def body(carry, x):
            return carry, fn(x)

        _c, ys = jax.lax.scan(body, (), xs, unroll=True)
        return ys
    return jax.lax.map(fn, xs)
