"""Mamba2 / SSD (state-space duality) block.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks of
Q tokens; within a chunk the output is a masked quadratic form (MXU
matmuls), across chunks a small recurrent state [H, hd, N] is carried by a
``lax.scan``.  Decode is the O(1) recurrence ``h = a·h + dt·B⊗x``;
``y = C·h + D·x`` — which is what makes long_500k serveable.

Single-group B/C (G=1), scalar A per head (the Mamba2 default).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain
from . import scan_util
from .config import ModelConfig
from .layers import PARAM_DTYPE


def init_ssm_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv = cfg.ssm_conv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj emits [z(di), x(di), B(n), C(n), dt(h)]
    proj_out = 2 * di + 2 * n + h
    return {
        "w_in": jax.random.normal(k1, (d, proj_out), PARAM_DTYPE) / math.sqrt(d),
        "conv_w": jax.random.normal(k2, (conv, di + 2 * n), PARAM_DTYPE) * 0.1,
        "conv_b": jnp.zeros(di + 2 * n, PARAM_DTYPE),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones(h, jnp.float32),
        "dt_bias": jnp.zeros(h, jnp.float32),
        "norm": jnp.ones(di, PARAM_DTYPE),
        "w_out": jax.random.normal(k3, (di, d), PARAM_DTYPE) / math.sqrt(di),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d over [B, S, C] with window len(w)."""
    conv = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (conv - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(conv):  # conv is tiny (4): unrolled taps
        out = out + pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def ssd_chunked(
    x: jnp.ndarray,  # [B, S, H, hd]
    dt: jnp.ndarray,  # [B, S, H] (post softplus)
    a: jnp.ndarray,  # [H] (negative decay rates)
    b_in: jnp.ndarray,  # [B, S, N]
    c_in: jnp.ndarray,  # [B, S, N]
    chunk: int,
    h_init: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.  Returns (y [B,S,H,hd], final state [B,H,hd,N])."""
    bsz, s, h, hd = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    s_p = s + pad
    nc = s_p // chunk

    xc = x.reshape(bsz, nc, chunk, h, hd)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_in.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    # per-step log decay: da[b,t,h] = a[h] * dt[b,t,h]  (a < 0)
    da = dtc * a[None, None, None, :]  # [B,nc,Q,H]
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative decay

    def chunk_step(h_state, inputs):
        # h_state: [B, H, hd, N].  Every contraction below is an explicit
        # pairwise binary op with rank <= 4 intermediates — multi-operand
        # einsums here let opt_einsum/autodiff materialize rank-6 monsters
        # (observed 128 GiB/chunk in the dry-run before this decomposition).
        xj, dtj, bj, cj, daj, cumj = inputs  # leading dim B
        xf = xj.astype(jnp.float32)
        # within-chunk decay matrix L[b,h,t,t'] = exp(cum_t - cum_t') * (t>=t')
        diff = cumj[:, :, None, :].transpose(0, 3, 1, 2) - cumj[:, :, None, :].transpose(0, 3, 2, 1)
        # diff[b,h,t,t'] = cum[b,t,h] - cum[b,t',h]; causal (t>=t') => diff<=0.
        # Clamp before exp: the masked (t<t') region has diff>0 and would
        # overflow to inf, and inf*0 = NaN.
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
        l_mat = jnp.exp(jnp.minimum(diff, 0.0)) * tri[None, None]
        # scores[b,t,t'] over state dim: (C_t · B_t')
        cb = jnp.einsum("btn,bun->btu", cj, bj)  # [B,Q,Q]
        # y_diag[b,t,h,d] = sum_u cb[t,u] * L[h,t,u] * dt[u,h] * x[u,h,d]
        w_tu = cb[:, None, :, :] * l_mat  # [B,H,Q,Q]
        w_tu = w_tu * dtj.transpose(0, 2, 1)[:, :, None, :]  # fold dt_u
        y_diag = jnp.einsum("bhtu,buhd->bthd", w_tu, xf)
        # contribution of the carried state: y_off[t] = exp(cum_t) * C_t · h
        decay_in = jnp.exp(cumj)  # [B,Q,H]
        cd = cj[:, :, None, :] * decay_in[:, :, :, None]  # [B,Q,H,N]
        y_off = jnp.einsum("bthn,bhdn->bthd", cd, h_state)
        # state update: h' = exp(sum da) * h + sum_u exp(cum_last - cum_u) dt_u B_u x_u
        total = cumj[:, -1, :]  # [B,H]
        decay_out = jnp.exp(total[:, None, :] - cumj)  # [B,Q,H]
        xw = xf * (decay_out * dtj)[..., None]  # [B,Q,H,hd]
        h_inc = jnp.einsum("bun,buhd->bhdn", bj, xw)
        h_new = jnp.exp(total)[:, :, None, None] * h_state + h_inc
        return h_new, (y_diag + y_off)

    # Remat each chunk: the backward pass recomputes the chunk forward
    # instead of saving O(Q^2) residuals per chunk per layer.
    chunk_step = jax.checkpoint(chunk_step)

    h0 = (
        h_init.astype(jnp.float32)
        if h_init is not None
        else jnp.zeros((bsz, h, hd, n), jnp.float32)
    )
    h0 = constrain(h0, "batch")
    inputs = (
        xc.swapaxes(0, 1),
        dtc.swapaxes(0, 1),
        bc.swapaxes(0, 1),
        cc.swapaxes(0, 1),
        da.swapaxes(0, 1),
        cum.swapaxes(0, 1),
    )
    h_final, ys = scan_util.scan(chunk_step, h0, inputs)
    y = ys.swapaxes(0, 1).reshape(bsz, s_p, h, hd)[:, :s]
    return y.astype(x.dtype), h_final


def ssm_block(
    cfg: ModelConfig, p: dict, x: jnp.ndarray,
) -> jnp.ndarray:
    """Full-sequence SSD block (train/prefill)."""
    from .layers import rms_norm

    bsz, s, _ = x.shape
    di, n, h, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["w_in"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(bsz, s, h, hd)
    b_in = xbc[..., di : di + n]
    c_in = xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["a_log"])  # [H], negative
    y, _hf = ssd_chunked(xs, dt, a, b_in, c_in, cfg.ssm_chunk)
    y = y + xs * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, s, di)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["w_out"]


def ssm_block_with_state(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, state: dict,
) -> tuple[jnp.ndarray, dict]:
    """Prefill variant returning the carry state for subsequent decode."""
    from .layers import rms_norm

    bsz, s, _ = x.shape
    di, n, h, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["w_in"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc_conv[..., :di].reshape(bsz, s, h, hd)
    b_in = xbc_conv[..., di : di + n]
    c_in = xbc_conv[..., di + n :]
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, h_final = ssd_chunked(xs, dt_sp, a, b_in, c_in, cfg.ssm_chunk,
                             h_init=state.get("h"))
    y = y + xs * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = (y.reshape(bsz, s, di) * jax.nn.silu(z))
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    conv_tail = xbc[:, -(cfg.ssm_conv - 1):, :] if s >= cfg.ssm_conv - 1 else jnp.pad(
        xbc, ((0, 0), (cfg.ssm_conv - 1 - s, 0), (0, 0))
    )
    new_state = {"h": h_final, "conv": conv_tail}
    return y @ p["w_out"], new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_d_inner + 2 * cfg.ssm_state),
                          PARAM_DTYPE),
    }


def ssm_decode_step(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, state: dict
) -> tuple[jnp.ndarray, dict]:
    """One-token decode: x [B, 1, D] -> (y [B, 1, D], new state)."""
    from .layers import rms_norm

    bsz = x.shape[0]
    di, n, h, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x[:, 0] @ p["w_in"]  # [B, proj_out]
    z, xbc, dt = _split_proj(cfg, proj)

    # conv window: previous conv-1 inputs + current
    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # [B,conv,C]
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
    )
    xbc_act = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    xs = xbc_act[..., :di].reshape(bsz, h, hd)
    b_in = xbc_act[..., di : di + n].astype(jnp.float32)
    c_in = xbc_act[..., di + n :].astype(jnp.float32)

    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt_sp * a[None, :])  # [B,H]
    h_state = state["h"]  # [B,H,hd,N]
    h_new = decay[:, :, None, None] * h_state + jnp.einsum(
        "bh,bn,bhd->bhdn", dt_sp, b_in, xs.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhdn->bhd", c_in, h_new)  # [B,H,hd]
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None, :]
    new_state = {"h": h_new, "conv": window[:, 1:, :]}
    return out, new_state
