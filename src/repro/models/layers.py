"""Transformer building blocks: RMSNorm, RoPE, flash attention (online
softmax over KV blocks), GQA/MQA with qk-norm / qkv-bias options, MLA.

All functions are pure (params passed explicitly) and written so GSPMD can
partition them: batch on the ``data`` mesh axis, heads / hidden features on
``model``.  Flash attention is a ``lax.scan`` over KV blocks with running
(max, denom, acc) — O(S·blk) memory, which is what makes prefill_32k fit.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain, model_axis_size
from . import scan_util
from .config import ModelConfig

PARAM_DTYPE = jnp.bfloat16
ACT_DTYPE = jnp.bfloat16

DEFAULT_KV_BLOCK = 1_024
DEFAULT_Q_BLOCK = 2_048


# ----------------------------------------------------------------- norms --
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ rope --
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------- flash attention --
def flash_attention(
    q: jnp.ndarray,  # [B, Sq, H, hd]
    k: jnp.ndarray,  # [B, Sk, KVH, hd]  (KVH may divide H: GQA/MQA)
    v: jnp.ndarray,  # [B, Sk, KVH, hd]
    causal_offset: int | None = 0,
    kv_block: int = DEFAULT_KV_BLOCK,
    q_block: int = DEFAULT_Q_BLOCK,
) -> jnp.ndarray:
    """Online-softmax attention, O(Sq·blk) live memory, GQA-aware.

    KV heads are NEVER materialized repeated to H — queries are grouped
    [B,S,KVH,G,hd] and contracted against the raw KV (repeat_kv would
    amplify KV HBM traffic by H/KVH, 8x on the GQA configs).

    ``causal_offset``: query i attends to keys j <= i + offset (offset =
    Sk - Sq for decode/prefix setups).  None disables masking entirely.
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    vd = v.shape[-1]  # MLA has v_head_dim != qk head_dim (96 vs 64)
    # Partial KV repeat up to the tensor-parallel width: grouped attention
    # with kvh < TP < H cannot 16-way-shard the (KVH, G) reshape, so GSPMD
    # replicates the whole attention.  Repeating KV to exactly TP heads
    # (2-4x, not H/KVH=8-16x) restores head sharding at minimal HBM cost
    # (EXPERIMENTS.md §Perf iter 6).
    tp = model_axis_size()
    if tp > 1 and kvh < tp <= h and h % tp == 0 and tp % kvh == 0:
        reps = tp // kvh
        k = jnp.repeat(k, reps, axis=2)
        v = jnp.repeat(v, reps, axis=2)
        kvh = tp
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    if scan_util.unrolling():
        # cost-analysis mode: fewer/bigger tiles so the unrolled HLO stays
        # compilable; FLOPs/bytes are invariant to the blocking.
        kv_block = max(kv_block, sk // 8)
        q_block = max(q_block, sq // 4)
    kv_block = min(kv_block, sk)
    q_block = min(q_block, sq)
    n_kv = -(-sk // kv_block)
    n_q = -(-sq // q_block)
    # pad seq dims to block multiples
    sq_p, sk_p = n_q * q_block, n_kv * kv_block
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))

    kb = kp.reshape(b, n_kv, kv_block, kvh, hd)
    vb = vp.reshape(b, n_kv, kv_block, kvh, vd)

    kb = constrain(kb, "batch", None, None, "model", None)
    vb = constrain(vb, "batch", None, None, "model", None)

    def one_q_block(qi, q_tile):
        # q_tile: [B, q_block, H, hd] -> grouped [B, qb, KVH, G, hd]
        q5 = constrain(q_tile.reshape(b, q_block, kvh, g, hd),
                       "batch", None, "model", None, None)
        q_pos = qi * q_block + jnp.arange(q_block)

        def kv_step(carry, inputs):
            m, l, acc = carry  # [B,KVH,G,qb], ..., [B,KVH,G,qb,hd]
            kj, k_tile, v_tile = inputs  # tiles [B, kv_block, KVH, hd]
            s = jnp.einsum(
                "bqkgd,bekd->bkgqe", q5, k_tile,
                preferred_element_type=jnp.float32,
            ) * scale  # [B,KVH,G,qb,kb]
            k_pos = kj * kv_block + jnp.arange(kv_block)
            mask = k_pos[None, :] < sk  # padding mask
            if causal_offset is not None:
                mask = mask & (
                    k_pos[None, :] <= q_pos[:, None] + causal_offset
                )
            s = jnp.where(mask[None, None, None, :, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqe,bekd->bkgqd", p, v_tile, preferred_element_type=jnp.float32
            )
            return (m_new, l_new, acc_new), None

        # scan carries must start with the right sharding or GSPMD
        # replicates the whole loop body (see act_sharding docstring)
        m0 = constrain(jnp.full((b, kvh, g, q_block), -jnp.inf, jnp.float32), "batch")
        l0 = constrain(jnp.zeros((b, kvh, g, q_block), jnp.float32), "batch")
        a0 = constrain(jnp.zeros((b, kvh, g, q_block, vd), jnp.float32), "batch")
        (m, l, acc), _ = scan_util.scan(
            kv_step, (m0, l0, a0), (jnp.arange(n_kv), kb.swapaxes(0, 1), vb.swapaxes(0, 1))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)  # [B,KVH,G,qb,vd]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_block, h, vd)

    q_tiles = qp.reshape(b, n_q, q_block, h, hd).swapaxes(0, 1)  # [n_q, B, qb, H, hd]
    out_tiles = scan_util.map_(lambda args: one_q_block(*args), (jnp.arange(n_q), q_tiles))
    out = out_tiles.swapaxes(0, 1).reshape(b, sq_p, h, vd)[:, :sq]
    return out.astype(q.dtype)


def repeat_kv(x: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """[B, S, KVH, hd] -> [B, S, H, hd] by repeating each kv head."""
    b, s, kvh, hd = x.shape
    if kvh == num_heads:
        return x
    reps = num_heads // kvh
    return jnp.repeat(x, reps, axis=2)


# --------------------------------------------------------------- GQA core --
def init_attention_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d = cfg.d_model
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    if cfg.attn_type == "mla":
        qr = cfg.q_lora_rank or d
        qhd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        p = {
            "w_dq": jax.random.normal(k1, (d, qr), PARAM_DTYPE) * s,
            "q_norm": jnp.ones(qr, PARAM_DTYPE),
            "w_uq": jax.random.normal(k2, (qr, cfg.num_heads * qhd), PARAM_DTYPE)
            * (1.0 / math.sqrt(qr)),
            "w_dkv": jax.random.normal(
                k3, (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim), PARAM_DTYPE
            )
            * s,
            "kv_norm": jnp.ones(cfg.kv_lora_rank, PARAM_DTYPE),
            "w_ukv": jax.random.normal(
                k4,
                (cfg.kv_lora_rank, cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
                PARAM_DTYPE,
            )
            * (1.0 / math.sqrt(cfg.kv_lora_rank)),
            "w_o": jax.random.normal(k5, (cfg.num_heads * cfg.v_head_dim, d), PARAM_DTYPE)
            * (1.0 / math.sqrt(cfg.num_heads * cfg.v_head_dim)),
        }
        return p
    p = {
        "w_q": jax.random.normal(k1, (d, cfg.q_dim), PARAM_DTYPE) * s,
        "w_k": jax.random.normal(k2, (d, cfg.kv_dim), PARAM_DTYPE) * s,
        "w_v": jax.random.normal(k3, (d, cfg.kv_dim), PARAM_DTYPE) * s,
        "w_o": jax.random.normal(k4, (cfg.q_dim, d), PARAM_DTYPE)
        * (1.0 / math.sqrt(cfg.q_dim)),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros(cfg.q_dim, PARAM_DTYPE)
        p["b_k"] = jnp.zeros(cfg.kv_dim, PARAM_DTYPE)
        p["b_v"] = jnp.zeros(cfg.kv_dim, PARAM_DTYPE)
    if cfg.qk_norm:
        p["q_head_norm"] = jnp.ones(cfg.head_dim, PARAM_DTYPE)
        p["k_head_norm"] = jnp.ones(cfg.head_dim, PARAM_DTYPE)
    return p


def gqa_qkv(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, positions: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Project to (q [B,S,H,hd], k [B,S,KVH,hd], v [B,S,KVH,hd]) with rope."""
    b, s, _ = x.shape
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if cfg.qkv_bias:
        q = q + p["b_q"]
        k = k + p["b_k"]
        v = v + p["b_v"]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_head_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_head_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # Pin attention sharding: heads on `model` when divisible, otherwise
    # fully replicated.  Without this GSPMD shards head_dim and ALL-REDUCES
    # the partial scores — 165 GiB/step on paligemma prefill_32k (§Perf).
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    return q, k, v


def mla_qkv(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, positions: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """MLA projections.  Returns (q [B,S,H,qhd], k [B,S,H,qhd], v [B,S,H,vhd],
    compressed cache payload c [B,S,kv_lora+rope]).

    The compressed c_kv (+ shared rope key) is what a serving cache stores —
    the MLA memory saving.  k/v here are the decompressed views.
    """
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    dkv = x @ p["w_dkv"]  # [B,S,kv_lora+rope]
    c_kv = rms_norm(dkv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., cfg.kv_lora_rank :].reshape(b, s, 1, rope_d)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)

    ukv = (c_kv @ p["w_ukv"]).reshape(b, s, h, nope + vd)
    k_nope, v = ukv[..., :nope], ukv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope_d))], axis=-1)
    # NOTE: no sharding pin here — for MLA (40 heads, not divisible by 16)
    # replicating q/k/v measured WORSE than GSPMD's layout (coll 2.4s->5.7s,
    # §Perf refuted-hypothesis log), and MLA prefill is memory-bound anyway.
    # cache stores the compressed c_kv and the *post-rope* shared key part —
    # exactly what the absorbed decode consumes.
    cache_payload = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)
    return q, k, v, cache_payload


def attention_block(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    kv_block: int = DEFAULT_KV_BLOCK,
) -> jnp.ndarray:
    """Full causal self-attention for train/prefill."""
    if cfg.attn_type == "mla":
        q, k, v, _c = mla_qkv(cfg, p, x, positions)
        out = flash_attention(q, k, v, causal_offset=0, kv_block=kv_block)
        b, s = x.shape[:2]
        return out.reshape(b, s, cfg.num_heads * cfg.v_head_dim) @ p["w_o"]
    q, k, v = gqa_qkv(cfg, p, x, positions)
    out = flash_attention(q, k, v, causal_offset=0, kv_block=kv_block)
    b, s = x.shape[:2]
    return out.reshape(b, s, cfg.q_dim) @ p["w_o"]


# ------------------------------------------------------------------- MLP --
def init_mlp_params(d: int, f: int, key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, f), PARAM_DTYPE) / math.sqrt(d),
        "w_up": jax.random.normal(k2, (d, f), PARAM_DTYPE) / math.sqrt(d),
        "w_down": jax.random.normal(k3, (f, d), PARAM_DTYPE) / math.sqrt(f),
    }


def mlp_block(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
