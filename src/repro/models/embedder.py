"""Embedding extraction: the bridge between the model zoo and Manu.

Any decoder config doubles as an embedding model (the paper's §7
"embedding generation toolbox"): mean-pooled final hidden states, L2
normalized — the standard decoder-as-embedder recipe.  ``Embedder`` wraps
a jitted batch-embed function plus a request micro-batcher for serving.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .config import ModelConfig


def embed_tokens(cfg: ModelConfig, params, tokens: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean-pooled, L2-normalized embeddings [B, d_model] (f32)."""
    h = M.hidden_states(cfg, params, tokens, remat=False)
    h = h.astype(jnp.float32)
    if mask is None:
        pooled = h.mean(axis=1)
    else:
        w = mask.astype(jnp.float32)[..., None]
        pooled = (h * w).sum(axis=1) / jnp.maximum(w.sum(axis=1), 1.0)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)


class Embedder:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 32):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self._fn = jax.jit(lambda p, t, m: embed_tokens(cfg, p, t, m))

    @property
    def dim(self) -> int:
        return self.cfg.d_model

    def embed(self, token_batches: np.ndarray, mask: np.ndarray | None = None) -> np.ndarray:
        """tokens [N, S] -> embeddings [N, d] (internally micro-batched)."""
        n = len(token_batches)
        out = []
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            t = jnp.asarray(token_batches[lo:hi], jnp.int32)
            m = None if mask is None else jnp.asarray(mask[lo:hi])
            if m is None:
                m = jnp.ones(t.shape, jnp.int32)
            out.append(np.asarray(self._fn(self.params, t, m)))
        return np.concatenate(out) if out else np.empty((0, self.dim), np.float32)
