"""Mixture-of-Experts FFN with capacity-factor scatter/gather dispatch.

Token-choice top-k routing (Qwen3-MoE, DeepSeekMoE).  Dispatch builds a
[B, E, C, D] buffer per batch row via scatter-add; expert matmuls are a
batched einsum with the expert axis sharded over the ``model`` mesh axis;
combine gathers results back and weighs by the (optionally renormalized)
gates.  Tokens over capacity are dropped (standard capacity-factor
semantics) — the capacity factor bounds the buffer so the whole block stays
static-shaped for XLA/GSPMD.

DeepSeek's *shared experts* are dense MLPs added unconditionally.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain
from ..distributed.compat import shard_map
from .config import ModelConfig
from .layers import PARAM_DTYPE


def moe_capacity(cfg: ModelConfig, seq_len: int) -> int:
    cap = int(
        math.ceil(seq_len * cfg.moe_top_k / cfg.moe_num_experts * cfg.moe_capacity_factor)
    )
    return max(8, -(-cap // 8) * 8)  # round up to 8 for lane alignment


def init_moe_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.moe_num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) / math.sqrt(d),
        "w_gate": jax.random.normal(k2, (e, d, f), PARAM_DTYPE) / math.sqrt(d),
        "w_up": jax.random.normal(k3, (e, d, f), PARAM_DTYPE) / math.sqrt(d),
        "w_down": jax.random.normal(k4, (e, f, d), PARAM_DTYPE) / math.sqrt(f),
    }
    if cfg.moe_num_shared:
        fs = f * cfg.moe_num_shared
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": jax.random.normal(ks[0], (d, fs), PARAM_DTYPE) / math.sqrt(d),
            "w_up": jax.random.normal(ks[1], (d, fs), PARAM_DTYPE) / math.sqrt(d),
            "w_down": jax.random.normal(ks[2], (fs, d), PARAM_DTYPE) / math.sqrt(fs),
        }
    return p


def moe_block(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Dispatch: expert-parallel shard_map when a mesh policy with a
    ``model`` axis is installed and experts divide it; otherwise the dense
    scatter formulation (single-host tests, and the GSPMD baseline the perf
    log compares against — see EXPERIMENTS.md §Perf)."""
    from ..distributed import act_sharding

    pol = act_sharding._policy()
    if pol is not None and pol.get("moe_impl", "shard_map") == "shard_map":
        mesh = pol["mesh"]
        m = mesh.shape.get("model", 1)
        if m > 1 and cfg.moe_num_experts % m == 0:
            return _moe_block_shard_map(cfg, p, x, pol)
    return _moe_block_dense(cfg, p, x)


def _moe_block_dense(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    c = moe_capacity(cfg, s)

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # [B,S,k]
    if cfg.moe_norm_topk:
        gate = gate / jnp.maximum(gate.sum(axis=-1, keepdims=True), 1e-9)

    sk = s * k
    e_flat = idx.reshape(b, sk)  # expert of each slot
    # position of each slot within its expert's buffer (per batch row)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # [B, sk, E]
    pos_all = jnp.cumsum(onehot, axis=1) - 1  # [B, sk, E]
    pos = jnp.take_along_axis(pos_all, e_flat[..., None], axis=-1)[..., 0]  # [B, sk]
    in_cap = pos < c

    tok_of_slot = jnp.arange(sk) // k  # [sk]
    src = jnp.take(x, tok_of_slot, axis=1)  # [B, sk, D]
    src = src * in_cap[..., None].astype(x.dtype)
    pos_c = jnp.where(in_cap, pos, c - 1)

    batch_idx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, sk))
    buffer = constrain(jnp.zeros((b, e, c, d), x.dtype), "batch", "model")
    buffer = buffer.at[batch_idx, e_flat, pos_c].add(src, mode="drop")
    buffer = constrain(buffer, "batch", "model")

    # Expert MLPs: expert axis is a batched matmul dim (sharded on `model`).
    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buffer, p["w_gate"])
    ) * jnp.einsum("becd,edf->becf", buffer, p["w_up"])
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])  # [B,E,C,D]

    # Combine: gather each slot's result, weight by gate, sum over k.
    gathered = out_buf[batch_idx, e_flat, pos_c]  # [B, sk, D]
    gathered = gathered * (gate.reshape(b, sk, 1) * in_cap[..., None]).astype(x.dtype)
    out = gathered.reshape(b, s, k, d).sum(axis=2)

    if cfg.moe_num_shared:
        sp = p["shared"]
        out = out + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    return out


# ---------------------------------------------------------------------------
# Expert-parallel shard_map implementation
# ---------------------------------------------------------------------------
#
# Experts are sharded over the ``model`` mesh axis; activations are sharded
# over ``data`` and REPLICATED over ``model``.  Each model-rank dispatches
# tokens to its local experts with a purely local scatter (zero dispatch
# collectives — the tokens are already present), computes its expert MLPs,
# scatters results back, and a single psum over ``model`` combines partial
# outputs.  Collective bytes per MoE layer = one [B_local, S, D] psum —
# the same order as a Megatron-style TP FFN, vs. the GSPMD scatter
# formulation's per-layer buffer all-gathers (measured 600x worse in the
# dry-run; see EXPERIMENTS.md §Perf).


def _moe_local_compute(cfg: ModelConfig, x_l, router, w_gate, w_up, w_down, e0):
    """Token dispatch + expert MLPs for the local expert range [e0, e0+E_l)."""
    b, s, d = x_l.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    e_l = w_gate.shape[0]
    c = moe_capacity(cfg, s)

    logits = (x_l.astype(jnp.float32) @ router)  # [B,S,E] (replicated compute)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # [B,S,k]
    if cfg.moe_norm_topk:
        gate = gate / jnp.maximum(gate.sum(axis=-1, keepdims=True), 1e-9)

    sk = s * k
    e_flat = idx.reshape(b, sk)
    # capacity position must match the global (dense) semantics: rank within
    # the expert across the whole row, computed over ALL experts
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos_all = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos_all, e_flat[..., None], axis=-1)[..., 0]
    in_cap = pos < c

    local = (e_flat >= e0) & (e_flat < e0 + e_l) & in_cap
    e_local = jnp.clip(e_flat - e0, 0, e_l - 1)
    pos_c = jnp.where(local, pos, c - 1)

    tok_of_slot = jnp.arange(sk) // k
    src = jnp.take(x_l, tok_of_slot, axis=1) * local[..., None].astype(x_l.dtype)
    batch_idx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, sk))
    buffer = jnp.zeros((b, e_l, c, d), x_l.dtype)
    buffer = buffer.at[batch_idx, e_local, pos_c].add(src, mode="drop")

    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buffer, w_gate)
    ) * jnp.einsum("becd,edf->becf", buffer, w_up)
    out_buf = jnp.einsum("becf,efd->becd", h, w_down)

    gathered = out_buf[batch_idx, e_local, pos_c]
    gathered = gathered * (gate.reshape(b, sk, 1) * local[..., None]).astype(x_l.dtype)
    return gathered.reshape(b, s, k, d).sum(axis=2)


def _moe_block_shard_map(cfg: ModelConfig, p: dict, x: jnp.ndarray, pol) -> jnp.ndarray:
    from jax.sharding import PartitionSpec as P

    mesh = pol["mesh"]
    batch_axes = pol.get("batch")
    b_axis = None
    if batch_axes and x.shape[0] % _mesh_axes_size(mesh, batch_axes) == 0:
        b_axis = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    def local(x_l, router, w_gate, w_up, w_down):
        e_l = w_gate.shape[0]
        e0 = jax.lax.axis_index("model") * e_l
        out_partial = _moe_local_compute(cfg, x_l, router, w_gate, w_up, w_down, e0)
        return jax.lax.psum(out_partial, "model")

    out = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(b_axis, None, None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=P(b_axis, None, None),
        check=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.moe_num_shared:
        sp = p["shared"]
        out = out + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    return out


def _mesh_axes_size(mesh, axes) -> int:
    total = 1
    for a in axes:
        total *= mesh.shape.get(a, 1)
    return total
