"""The assembled decoder-only model covering all 10 assigned architectures.

One generic ``Transformer`` parameterized by ``ModelConfig``:

* layer kinds come from ``cfg.layer_pattern`` (attn / ssm), repeated over
  depth; MoE FFNs appear on layers selected by ``moe_every``;
* layers are **stacked and scanned**: parameters of equal-structure layers
  are stacked along a leading ``periods`` axis and the forward pass is a
  ``jax.lax.scan`` over that axis (HLO size O(1) in depth — essential for
  compiling 64-layer configs in the dry-run).  Heterogeneous stacks (Jamba)
  scan over *periods* of the pattern with the slots unrolled inside;
* three entry points per the assigned shapes: ``forward`` (train loss),
  ``prefill`` (logits + cache), ``decode_step`` (1 token against a cache).

Caches: GQA stores (k, v) [B, S, KVH, hd]; MLA stores the *compressed*
c_kv ‖ k_rope payload [B, S, r+rope] and uses the absorbed-matmul decode
(the DeepSeek inference trick); SSM stores the O(1) recurrent state.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..distributed.act_sharding import constrain
from .config import ModelConfig
from .layers import (
    ACT_DTYPE,
    PARAM_DTYPE,
    apply_rope,
    attention_block,
    gqa_qkv,
    init_attention_params,
    init_mlp_params,
    mla_qkv,
    mlp_block,
    repeat_kv,
    rms_norm,
)
from . import scan_util
from .moe import init_moe_params, moe_block
from .ssm import (
    init_ssm_params,
    init_ssm_state,
    ssm_block,
    ssm_block_with_state,
    ssm_decode_step,
)

Params = dict[str, Any]


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def effective_pattern(cfg: ModelConfig) -> list[tuple[str, bool]]:
    """Per-slot (kind, is_moe) over one effective period."""
    pat = cfg.pattern()
    period = _lcm(len(pat), cfg.moe_every if cfg.moe_num_experts else 1)
    if cfg.num_layers % period != 0:
        raise ValueError(
            f"{cfg.name}: layers {cfg.num_layers} not divisible by period {period}"
        )
    return [(pat[s % len(pat)], cfg.is_moe_layer(s)) for s in range(period)]


def num_periods(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(effective_pattern(cfg))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, kind: str, is_moe: bool, key: jax.Array) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "ln_attn": jnp.ones(cfg.d_model, PARAM_DTYPE),
        "ln_mlp": jnp.ones(cfg.d_model, PARAM_DTYPE),
    }
    if kind == "attn":
        p["attn"] = init_attention_params(cfg, k1)
    else:
        p["ssm"] = init_ssm_params(cfg, k2)
    if is_moe:
        p["moe"] = init_moe_params(cfg, k3)
    elif cfg.d_ff > 0:
        p["mlp"] = init_mlp_params(cfg.d_model, cfg.d_ff, k4)
    else:
        del p["ln_mlp"]  # pure mamba blocks have no MLP sublayer
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 4)
    pattern = effective_pattern(cfg)
    periods = num_periods(cfg)

    def init_slot(s: int, kind: str, is_moe: bool) -> Params:
        slot_keys = jax.random.split(jax.random.fold_in(keys[0], s), periods)
        stacked = [
            _init_layer(cfg, kind, is_moe, slot_keys[pi]) for pi in range(periods)
        ]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacked)

    params: Params = {
        "embed": jax.random.normal(keys[1], (cfg.vocab_size, cfg.d_model), PARAM_DTYPE)
        * 0.02,
        "ln_final": jnp.ones(cfg.d_model, PARAM_DTYPE),
        "layers": {
            f"slot{s}": init_slot(s, kind, is_moe)
            for s, (kind, is_moe) in enumerate(pattern)
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[2], (cfg.d_model, cfg.vocab_size), PARAM_DTYPE) * 0.02
        )
    if cfg.frontend == "vlm_stub":
        # projection applied to precomputed patch embeddings (SigLIP stub)
        params["vision_proj"] = (
            jax.random.normal(keys[3], (cfg.d_model, cfg.d_model), PARAM_DTYPE)
            / math.sqrt(cfg.d_model)
        )
    return params


def params_shape(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


# ---------------------------------------------------------------------------
# Forward (train) and prefill
# ---------------------------------------------------------------------------


def _layer_forward(
    cfg: ModelConfig, kind: str, is_moe: bool, p: Params, x: jnp.ndarray,
    positions: jnp.ndarray,
) -> jnp.ndarray:
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    if kind == "attn":
        x = x + attention_block(cfg, p["attn"], h, positions)
    else:
        x = x + ssm_block(cfg, p["ssm"], h)
    if is_moe:
        h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + moe_block(cfg, p["moe"], h)
    elif cfg.d_ff > 0:
        h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + mlp_block(p["mlp"], h)
    return x


def embed_inputs(
    cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
    prefix_embeds: jnp.ndarray | None = None,
) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0).astype(ACT_DTYPE)
    if cfg.frontend == "vlm_stub":
        if prefix_embeds is None:
            raise ValueError(f"{cfg.name} needs prefix patch embeddings")
        pe = prefix_embeds.astype(ACT_DTYPE) @ params["vision_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    return constrain(x, "batch")


def hidden_states(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,
    prefix_embeds: jnp.ndarray | None = None,
    remat: bool = True,
) -> jnp.ndarray:
    """Final-norm hidden states [B, S_total, D] (no LM head)."""
    x = embed_inputs(cfg, params, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    pattern = effective_pattern(cfg)

    def period_body(x, period_params):
        x = constrain(x, "batch")
        for si, (kind, is_moe) in enumerate(pattern):
            x = _layer_forward(cfg, kind, is_moe, period_params[f"slot{si}"], x, positions)
        return constrain(x, "batch"), None

    body = jax.checkpoint(period_body) if remat else period_body
    x, _ = scan_util.scan(body, x, params["layers"])
    return rms_norm(x, params["ln_final"], cfg.norm_eps)


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,  # [B, S] int32
    prefix_embeds: jnp.ndarray | None = None,
    remat: bool = True,
) -> jnp.ndarray:
    """Causal LM logits [B, S_total, V]."""
    x = hidden_states(cfg, params, tokens, prefix_embeds, remat=remat)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum(
        "bsd,dv->bsv", x, head, preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=ACT_DTYPE) -> Params:
    """Zeroed decode cache for every slot, stacked over periods."""
    periods = num_periods(cfg)
    cache: Params = {"length": jnp.zeros((), jnp.int32)}
    for s, (kind, _moe) in enumerate(effective_pattern(cfg)):
        if kind == "attn":
            if cfg.attn_type == "mla":
                payload = cfg.kv_lora_rank + cfg.qk_rope_head_dim
                cache[f"slot{s}"] = {
                    "c": jnp.zeros((periods, batch, max_seq, payload), dtype)
                }
            else:
                shp = (periods, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
                cache[f"slot{s}"] = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
        else:
            st = init_ssm_state(cfg, batch)
            cache[f"slot{s}"] = {
                "h": jnp.zeros((periods,) + st["h"].shape, jnp.float32),
                "conv": jnp.zeros((periods,) + st["conv"].shape, PARAM_DTYPE),
            }
    return cache


def cache_shape(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Prefill: logits for all positions + populated cache
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,  # [B, S]
    cache: Params,
    prefix_embeds: jnp.ndarray | None = None,
    remat: bool = True,
    last_only: bool = False,
    cache_mode: str = "carry",
) -> tuple[jnp.ndarray, Params]:
    x = embed_inputs(cfg, params, tokens, prefix_embeds)
    b, s, _ = x.shape
    max_seq = _cache_max_seq(cfg, cache)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    pattern = effective_pattern(cfg)

    def period_body(x, inputs):
        period_params, period_cache = inputs
        x = constrain(x, "batch")
        new_cache = {}
        for si, (kind, is_moe) in enumerate(pattern):
            p = period_params[f"slot{si}"]
            h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
            if kind == "attn":
                attn_out, slot_cache = _attn_prefill(
                    cfg, p["attn"], h, positions, period_cache[f"slot{si}"]
                )
            else:
                # prefill starts from a fresh SSM state ({} -> zero init)
                attn_out, slot_cache = ssm_block_with_state(cfg, p["ssm"], h, {})
            x = x + attn_out
            if is_moe or cfg.d_ff > 0:
                h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
                x = x + (moe_block(cfg, p["moe"], h) if is_moe else mlp_block(p["mlp"], h))
            new_cache[f"slot{si}"] = slot_cache
        return x, new_cache

    layer_caches_in = {k: v for k, v in cache.items() if k.startswith("slot")}
    if cache_mode == "carry":
        # Thread the stacked cache as scan CARRY with per-period indexed
        # updates: the while-loop carry aliases in place and KEEPS the
        # cache's input sharding.  The ys formulation lets GSPMD defer the
        # output reshard and keep multiple UNSHARDED f32 cache copies live
        # inside the loop (observed +15 GiB/dev on qwen3 prefill, §Perf).
        periods = num_periods(cfg)

        def _carry_body(carry, inputs):
            x, cache_all = carry
            period_params, idx = inputs
            period_cache = jax.tree_util.tree_map(
                lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
                cache_all,
            )
            x, new_cache = period_body(x, (period_params, period_cache))
            cache_all = jax.tree_util.tree_map(
                lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                    c, nc.astype(c.dtype), idx, 0
                ),
                cache_all, new_cache,
            )
            return (x, cache_all), None

        body = jax.checkpoint(_carry_body) if remat else _carry_body
        (x, new_caches), _ = scan_util.scan(
            body, (x, layer_caches_in), (params["layers"], jnp.arange(periods))
        )
    else:
        body = jax.checkpoint(period_body, static_argnums=()) if remat else period_body
        x, new_caches = scan_util.scan(body, x, (params["layers"], layer_caches_in))
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    if last_only:
        # serving only needs the next-token distribution: never materialize
        # the full [B, S, V] logits (67 GiB at 257k vocab x 32k seq).
        x = x[:, -1:, :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)
    out_cache = dict(new_caches)
    out_cache["length"] = jnp.asarray(s, jnp.int32)
    return logits, out_cache


def _attn_prefill(cfg, p, h, positions, slot_cache):
    """Attention + cache fill (writes into the provided cache buffers)."""
    from .layers import flash_attention

    b, s, _ = h.shape
    if cfg.attn_type == "mla":
        q, k, v, payload = mla_qkv(cfg, p, h, positions)
        out = flash_attention(q, k, v, causal_offset=0)
        attn_out = out.reshape(b, s, cfg.num_heads * cfg.v_head_dim) @ p["w_o"]
        c = jax.lax.dynamic_update_slice(
            slot_cache["c"], payload.astype(slot_cache["c"].dtype), (0, 0, 0)
        )
        return attn_out, {"c": c}
    q, k, v = gqa_qkv(cfg, p, h, positions)
    out = flash_attention(q, k, v, causal_offset=0)
    attn_out = out.reshape(b, s, cfg.q_dim) @ p["w_o"]
    kc = jax.lax.dynamic_update_slice(
        slot_cache["k"], k.astype(slot_cache["k"].dtype), (0, 0, 0, 0)
    )
    vc = jax.lax.dynamic_update_slice(
        slot_cache["v"], v.astype(slot_cache["v"].dtype), (0, 0, 0, 0)
    )
    return attn_out, {"k": kc, "v": vc}


def _cache_max_seq(cfg: ModelConfig, cache: Params) -> int:
    for s, (kind, _m) in enumerate(effective_pattern(cfg)):
        if kind == "attn":
            slot = cache[f"slot{s}"]
            arr = slot["c"] if cfg.attn_type == "mla" else slot["k"]
            return arr.shape[2]
    return 0


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

# Decode attention implementations are injectable: the distributed layer
# provides shard_map flash-decode over sequence-sharded caches
# (repro.distributed.decode_attn); the dense defaults below are the
# single-host reference.  Signatures:
#   gqa: (q, k_new, v_new, k_cache, v_cache, pos) -> (out, k_cache, v_cache)
#   mla: (q_c, q_rope, payload, c_cache, pos, r, scale_dim) -> (ctx, c_cache)
DecodeAttnFn = Callable[..., tuple]


def dense_gqa_decode_attn(q, k_new, v_new, k_cache, v_cache, pos):
    b, _one, h, hd = q.shape
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0)
    )
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    q5 = q.reshape(b, 1, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", q5, k_cache.astype(jnp.float32)
    ) / math.sqrt(hd)
    mask = jnp.arange(s)[None, None, None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype), k_cache, v_cache


def dense_mla_decode_attn(q_c, q_rope, payload, c_cache, pos, r, scale_dim):
    c_cache = jax.lax.dynamic_update_slice(
        c_cache, payload.astype(c_cache.dtype), (0, pos, 0)
    )
    s = c_cache.shape[1]
    c_kv = c_cache[..., :r].astype(jnp.float32)
    k_rope = c_cache[..., r:].astype(jnp.float32)
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_c.astype(jnp.float32), c_kv)
        + jnp.einsum("bqhn,bsn->bhqs", q_rope.astype(jnp.float32), k_rope)
    ) / math.sqrt(scale_dim)
    mask = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, c_kv)
    return ctx.astype(q_c.dtype), c_cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Params,
    tokens: jnp.ndarray,  # [B, 1] the newest token ids
    gqa_attn_impl: DecodeAttnFn = dense_gqa_decode_attn,
    mla_attn_impl: DecodeAttnFn = dense_mla_decode_attn,
    cache_mode: str = "carry",
) -> tuple[jnp.ndarray, Params]:
    """One decode step: returns (logits [B,1,V], updated cache).

    ``cache_mode="carry"`` threads the stacked cache through the period scan
    as CARRY with per-period dynamic-index updates — the while-loop carry
    aliases in place, so the cache exists once.  ``"ys"`` (the naive
    formulation) re-emits each period's cache as stacked scan outputs, which
    double-buffers the entire multi-GiB cache (input xs + output ys live
    simultaneously) — kept as the §Perf baseline.
    """
    x = jnp.take(params["embed"], tokens, axis=0).astype(ACT_DTYPE)
    b = x.shape[0]
    pos = cache["length"]  # scalar: index where the new token is written
    positions = jnp.broadcast_to(pos, (b, 1))
    pattern = effective_pattern(cfg)
    layer_caches_in = {k: v for k, v in cache.items() if k.startswith("slot")}

    def slots_forward(x, period_params, period_cache):
        new_cache = {}
        for si, (kind, is_moe) in enumerate(pattern):
            p = period_params[f"slot{si}"]
            sc = period_cache[f"slot{si}"]
            h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
            if kind == "attn":
                attn_out, sc = _attn_decode(cfg, p["attn"], h, sc, pos, positions,
                                            gqa_attn_impl, mla_attn_impl)
            else:
                attn_out, sc = ssm_decode_step(cfg, p["ssm"], h, sc)
            x = x + attn_out
            if is_moe or cfg.d_ff > 0:
                h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
                x = x + (moe_block(cfg, p["moe"], h) if is_moe else mlp_block(p["mlp"], h))
            new_cache[f"slot{si}"] = sc
        return x, new_cache

    if cache_mode == "ys":
        def period_body(x, inputs):
            period_params, period_cache = inputs
            return slots_forward(x, period_params, period_cache)

        x, new_caches = scan_util.scan(period_body, x, (params["layers"], layer_caches_in))
    else:
        periods = num_periods(cfg)

        def period_body(carry, inputs):
            x, cache_all = carry
            period_params, idx = inputs
            period_cache = jax.tree_util.tree_map(
                lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
                cache_all,
            )
            x, new_cache = slots_forward(x, period_params, period_cache)
            cache_all = jax.tree_util.tree_map(
                lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                    c, nc.astype(c.dtype), idx, 0
                ),
                cache_all, new_cache,
            )
            return (x, cache_all), None

        (x, new_caches), _ = scan_util.scan(
            period_body, (x, layer_caches_in),
            (params["layers"], jnp.arange(periods)),
        )
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)
    out_cache = dict(new_caches)
    out_cache["length"] = pos + 1
    return logits, out_cache


def _attn_decode(cfg, p, h, slot_cache, pos, positions, gqa_attn_impl,
                 mla_attn_impl):
    b = h.shape[0]
    if cfg.attn_type == "mla":
        nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
        r = cfg.kv_lora_rank
        vd = cfg.v_head_dim
        hn = cfg.num_heads
        cq = rms_norm(h @ p["w_dq"], p["q_norm"], cfg.norm_eps)
        q = (cq @ p["w_uq"]).reshape(b, 1, hn, nope + rope_d)
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        dkv = h @ p["w_dkv"]  # [B,1,r+rope]
        c_kv = rms_norm(dkv[..., :r], p["kv_norm"], cfg.norm_eps)
        k_rope = apply_rope(
            dkv[..., r:].reshape(b, 1, 1, rope_d), positions, cfg.rope_theta
        ).reshape(b, 1, rope_d)
        payload = jnp.concatenate([c_kv, k_rope], axis=-1)
        # Absorbed query/value projections (DeepSeek inference trick):
        # scoring and reading happen entirely in the compressed space.
        w_ukv = p["w_ukv"].reshape(r, hn, nope + vd)
        w_uk, w_uv = w_ukv[..., :nope], w_ukv[..., nope:]
        q_c = jnp.einsum(
            "bqhn,rhn->bqhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32)
        ).astype(h.dtype)
        ctx, c_cache = mla_attn_impl(
            q_c, q_rope, payload, slot_cache["c"], pos, r, nope + rope_d
        )
        out = jnp.einsum(
            "bqhr,rhv->bqhv", ctx.astype(jnp.float32), w_uv.astype(jnp.float32)
        ).astype(h.dtype)
        attn_out = out.reshape(b, 1, hn * vd) @ p["w_o"]
        return attn_out, {"c": c_cache}
    q, k, v = gqa_qkv(cfg, p, h, positions)
    out, kc, vc = gqa_attn_impl(q, k, v, slot_cache["k"], slot_cache["v"], pos)
    attn_out = out.reshape(b, 1, cfg.q_dim) @ p["w_o"]
    return attn_out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,  # [B, S]
    labels: jnp.ndarray,  # [B, S] next-token targets; -100 = ignore
    prefix_embeds: jnp.ndarray | None = None,
    remat: bool = True,
    seq_chunk: int = 1_024,
) -> jnp.ndarray:
    """Sequence-chunked cross entropy.

    The [B, S, V] logits tensor is never materialized: the head matmul +
    log-softmax run per sequence chunk under remat, so peak memory is
    [B, seq_chunk, V] — at 151k vocab and 4k seq that is the difference
    between ~50 GiB and ~1.5 GiB per device.
    """
    x = hidden_states(cfg, params, tokens, prefix_embeds, remat=remat)
    if cfg.frontend == "vlm_stub" and prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1] :, :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    b, s, d = x.shape
    chunk = min(seq_chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    nchunks = (s + pad) // chunk
    xc = x.reshape(b, nchunks, chunk, d).swapaxes(0, 1)  # [nc, B, C, D]
    lc = labels.reshape(b, nchunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_nll(carry, inputs):
        x_c, l_c = inputs
        logits = jnp.einsum(
            "bcd,dv->bcv", x_c, head, preferred_element_type=jnp.float32
        )
        valid = l_c >= 0
        safe = jnp.where(valid, l_c, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + (nll * valid).sum(), cnt + valid.sum()), None

    (total, count), _ = scan_util.scan(
        chunk_nll,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, lc),
    )
    return total / jnp.maximum(count, 1)
