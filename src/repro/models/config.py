"""Model configuration shared by the embedding-model zoo.

One frozen dataclass describes every assigned architecture: dense GQA/MQA
transformers, MLA, MoE, pure-SSM (Mamba2/SSD), hybrids (Jamba), and the
VLM/audio stub-frontend variants.  ``layer_pattern`` gives the repeating
per-layer kind sequence; ``moe_every`` marks which layers carry MoE FFNs.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention options
    attn_type: str = "gqa"  # gqa | mla
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0

    # MLA (multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_num_shared: int = 0
    moe_every: int = 1  # layer l has MoE FFN iff (l % moe_every) == moe_every-1
    moe_capacity_factor: float = 1.25
    moe_norm_topk: bool = True

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # layer pattern, repeated to num_layers; None => all "attn" (or all "ssm"
    # for family == "ssm")
    layer_pattern: tuple[str, ...] | None = None

    # modality frontend stubs
    frontend: str | None = None  # "vlm_stub" | "audio_stub"
    num_prefix_embeddings: int = 0  # e.g. 256 image patches

    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # ---------------------------------------------------------- derived ----
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def pattern(self) -> tuple[str, ...]:
        if self.layer_pattern is not None:
            if self.num_layers % len(self.layer_pattern) != 0:
                raise ValueError("num_layers must be a multiple of the pattern length")
            return self.layer_pattern
        return ("ssm",) if self.family == "ssm" else ("attn",)

    def layer_kinds(self) -> list[str]:
        pat = self.pattern()
        return [pat[l % len(pat)] for l in range(self.num_layers)]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe_num_experts == 0:
            return False
        return (layer_idx % self.moe_every) == (self.moe_every - 1)

    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no dense O(S^2)-per-token decode state."""
        kinds = set(self.layer_kinds())
        return kinds == {"ssm"} or "ssm" in kinds  # pure SSM or hybrid

    def num_params(self) -> int:
        """Analytic parameter count (for 6ND model-FLOP accounting)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for l, kind in enumerate(self.layer_kinds()):
            total += 2 * d  # norms
            if kind == "attn":
                if self.attn_type == "mla":
                    qr = self.q_lora_rank or d
                    total += d * qr + qr * self.num_heads * (
                        self.qk_nope_head_dim + self.qk_rope_head_dim
                    )
                    total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    total += self.kv_lora_rank * self.num_heads * (
                        self.qk_nope_head_dim + self.v_head_dim
                    )
                    total += self.num_heads * self.v_head_dim * d
                else:
                    total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                    if self.qkv_bias:
                        total += self.q_dim + 2 * self.kv_dim
            else:  # ssm
                di, st = self.ssm_d_inner, self.ssm_state
                h = self.ssm_heads
                total += d * (2 * di + 2 * st + h)  # in_proj (z, x, B, C, dt)
                total += self.ssm_conv * (di + 2 * st)
                total += 2 * h  # A_log, D
                total += di * d  # out_proj
            if self.is_moe_layer(l):
                e, f = self.moe_num_experts, self.moe_d_ff
                total += d * e  # router
                total += e * 3 * d * f
                total += self.moe_num_shared * 3 * d * f
            else:
                total += 3 * d * self.d_ff
        return total

    def active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe_num_experts == 0:
            return self.num_params()
        d = self.d_model
        total = self.num_params()
        for l in range(self.num_layers):
            if self.is_moe_layer(l):
                e, f, k = self.moe_num_experts, self.moe_d_ff, self.moe_top_k
                total -= (e - k) * 3 * d * f
        return total

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        pat = self.pattern()
        small_layers = max(len(pat), 2 if len(pat) == 1 else len(pat))
        defaults = dict(
            num_layers=small_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            qk_nope_head_dim=8 if self.qk_nope_head_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            moe_num_experts=4 if self.moe_num_experts else 0,
            moe_top_k=min(2, self.moe_top_k) if self.moe_top_k else 0,
            moe_d_ff=32 if self.moe_d_ff else 0,
            moe_num_shared=min(1, self.moe_num_shared),
            # generous capacity so smoke tests see no token drops (the full
            # configs keep the faithful 1.25 factor)
            moe_capacity_factor=4.0 if self.moe_num_experts else self.moe_capacity_factor,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 256,
            num_prefix_embeddings=8 if self.num_prefix_embeddings else 0,
        )
        defaults.update(overrides)
        return replace(self, **defaults)


@dataclass(frozen=True)
class ShapeConfig:
    """One (workload shape) cell: what to lower in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
