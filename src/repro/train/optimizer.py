"""AdamW + global-norm gradient clipping, pure jax (no optax dependency).

Optimizer moments are f32 and shard exactly like their parameters (ZeRO-3:
the param specs carry the FSDP axis, so m/v inherit it for free).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params: Params) -> Params:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def opt_state_shape(params_shape: Params) -> Params:
    return jax.eval_shape(init_opt_state, params_shape)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step.astype(jnp.float32) + 1.0) / cfg.warmup_steps)
    return cfg.lr * warm


def adamw_update(
    cfg: AdamWConfig, params: Params, grads: Params, opt_state: Params
) -> tuple[Params, Params]:
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}
