"""Step-atomic checkpoint/restart to the object store.

Training state (params + optimizer moments + step) serializes to flat
``ckpt/<name>/<step>/<leaf-path>`` objects plus a manifest written LAST —
a partially written checkpoint is never visible (the manifest is the
commit record), which is what makes preemption/node-failure recovery safe.
``restore_latest`` resumes from the newest committed step.
"""

from __future__ import annotations

import io
import json
from typing import Any

import jax
import numpy as np

from ..core.object_store import ObjectStore

Params = Any


def _flatten(tree: Params) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(
    store: ObjectStore, name: str, step: int, params: Params, opt_state: Params,
    extra: dict | None = None,
) -> str:
    prefix = f"ckpt/{name}/{step:010d}"
    leaves: dict[str, dict] = {"params": {}, "opt": {}}
    for group, tree in (("params", params), ("opt", opt_state)):
        for key, arr in _flatten(tree).items():
            obj_key = f"{prefix}/{group}/{key}"
            real_dtype = str(arr.dtype)
            if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                                 np.uint8, np.uint32, np.bool_):
                # np.save cannot round-trip ml_dtypes (bfloat16): store as
                # f32 — a lossless widening for bf16 — and cast on restore.
                arr = arr.astype(np.float32)
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            store.put(obj_key, buf.getvalue())
            leaves[group][key] = {"key": obj_key, "dtype": real_dtype,
                                  "shape": list(arr.shape)}
    manifest = {
        "name": name,
        "step": step,
        "leaves": leaves,
        "extra": extra or {},
    }
    # the manifest is the atomic commit record — written last
    store.put(f"{prefix}/MANIFEST", json.dumps(manifest).encode())
    return prefix


def committed_steps(store: ObjectStore, name: str) -> list[int]:
    steps = []
    for meta in store.list(f"ckpt/{name}/"):
        parts = meta.key.split("/")
        if parts[-1] == "MANIFEST":
            steps.append(int(parts[2]))
    return sorted(steps)


def restore_checkpoint(
    store: ObjectStore, name: str, step: int, params_like: Params, opt_like: Params,
) -> tuple[Params, Params, dict]:
    prefix = f"ckpt/{name}/{step:010d}"
    manifest = json.loads(store.get(f"{prefix}/MANIFEST").decode())

    def load_tree(group: str, like: Params) -> Params:
        flat_info = manifest["leaves"][group]
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
            arr = np.load(io.BytesIO(store.get(flat_info[key]["key"])), allow_pickle=False)
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return load_tree("params", params_like), load_tree("opt", opt_like), manifest["extra"]


def restore_latest(
    store: ObjectStore, name: str, params_like: Params, opt_like: Params,
) -> tuple[int, Params, Params, dict] | None:
    steps = committed_steps(store, name)
    if not steps:
        return None
    step = steps[-1]
    p, o, extra = restore_checkpoint(store, name, step, params_like, opt_like)
    return step, p, o, extra


def prune_checkpoints(store: ObjectStore, name: str, keep: int = 2) -> None:
    steps = committed_steps(store, name)
    for step in steps[:-keep]:
        prefix = f"ckpt/{name}/{step:010d}"
        for meta in list(store.list(prefix)):
            store.delete(meta.key)
