"""Fault-tolerant training loop: resume-from-checkpoint, periodic commits,
simple synthetic LM data pipeline.  Used by examples/train_embedder.py and
the launchers; on a real cluster the same loop runs under pjit with the
production mesh (launch/train.py)."""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.object_store import ObjectStore
from ..models import model as M
from ..models.config import ModelConfig
from .checkpoint import prune_checkpoints, restore_latest, save_checkpoint
from .optimizer import AdamWConfig, adamw_update, clip_by_global_norm, init_opt_state


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 64
    checkpoint_every: int = 25
    log_every: int = 10
    run_name: str = "run0"
    seed: int = 0


def synthetic_lm_batches(cfg: ModelConfig, tc: TrainConfig):
    """Deterministic synthetic corpus: structured (learnable) token streams —
    a Zipfian unigram mixed with a copy pattern so loss decreases visibly."""
    rng = np.random.default_rng(tc.seed)
    zipf_p = 1.0 / np.arange(1, cfg.vocab_size + 1)
    zipf_p /= zipf_p.sum()
    step = 0
    while True:
        toks = rng.choice(cfg.vocab_size, size=(tc.batch, tc.seq_len), p=zipf_p)
        # periodic copy pattern: position i repeats position i-4
        toks[:, 4::4] = toks[:, : tc.seq_len - 4 : 4][:, : toks[:, 4::4].shape[1]]
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -100
        yield {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        step += 1


def train(
    cfg: ModelConfig,
    store: ObjectStore,
    tc: TrainConfig,
    adamw: AdamWConfig | None = None,
    batch_iter=None,
    on_step: Callable[[int, float], None] | None = None,
) -> tuple[dict, dict, list[float]]:
    """Run (or resume) training; returns (params, opt_state, loss history)."""
    adamw = adamw or AdamWConfig(lr=3e-3, warmup_steps=20)
    params = M.init_params(cfg, jax.random.key(tc.seed))
    opt_state = init_opt_state(params)
    start_step = 0
    resumed = restore_latest(store, tc.run_name, params, opt_state)
    if resumed is not None:
        start_step, params, opt_state, _extra = resumed
        print(f"[train] resumed '{tc.run_name}' from step {start_step}")

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            return M.lm_loss(cfg, p, batch["tokens"], batch["labels"],
                             remat=True, seq_chunk=min(64, tc.seq_len))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = clip_by_global_norm(grads, adamw.grad_clip)
        params, opt_state = adamw_update(adamw, params, grads, opt_state)
        return params, opt_state, loss

    batches = batch_iter or synthetic_lm_batches(cfg, tc)
    # data-pipeline restore: advance the stream to the resume point so a
    # resumed run consumes exactly the batches the lost run would have
    for _ in range(start_step):
        next(batches)
    losses: list[float] = []
    t0 = time.time()
    for step in range(start_step, tc.steps):
        batch = next(batches)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if on_step:
            on_step(step, float(loss))
        if (step + 1) % tc.log_every == 0:
            rate = (step + 1 - start_step) / max(time.time() - t0, 1e-9)
            print(f"[train] step {step+1}/{tc.steps} loss={float(loss):.4f} "
                  f"({rate:.1f} steps/s)")
        if (step + 1) % tc.checkpoint_every == 0 or step + 1 == tc.steps:
            save_checkpoint(store, tc.run_name, step + 1, params, opt_state)
            prune_checkpoints(store, tc.run_name, keep=2)
    return params, opt_state, losses
