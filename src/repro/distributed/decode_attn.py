"""Flash-decode over a sequence-sharded KV cache (shard_map).

Each ``model``-axis shard holds a contiguous S/m slice of the KV cache.
For one new token:

  1. the shard owning position ``pos`` writes the fresh K/V into its local
     slice (conditional dynamic_update_slice — no cross-shard traffic);
  2. every shard computes *partial* attention over its slice: running
     (max m_i, denom l_i, weighted value o_i);
  3. the partials are merged with the standard log-sum-exp combine over a
     tiny ``all_gather`` / ``psum`` — bytes moved per layer per step are
     O(B·H·hd), independent of sequence length.

This is the TPU-idiomatic equivalent of flash-decode / paged KV on GPUs:
the 32k–524k KV never materializes on one chip, and the collective term of
the roofline stays flat in S (validated in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models.layers import repeat_kv
from .compat import shard_map


def _partial_attention(q, k, v, valid):
    """Local partial softmax over raw (un-repeated) GQA KV.

    Returns (o [B,1,H,hd] f32, m, l [B,H,1])."""
    b, _one, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q5 = q.reshape(b, 1, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", q5, k.astype(jnp.float32)
    ) / math.sqrt(hd)  # [B,KVH,G,1,S]
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    m = scores.max(axis=-1)  # [B,KVH,G,1]
    p = jnp.exp(scores - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, 1, h, hd), m.reshape(b, h, 1), l.reshape(b, h, 1)


def make_gqa_flash_decode(mesh: Mesh, seq_axis: str = "model",
                          batch_spec: P | None = None):
    """Returns an attn impl: (q, k_new, v_new, k_cache, v_cache, pos) ->
    (out [B,1,H,hd], new_k_cache, new_v_cache) with caches S-sharded."""
    b_spec = batch_spec if batch_spec is not None else P(None)
    b_axis = b_spec[0] if len(b_spec) else None

    def impl(q, k_new, v_new, k_cache, v_cache, pos):
        num_heads = q.shape[2]

        def local(q, k_new, v_new, kc, vc, pos):
            idx = jax.lax.axis_index(seq_axis)
            s_local = kc.shape[1]
            offset = idx * s_local
            local_pos = pos - offset
            owns = (local_pos >= 0) & (local_pos < s_local)
            lp = jnp.clip(local_pos, 0, s_local - 1)
            # Slice-level select: non-owners re-write their existing row, so
            # only O(1 token) of cache traffic per shard (a whole-cache
            # jnp.where(owns, ...) here costs 3x full-cache HBM traffic).
            k_old = jax.lax.dynamic_slice(
                kc, (0, lp, 0, 0), (kc.shape[0], 1, kc.shape[2], kc.shape[3])
            )
            v_old = jax.lax.dynamic_slice(
                vc, (0, lp, 0, 0), (vc.shape[0], 1, vc.shape[2], vc.shape[3])
            )
            k_row = jnp.where(owns, k_new.astype(kc.dtype), k_old)
            v_row = jnp.where(owns, v_new.astype(vc.dtype), v_old)
            kc = jax.lax.dynamic_update_slice(kc, k_row, (0, lp, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v_row, (0, lp, 0, 0))

            valid = (jnp.arange(s_local)[None, :] + offset) <= pos
            valid = jnp.broadcast_to(valid, (q.shape[0], s_local))
            o, m, l = _partial_attention(q, kc, vc, valid)

            # LSE combine across sequence shards (tiny tensors)
            g_m = jax.lax.pmax(m, seq_axis)
            scale = jnp.exp(m - g_m)
            l_tot = jax.lax.psum(l * scale, seq_axis)
            o_tot = jax.lax.psum(o * scale.transpose(0, 2, 1)[..., None], seq_axis)
            out = (o_tot / jnp.maximum(l_tot, 1e-30).transpose(0, 2, 1)[..., None])
            return out.astype(q.dtype), kc, vc

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(
                P(b_axis, None, None, None),  # q replicated over model
                P(b_axis, None, None, None),
                P(b_axis, None, None, None),
                P(b_axis, seq_axis, None, None),
                P(b_axis, seq_axis, None, None),
                P(),
            ),
            out_specs=(
                P(b_axis, None, None, None),
                P(b_axis, seq_axis, None, None),
                P(b_axis, seq_axis, None, None),
            ),
            check=False,
        )(q, k_new, v_new, k_cache, v_cache, pos)

    return impl


def make_mla_flash_decode(mesh: Mesh, seq_axis: str = "model",
                          batch_spec: P | None = None):
    """MLA absorbed flash-decode over an S-sharded compressed cache.

    (q_c [B,1,H,r], q_rope [B,1,H,rope], payload_new [B,1,r+rope],
     c_cache [B,S,r+rope], pos, r) -> (ctx [B,1,H,r], new_c_cache)
    where ctx is the attention read in compressed space (caller applies the
    absorbed value up-projection).
    """
    b_spec = batch_spec if batch_spec is not None else P(None)
    b_axis = b_spec[0] if len(b_spec) else None

    def impl(q_c, q_rope, payload_new, c_cache, pos, r, scale_dim):
        def local(q_c, q_rope, payload_new, cc, pos):
            idx = jax.lax.axis_index(seq_axis)
            s_local = cc.shape[1]
            offset = idx * s_local
            local_pos = pos - offset
            owns = (local_pos >= 0) & (local_pos < s_local)
            lp = jnp.clip(local_pos, 0, s_local - 1)
            old = jax.lax.dynamic_slice(
                cc, (0, lp, 0), (cc.shape[0], 1, cc.shape[2])
            )
            row = jnp.where(owns, payload_new.astype(cc.dtype), old)
            cc = jax.lax.dynamic_update_slice(cc, row, (0, lp, 0))

            c_kv = cc[..., :r].astype(jnp.float32)
            k_rope = cc[..., r:].astype(jnp.float32)
            scores = (
                jnp.einsum("bqhr,bsr->bhqs", q_c.astype(jnp.float32), c_kv)
                + jnp.einsum("bqhn,bsn->bhqs", q_rope.astype(jnp.float32), k_rope)
            ) / math.sqrt(scale_dim)
            valid = (jnp.arange(s_local)[None, :] + offset) <= pos
            scores = jnp.where(valid[:, None, None, :], scores, -1e30)
            m = scores.max(axis=-1)
            p = jnp.exp(scores - m[..., None])
            l = p.sum(axis=-1)
            ctx = jnp.einsum("bhqs,bsr->bqhr", p, c_kv)

            g_m = jax.lax.pmax(m, seq_axis)
            scale = jnp.exp(m - g_m)
            l_tot = jax.lax.psum(l * scale, seq_axis)
            ctx_tot = jax.lax.psum(ctx * scale.transpose(0, 2, 1)[..., None], seq_axis)
            ctx_out = ctx_tot / jnp.maximum(l_tot, 1e-30).transpose(0, 2, 1)[..., None]
            return ctx_out.astype(q_c.dtype), cc

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(
                P(b_axis, None, None, None),
                P(b_axis, None, None, None),
                P(b_axis, None, None),
                P(b_axis, seq_axis, None),
                P(),
            ),
            out_specs=(
                P(b_axis, None, None, None),
                P(b_axis, seq_axis, None),
            ),
            check=False,
        )(q_c, q_rope, payload_new, c_cache, pos)

    return impl
