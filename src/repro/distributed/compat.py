"""Version-compat shims for JAX API drift.

``shard_map`` has moved twice: older releases expose it only as
``jax.experimental.shard_map.shard_map`` with a ``check_rep`` kwarg;
newer ones promote it to ``jax.shard_map`` and rename the kwarg to
``check_vma``.  ``shard_map`` below resolves whichever spelling the
installed JAX provides, so the distributed code runs unchanged across
the range pinned in pyproject.toml.
"""

from __future__ import annotations

import inspect

import jax


def _resolve():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return fn, check_kw


_SHARD_MAP, _CHECK_KW = _resolve()


def shard_map(f, mesh, in_specs, out_specs, check: bool = True):
    """``jax.shard_map`` with the replication-check kwarg normalized."""
    return _SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: check}
    )


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a flat dict.

    Older JAX returns a one-element list of per-program dicts; newer JAX
    returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
