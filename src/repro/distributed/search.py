"""Distributed vector search: Manu's segment-parallel two-phase reduce as a
``shard_map`` over the device mesh.

The paper's scale-out (§3.6): segments are distributed over query nodes;
each node computes segment-wise top-k, merges to node-wise top-k, and the
proxy aggregates the global top-k.  On a TPU mesh this maps to: base
vectors row-sharded over every device, each device scans its shard (MXU
distance kernel), and the reduce is an ``all_gather`` of k-sized partials
(+ final local sort) — bytes moved are O(devices * k), independent of
collection size.

Runs identically on 2 host devices (tests) and the 256-chip production
mesh (dry-run); ``dryrun_search`` lowers + compiles it for the roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map


def _local_topk(queries, base_shard, k, metric, row_offset, valid=None):
    q = queries.astype(jnp.float32)
    x = base_shard.astype(jnp.float32)
    if metric == "l2":
        scores = (
            jnp.sum(q * q, axis=1, keepdims=True)
            - 2.0 * q @ x.T
            + jnp.sum(x * x, axis=1)[None, :]
        )
        scores = -scores  # top_k takes max
    else:
        scores = q @ x.T
    if valid is not None:
        scores = jnp.where(valid[None, :] > 0, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx + row_offset


def make_distributed_search(mesh: Mesh, k: int, metric: str = "l2"):
    """Returns search(queries [NQ,D] replicated, base [N,D] row-sharded,
    valid [N]) -> (scores [NQ,k], global row idx [NQ,k]).

    Base rows are sharded over ALL mesh axes (maximum scan parallelism —
    the 'segments spread over every query node' configuration).
    """
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))

    def search(queries, base, valid):
        def local(q, x_shard, v_shard):
            # flatten the multi-axis shard index into a row offset
            shard_idx = jax.lax.axis_index(axes)
            rows_local = x_shard.shape[0]
            offset = shard_idx * rows_local
            k_local = min(k, rows_local)
            vals, idx = _local_topk(q, x_shard, k_local, metric, offset, v_shard)
            # two-phase reduce: one all_gather of the k-sized partials over
            # every mesh axis (O(devices*k) bytes), then a local re-reduce.
            all_vals = jax.lax.all_gather(vals, axes, axis=0, tiled=False)
            all_idx = jax.lax.all_gather(idx, axes, axis=0, tiled=False)
            nq = q.shape[0]
            cand_v = jnp.moveaxis(all_vals.reshape(-1, nq, k_local), 0, 1).reshape(nq, -1)
            cand_i = jnp.moveaxis(all_idx.reshape(-1, nq, k_local), 0, 1).reshape(nq, -1)
            out_v, sel = jax.lax.top_k(cand_v, k)
            out_i = jnp.take_along_axis(cand_i, sel, axis=1)
            return out_v, out_i

        out = shard_map(
            local,
            mesh=mesh,
            in_specs=(P(None, None), P(axes, None), P(axes)),
            out_specs=(P(), P()),
            check=False,
        )(queries, base, valid)
        vals, idx = out
        if metric == "l2":
            vals = -vals
        return vals, idx

    return search


def distributed_search_host(queries, base, k, metric="l2", mesh=None):
    """Convenience wrapper: shards base over available devices and runs."""
    mesh = mesh or jax.make_mesh((jax.device_count(),), ("data",))
    n = base.shape[0]
    n_dev = mesh.devices.size
    pad = (-n) % n_dev
    basep = np.pad(base, ((0, pad), (0, 0)))
    valid = np.concatenate([np.ones(n, np.int32), np.zeros(pad, np.int32)])
    fn = make_distributed_search(mesh, k, metric)
    with mesh:
        base_sh = jax.device_put(basep, NamedSharding(mesh, P(tuple(mesh.axis_names), None)))
        valid_sh = jax.device_put(valid, NamedSharding(mesh, P(tuple(mesh.axis_names))))
        q_sh = jax.device_put(np.asarray(queries, np.float32), NamedSharding(mesh, P(None, None)))
        vals, idx = jax.jit(fn)(q_sh, base_sh, valid_sh)
    return np.asarray(vals), np.asarray(idx)


def dryrun_search(mesh: Mesh, n_rows: int, dim: int, nq: int, k: int, metric="l2"):
    """Lower + compile the distributed search at production-mesh scale."""
    fn = make_distributed_search(mesh, k, metric)
    axes = tuple(mesh.axis_names)
    with mesh:
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((nq, dim), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, dim), jnp.float32),
            jax.ShapeDtypeStruct((n_rows,), jnp.int32),
        )
        return lowered.compile()
