"""Partitioning rules: param/batch/cache PartitionSpecs for any mesh.

Scheme (DESIGN.md §5):

* **Training** — batch over (pod, data); params 2-D sharded: FSDP (ZeRO-3)
  over ``data`` on the input-feature dim + tensor-parallel over ``model``
  on the output-feature/head/expert dim; optimizer state like params;
  gradients all-reduce over ``pod`` (inter-pod traffic = one all-reduce).
* **Serving** — weights TP over ``model`` with FSDP off (replicated over
  ``data``), requests sharded over data; decode KV caches sharded on the
  *sequence* dim over ``model`` (flash-decode merges partial softmax
  stats), batch over data when divisible.
* Dims that do not divide the axis size stay unsharded — the rules check
  divisibility explicitly, so every assigned architecture lowers cleanly
  (e.g. 40-head MiniCPM3 replicates attention heads but still TPs its FFN).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

Params = dict[str, Any]


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _div(dim: int, mesh: Mesh, axis: str | tuple[str, ...] | None) -> str | tuple | None:
    """Return the axis if ``dim`` divides its total size, else None."""
    if axis is None:
        return None
    names = (axis,) if isinstance(axis, str) else axis
    total = int(np.prod([axis_size(mesh, a) for a in names]))
    if total <= 1:
        return None
    return axis if dim % total == 0 else None


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec(mesh: Mesh, batch: int) -> P:
    axes = batch_axes(mesh)
    full = _div(batch, mesh, axes)
    if full is not None:
        return P(axes)
    one = _div(batch, mesh, "data")
    return P(one) if one else P(None)


# ---------------------------------------------------------------------------
# Parameter rules.  Matched on the param path (tuple of keys).  ``fsdp``
# toggles the data-axis dimension sharding (on for training, off for
# serving).  Stacked layer params get a leading None for the periods dim.
# ---------------------------------------------------------------------------


def _param_rule(
    cfg: ModelConfig, mesh: Mesh, path: tuple[str, ...], shape: tuple[int, ...],
    fsdp: bool,
) -> P:
    d_axis = "data" if fsdp else None
    name = path[-1]
    in_layers = "layers" in path

    def spec2(d0: int, d1: int, a0, a1) -> P:
        s0 = _div(d0, mesh, a0)
        s1 = _div(d1, mesh, a1)
        base = (s0, s1)
        return P(None, *base) if in_layers else P(*base)

    def spec3(d0, d1, d2, a0, a1, a2) -> P:
        s = (_div(d0, mesh, a0), _div(d1, mesh, a1), _div(d2, mesh, a2))
        return P(None, *s) if in_layers else P(*s)

    body = shape[1:] if in_layers else shape  # strip stacked periods dim

    # ---- top level ----
    # Embedding / head: vocab on `model` when divisible; NEVER shard the
    # d_model contraction dim on `data` — GSPMD then emits unsharded partial
    # logits ([B,S,V] full per device — observed 196 GiB in the dry-run).
    if name == "embed":
        return P(_div(shape[0], mesh, "model"), None)
    if name == "lm_head":
        return P(None, _div(shape[1], mesh, "model"))
    if name == "vision_proj":
        return P(None, _div(shape[1], mesh, "model"))
    if name in ("ln_final",):
        return P(None)

    # ---- per-layer 1-D params (norms, biases, scalars) ----
    if len(body) == 1:
        if name in ("b_q", "b_k", "b_v"):
            return P(None, _div(body[0], mesh, "model"))
        return P(None, None) if in_layers else P(None)

    # ---- attention ----
    if name in ("w_q", "w_k", "w_v", "w_dq", "w_uq", "w_dkv", "w_ukv",
                "w_gate", "w_up", "w_in"):
        if len(body) == 3:  # MoE expert weights [E, D, F]
            return spec3(body[0], body[1], body[2], "model", d_axis, None)
        return spec2(body[0], body[1], d_axis, "model")
    if name in ("w_o", "w_down", "w_out"):
        if len(body) == 3:  # MoE [E, F, D]
            return spec3(body[0], body[1], body[2], "model", d_axis, None)
        return spec2(body[0], body[1], "model", d_axis)
    if name == "router":
        return spec2(body[0], body[1], d_axis, None)
    if name == "conv_w":
        # tiny depthwise taps: replicate — sharding the channel dim forces
        # an activation reshard (B:data -> C:model) per SSM layer.
        return P(None, None, None) if in_layers else P(None, None)

    # default: replicate
    return P(*([None] * len(shape)))


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Params, fsdp: bool) -> Params:
    def rule(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        return _param_rule(cfg, mesh, keys, leaf.shape, fsdp)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape: Params, fsdp: bool):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(cfg, mesh, params_shape, fsdp),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Decode / prefill cache rules
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape: Params, batch: int) -> Params:
    """KV caches: [periods, B, S, ...] -> P(None, batch?, 'model' on S, ...).

    SSM states have no sequence dim; their head dim takes ``model`` when the
    batch cannot use ``data`` (long-context batch=1 case).
    """
    b_axes = batch_axes(mesh)
    b_spec = _div(batch, mesh, b_axes) or _div(batch, mesh, "data")

    def rule(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        name = keys[-1]
        shp = leaf.shape
        if name == "length":
            return P()
        if name in ("k", "v"):  # [periods, B, S, KVH, hd]
            return P(None, b_spec, _div(shp[2], mesh, "model"), None, None)
        if name == "c":  # MLA [periods, B, S, r+rope]
            return P(None, b_spec, _div(shp[2], mesh, "model"), None)
        if name == "h":  # SSM [periods, B, H, hd, N]
            h_spec = None if b_spec else _div(shp[2], mesh, "model")
            return P(None, b_spec, h_spec, None, None)
        if name == "conv":  # [periods, B, conv-1, C]
            return P(None, b_spec, None, _div(shp[3], mesh, "model"))
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def tree_shardings(mesh: Mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
