"""Activation-sharding constraints (logical axis rules).

GSPMD infers shardings by propagation, but ``lax.scan`` carries initialized
with ``jnp.zeros`` start out replicated — and a replicated carry forces the
whole loop body to run unsharded (observed: the SSM block computing on the
full 256-row global batch per device).  The industry-standard fix (MaxText
et al.) is explicit ``with_sharding_constraint`` on the carries and other
propagation boundaries.

The model code stays mesh-agnostic: it calls ``constrain(x, "batch", ...)``
with *logical* axis names; the launcher installs a policy mapping logical
axes to mesh axes before building a cell.  With no policy installed (unit
tests, single device) the calls are no-ops.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _policy():
    return getattr(_STATE, "policy", None)


def set_policy(mesh: Mesh, batch_axes: tuple[str, ...] | None,
               moe_impl: str = "shard_map") -> None:
    _STATE.policy = {"mesh": mesh, "batch": batch_axes, "model": ("model",),
                     "moe_impl": moe_impl}


def clear_policy() -> None:
    _STATE.policy = None


@contextmanager
def policy(mesh: Mesh, batch_axes: tuple[str, ...] | None,
           moe_impl: str = "shard_map"):
    set_policy(mesh, batch_axes, moe_impl)
    try:
        yield
    finally:
        clear_policy()


def _axes_size(mesh: Mesh, axes) -> int:
    total = 1
    for a in axes:
        total *= mesh.shape.get(a, 1)
    return total


def constrain(x, *logical: str | None):
    """Apply a sharding constraint by logical dims ("batch"/"model"/None).

    Dims that do not divide the mapped mesh axes fall back to None, so this
    is always safe to call.  Trailing dims default to None.
    """
    pol = _policy()
    if pol is None or x is None:
        return x
    mesh = pol["mesh"]
    spec = []
    for i, name in enumerate(logical):
        if name is None or i >= x.ndim:
            spec.append(None)
            continue
        axes = pol.get(name)
        if not axes:
            spec.append(None)
            continue
        axes_t = tuple(axes) if not isinstance(axes, str) else (axes,)
        if x.shape[i] % _axes_size(mesh, axes_t) == 0:
            spec.append(axes_t if len(axes_t) > 1 else axes_t[0])
        else:
            spec.append(None)
    while len(spec) < x.ndim:
        spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_tree_batch(tree, batch_dim_by_rank: dict[int, int] | None = None):
    """Constrain every leaf's batch dim (default dim 0)."""
    pol = _policy()
    if pol is None:
        return tree

    def leaf(x):
        dims = [None] * x.ndim
        bd = 0 if batch_dim_by_rank is None else batch_dim_by_rank.get(x.ndim, 0)
        if x.ndim:
            dims[bd] = "batch"
        return constrain(x, *dims)

    return jax.tree_util.tree_map(leaf, tree)


def model_axis_size() -> int:
    pol = _policy()
    if pol is None:
        return 1
    mesh = pol["mesh"]
    return mesh.shape.get("model", 1)
