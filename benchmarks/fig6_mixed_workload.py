"""Fig. 6: sustained mixed insert+search workload — Manu vs coupled serving.

The paper's mechanism: Milvus has a single write node that also builds
indexes, so at high insert rates ingest and index work contend with
queries and search latency degrades.  Manu decouples the paths: writes
enter through the serving-tier request scheduler (bounded queues,
micro-batched WAL crossings) and ingest/index work completes on dedicated
nodes outside the query window, keeping search latency flat as the insert
rate rises.

Reproduction (scaled down, same mechanism): a sustained run per insert
rate.  In *manu* mode each tick admits the tick's rows asynchronously
(``insert_async`` under backpressure, one ``Logger.mutate_batch`` crossing
per micro-batch flush) and the ingest pipeline drains OFF the timed
window; searches are timed alone.  In *coupled* mode the same rows are
published synchronously and the full consume/seal/index pipeline runs
INSIDE the serving window.  Emits p50/p99 search-latency rows.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import AdmissionRejected, ManuConfig, ManuSystem

from .common import emit

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DIM = 32
CHUNK = 128  # admission granularity in manu mode (rows per async request)


def run_mode(mode: str, insert_rate_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    system = ManuSystem(ManuConfig(
        num_query_nodes=2, num_index_nodes=1, seal_rows=512, slice_rows=256,
        ingest_flush_rows=256, ingest_queue_rows=4 * insert_rate_rows,
    ))
    coll = system.create_collection("c", dim=DIM)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 16, "nprobe": 4})
    q = rng.standard_normal((4, DIM)).astype(np.float32)
    coll.insert({"vector": rng.standard_normal((64, DIM)).astype(np.float32)})
    coll.search(q, limit=10)  # warmup (numpy/BLAS init must not skew tick 0)

    ticks = 4 if SMOKE else 6
    searches_per_tick = 3
    latencies = []
    for _ in range(ticks):
        vecs = rng.standard_normal((insert_rate_rows, DIM)).astype(np.float32)
        if mode == "manu":
            # Serving tier: admit the tick's rows through the scheduler's
            # bounded queues (credit backpressure -> flush and retry), then
            # drain ingest+index work OUTSIDE the timed window.
            for i in range(0, insert_rate_rows, CHUNK):
                chunk = {"vector": vecs[i:i + CHUNK]}
                try:
                    coll.insert_async(chunk)
                except AdmissionRejected:
                    system.flush_ingest()
                    coll.insert_async(chunk)
            system.flush_ingest()
            system.run_until_idle()  # dedicated nodes: not serving time
            for _ in range(searches_per_tick):
                t0 = time.perf_counter()
                coll.search(q, limit=10, staleness_ms=0.0)
                latencies.append(time.perf_counter() - t0)
        else:
            # Coupled: publish synchronously, then the consume/seal/index
            # pipeline AND the search share the timed serving window.
            system.proxy.insert(coll.info, {"vector": vecs})
            t0 = time.perf_counter()
            system.run_until_idle()  # counted: contention on the write node
            coll.search(q, limit=10, staleness_ms=0.0)
            latencies.append(time.perf_counter() - t0)
            for _ in range(searches_per_tick - 1):
                t0 = time.perf_counter()
                coll.search(q, limit=10, staleness_ms=0.0)
                latencies.append(time.perf_counter() - t0)
    lat_us = np.asarray(latencies) * 1e6
    return float(np.percentile(lat_us, 50)), float(np.percentile(lat_us, 99))


def main() -> list[tuple[str, float, str]]:
    rows = []
    rates = (256, 512) if SMOKE else (500, 1000, 2000)
    for rate in rates:
        manu_p50, manu_p99 = run_mode("manu", rate)
        coup_p50, coup_p99 = run_mode("coupled", rate)
        rows.append((f"fig6-manu-rate{rate}-p50", manu_p50, "search_latency_p50"))
        rows.append((f"fig6-manu-rate{rate}-p99", manu_p99, "search_latency_p99"))
        rows.append((f"fig6-coupled-rate{rate}-p50", coup_p50, "search_latency_p50"))
        rows.append((f"fig6-coupled-rate{rate}-p99", coup_p99,
                     f"coupled/decoupled_p99={coup_p99 / max(manu_p99, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    emit(main())
