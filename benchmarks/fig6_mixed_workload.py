"""Fig. 6: mixed insert+search workload — Manu vs Milvus-style coupling.

The paper's mechanism: Milvus has a single write node that also builds
indexes, so at high insert rates index building contends with queries and
search falls back to brute-force over ever-growing unindexed data.  Manu's
dedicated index nodes keep search latency flat.

Reproduction (scaled down, same mechanism): we ingest at increasing rates
and measure search latency.  In *manu* mode, index builds run on dedicated
index nodes between requests (not in the query path).  In *milvus* mode the
pending index builds execute inside the search window (shared write node),
and sealed-but-unindexed segments are brute-force scanned.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ManuConfig, ManuSystem

from .common import emit


def run_mode(mode: str, insert_rate_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    dim = 32
    system = ManuSystem(ManuConfig(num_query_nodes=2, num_index_nodes=1,
                                   seal_rows=512, slice_rows=256))
    coll = system.create_collection("c", dim=dim)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 16, "nprobe": 4})
    q = rng.standard_normal((4, dim)).astype(np.float32)
    coll.insert({"vector": rng.standard_normal((64, dim)).astype(np.float32)})
    coll.search(q, limit=10)  # warmup (numpy/BLAS init must not skew tick 0)

    latencies = []
    for tick in range(6):
        vecs = rng.standard_normal((insert_rate_rows, dim)).astype(np.float32)
        # publish inserts without pumping index nodes yet
        lsn, _ = system.proxy.insert(coll.info, {"vector": vecs})
        if mode == "manu":
            # dedicated index nodes: builds complete outside the query path
            system.run_until_idle()
            t0 = time.perf_counter()
            coll.search(q, limit=10, staleness_ms=0.0)
            latencies.append(time.perf_counter() - t0)
        else:
            # milvus-style: the shared write node processes data + index
            # work inside the serving window
            t0 = time.perf_counter()
            system.run_until_idle()  # counted: contention on the write node
            coll.search(q, limit=10, staleness_ms=0.0)
            latencies.append(time.perf_counter() - t0)
    return float(np.mean(latencies) * 1e6)


def main() -> list[tuple[str, float, str]]:
    rows = []
    for rate in (500, 1000, 2000):
        manu_us = run_mode("manu", rate)
        milvus_us = run_mode("milvus", rate)
        rows.append((f"fig6-manu-rate{rate}", manu_us, "search_latency"))
        rows.append((f"fig6-milvus-rate{rate}", milvus_us,
                     f"coupled/decoupled={milvus_us/manu_us:.2f}x"))
    return rows


if __name__ == "__main__":
    emit(main())
