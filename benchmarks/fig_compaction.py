"""fig_compaction: the delete-heavy workload the compaction subsystem opens.

Mechanism under test (paper §3.1/§3.6 maintenance story): out-of-place
deletes accumulate as tombstones that every search must mask out, and the
dead rows keep getting scanned — so throughput degrades as the delete
ratio grows.  A compaction cycle folds the tombstones into rewritten
binlogs and the GC reaper reclaims the old objects; search throughput
must recover to at least the pre-delete level (fewer live rows, empty
delta-delete map).

Emits:
    fig_compaction-pre-delete        us/search over the freshly sealed set
    fig_compaction-post-delete       ... with ~40% tombstones
    fig_compaction-post-compaction   ... after compact+GC (recovered=...)
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import ManuConfig, ManuSystem

from .common import emit, timeit_us

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def main() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(7)
    n, dim, seal, nq, iters = (
        (4_096, 16, 256, 4, 3) if SMOKE else (16_384, 32, 1_024, 8, 5)
    )
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, seal_rows=seal, slice_rows=seal // 4)
    )
    coll = system.create_collection("c", dim=dim)
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    for lo in range(0, n, seal):
        coll.insert({"vector": vecs[lo : lo + seal]})
    coll.flush()
    q = rng.standard_normal((nq, dim)).astype(np.float32)
    n_seg = len(system.data_coord.sealed_segments("c"))

    def qps():  # eventual reads: measure the pure scan path
        return timeit_us(lambda: coll.search(q, limit=10), iters=iters, best_of=3)

    t_pre = qps()

    n_del = int(0.4 * n)
    victims = rng.choice(n, n_del, replace=False)
    step = max(1, n_del // 8)
    for lo in range(0, n_del, step):  # batched deletes, WAL-realistic
        coll.delete(victims[lo : lo + step])
    t_deleted = qps()

    coll.compact()
    coll.gc()
    t_compacted = qps()

    recovered = t_compacted <= 1.1 * t_pre  # the acceptance bar
    reclaimed = getattr(system.store, "bytes_deleted", 0)
    return [
        ("fig_compaction-pre-delete", t_pre, f"n={n},segs={n_seg},nq={nq}"),
        (
            "fig_compaction-post-delete",
            t_deleted,
            f"tombstones={n_del};slowdown={t_deleted / t_pre:.2f}x",
        ),
        (
            "fig_compaction-post-compaction",
            t_compacted,
            f"vs_pre={t_compacted / t_pre:.2f}x;recovered={recovered};"
            f"gc_bytes={reclaimed}",
        ),
    ]


if __name__ == "__main__":
    emit(main())
