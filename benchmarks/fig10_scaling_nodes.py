"""Fig. 10: QPS scales ~linearly with query nodes.

Single-core container caveat: query nodes execute sequentially here, so
wall-clock QPS cannot scale.  We measure each node's *own* scan time for
its segment share and report the parallel-execution model QPS =
nq / max(per-node time) — the quantity the paper's multi-machine cluster
realizes physically (each node is an independent EC2 instance).

A second row set runs with ``replication_factor=2``: every segment lives
on two nodes, so each node carries twice the rf=1 share — the price of
failover capacity, visible as roughly halved model QPS at equal node
count (and the reason the fig9 kill-node run loses no answers).
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import ManuConfig, ManuSystem
from repro.core.consistency import GuaranteeTs
from repro.core.timestamp import INFINITE_STALENESS

from .common import emit, sift_like

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DIM = 32 if SMOKE else 64
N = 8_000 if SMOKE else 24_000
NQ = 16 if SMOKE else 32
SEAL = 1_000 if SMOKE else 1_500


def qps_with_nodes(
    n_nodes: int, replication_factor: int = 1
) -> tuple[float, float, "object | None"]:
    rng = np.random.default_rng(0)
    system = ManuSystem(
        ManuConfig(
            num_query_nodes=n_nodes,
            seal_rows=SEAL,
            replication_factor=replication_factor,
        )
    )
    coll = system.create_collection("c", dim=DIM)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 32, "nprobe": 8})
    base = sift_like(N, DIM)
    for lo in range(0, N, N // 4):
        coll.insert({"vector": base[lo : lo + N // 4]})
    coll.flush()
    q = rng.standard_normal((NQ, DIM)).astype(np.float32)
    g = GuaranteeTs(system.tso.next(), INFINITE_STALENESS)
    per_node = []
    for node in system.query_nodes.values():
        if not node.alive or not node.held_segments("c"):
            continue
        node.search("c", q, 10, coll.info.metric, g)  # warmup
        t0 = time.perf_counter()
        for _ in range(3):
            node.search("c", q, 10, coll.info.metric, g)
        per_node.append((time.perf_counter() - t0) / 3)
    slowest = max(per_node)
    # Cluster-wide per-search latency distribution from the registry (the
    # per-node scan histograms aggregate every warmup + timed search).
    hist = None
    for h in system.metrics().histograms:
        if h.name.startswith("query_node_search_latency_us"):
            hist = h if hist is None or h.p99 > hist.p99 else hist
    return NQ / slowest, slowest, hist


def main() -> list[tuple[str, float, str]]:
    rows = []
    base_qps = None
    for n_nodes in (1, 2, 4, 8):
        qps, slowest, hist = qps_with_nodes(n_nodes)
        base_qps = base_qps or qps
        tail = (
            f";p50={hist.p50:.0f}us;p99={hist.p99:.0f}us" if hist is not None else ""
        )
        rows.append((
            f"fig10-nodes{n_nodes}", slowest / NQ * 1e6,
            f"qps={qps:.0f};speedup={qps/base_qps:.2f}x{tail}",
        ))
    # replicated serving: rf=2 at 1/2/4 nodes (failover capacity cost)
    for n_nodes in (1, 2, 4):
        qps, slowest, hist = qps_with_nodes(n_nodes, replication_factor=2)
        tail = (
            f";p50={hist.p50:.0f}us;p99={hist.p99:.0f}us" if hist is not None else ""
        )
        rows.append((
            f"fig10-nodes{n_nodes}-rf2", slowest / NQ * 1e6,
            f"qps={qps:.0f};speedup={qps/base_qps:.2f}x;replication=2{tail}",
        ))
    return rows


if __name__ == "__main__":
    emit(main())
