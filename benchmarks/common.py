"""Shared benchmark helpers: synthetic SIFT/DEEP-like datasets, timing,
CSV emission (``name,us_per_call,derived``)."""

from __future__ import annotations

import time

import numpy as np


def sift_like(n: int, dim: int = 128, seed: int = 0, n_clusters: int = 64):
    """Clustered f32 vectors approximating SIFT's local-feature structure."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, dim)).astype(np.float32) * 3.0
    assign = rng.integers(0, n_clusters, n)
    base = centers[assign] + rng.standard_normal((n, dim)).astype(np.float32)
    return base.astype(np.float32)


def deep_like(n: int, dim: int = 96, seed: int = 1):
    """Unit-norm vectors (DEEP1B-style CNN descriptors); IP metric."""
    x = sift_like(n, dim, seed)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def queries_from(base: np.ndarray, nq: int, seed: int = 99, noise: float = 0.3):
    rng = np.random.default_rng(seed)
    picks = rng.choice(len(base), nq, replace=False)
    q = base[picks] + noise * rng.standard_normal((nq, base.shape[1])).astype(np.float32)
    return q.astype(np.float32)


def brute_force_topk(base, queries, k, metric="l2"):
    if metric == "l2":
        d = np.sum(queries**2, 1, keepdims=True) - 2 * queries @ base.T + np.sum(base**2, 1)
        return np.argsort(d, axis=1)[:, :k]
    return np.argsort(-(queries @ base.T), axis=1)[:, :k]


def recall_of(found, gt):
    hits = sum(len(set(found[r].tolist()) & set(gt[r].tolist())) for r in range(len(gt)))
    return hits / gt.size


def timeit_us(fn, warmup: int = 1, iters: int = 3, best_of: int = 1) -> float:
    """Mean us/call over ``iters``; with ``best_of`` > 1, the minimum of
    that many repeated measurements (robust against noisy neighbours on
    shared machines)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(max(1, best_of)):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def emit(rows: list[tuple[str, float, str]]) -> None:
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def python_dedup_merge(s, p, k, metric="l2"):
    """The pre-fusion per-row Python dedup merge (QueryNode/Proxy before
    the merge_topk kernel): stable score order, skip pk<0 / seen pks /
    non-finite, keep-best.  Kept as the semantic baseline for the merge
    equivalence tests and the merge-stage benchmark."""
    nq = s.shape[0]
    out_s = np.full((nq, k), np.inf if metric == "l2" else -np.inf, np.float32)
    out_p = np.full((nq, k), -1, np.int64)
    order = np.argsort(s if metric == "l2" else -s, axis=1, kind="stable")
    for r in range(nq):
        seen, slot = set(), 0
        for j in order[r]:
            pk = int(p[r, j])
            if pk < 0 or pk in seen:
                continue
            if not np.isfinite(s[r, j]):
                continue
            seen.add(pk)
            out_s[r, slot] = s[r, j]
            out_p[r, slot] = pk
            slot += 1
            if slot >= k:
                break
    return out_s, out_p
