"""Shared benchmark helpers: synthetic SIFT/DEEP-like datasets, timing,
CSV emission (``name,us_per_call,derived``)."""

from __future__ import annotations

import time

import numpy as np


def sift_like(n: int, dim: int = 128, seed: int = 0, n_clusters: int = 64):
    """Clustered f32 vectors approximating SIFT's local-feature structure."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, dim)).astype(np.float32) * 3.0
    assign = rng.integers(0, n_clusters, n)
    base = centers[assign] + rng.standard_normal((n, dim)).astype(np.float32)
    return base.astype(np.float32)


def deep_like(n: int, dim: int = 96, seed: int = 1):
    """Unit-norm vectors (DEEP1B-style CNN descriptors); IP metric."""
    x = sift_like(n, dim, seed)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def queries_from(base: np.ndarray, nq: int, seed: int = 99, noise: float = 0.3):
    rng = np.random.default_rng(seed)
    picks = rng.choice(len(base), nq, replace=False)
    q = base[picks] + noise * rng.standard_normal((nq, base.shape[1])).astype(np.float32)
    return q.astype(np.float32)


def brute_force_topk(base, queries, k, metric="l2"):
    if metric == "l2":
        d = np.sum(queries**2, 1, keepdims=True) - 2 * queries @ base.T + np.sum(base**2, 1)
        return np.argsort(d, axis=1)[:, :k]
    return np.argsort(-(queries @ base.T), axis=1)[:, :k]


def recall_of(found, gt):
    hits = sum(len(set(found[r].tolist()) & set(gt[r].tolist())) for r in range(len(gt)))
    return hits / gt.size


def timeit_us(fn, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows: list[tuple[str, float, str]]) -> None:
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
