"""fig_ingest: write-path throughput through the typed mutation pipeline.

Measures end-to-end ingestion (client -> proxy -> logger -> WAL -> growing
segments, cooperative pump included) in rows/s at 1, 2, and 4 shards, each
with and without partition placement (4 partitions, round-robin batches).
Sharding widens the WAL (one vectorized shard-split scatter per batch feeds
N channels); partitions add per-partition segment allocation on top.  The
derived column carries rows/s so the trajectory file tracks write
throughput across PRs.

Emits:
    fig_ingest-shards{n}              us/batch, derived rows_per_s
    fig_ingest-shards{n}-partitioned  same with 4-partition placement
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import InsertRequest, ManuConfig, ManuSystem

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _ingest(num_shards: int, partitioned: bool, n: int, dim: int, batch: int):
    system = ManuSystem(
        ManuConfig(num_shards=num_shards, num_query_nodes=2, seal_rows=batch * 2)
    )
    coll = system.create_collection("w", dim=dim)
    parts = ["_default"]
    if partitioned:
        parts = [f"p{i}" for i in range(4)]
        for p in parts:
            coll.create_partition(p)
    rng = np.random.default_rng(42)
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    n_batches = n // batch
    t0 = time.perf_counter()
    for i in range(n_batches):
        rows = {"vector": vecs[i * batch : (i + 1) * batch]}
        coll.insert(InsertRequest(rows, partition=parts[i % len(parts)]))
    elapsed = time.perf_counter() - t0
    us_per_batch = elapsed / n_batches * 1e6
    rows_per_s = n / elapsed
    return us_per_batch, rows_per_s


def main() -> list[tuple[str, float, str]]:
    n, dim, batch = (8_192, 16, 512) if SMOKE else (65_536, 32, 2_048)
    rows: list[tuple[str, float, str]] = []
    for shards in (1, 2, 4):
        for partitioned in (False, True):
            us, rps = _ingest(shards, partitioned, n, dim, batch)
            suffix = "-partitioned" if partitioned else ""
            rows.append(
                (
                    f"fig_ingest-shards{shards}{suffix}",
                    us,
                    f"n={n},dim={dim},batch={batch};rows_per_s={rps:.0f}",
                )
            )
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(main())
