"""Recovery figure: restart-to-first-correct-read + search p99 under faults.

Two claims from the log-backbone design (paper §3.3, §6.3) measured here:

1. **Restart cost scales with the WAL tail, not the data size.**  We build
   collections of increasing row counts on a ``FileObjectStore``, flush most
   rows to binlogs, leave a growing tail in the WAL, then tear the whole
   system down and time ``ManuSystem.restart()`` up to the first search whose
   answers match a never-crashed oracle bit-for-bit.

2. **The retry plane flattens transient-fault latency into the tail.**  We
   replay the same query trace with a seeded 10% transient fault rate on
   object-store reads and report p50/p95/p99 against the fault-free run —
   wrong answers must stay 0 in both.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import FaultInjector, ManuConfig, ManuSystem
from repro.core.object_store import FileObjectStore

from .common import emit, sift_like

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DIM = 16 if SMOKE else 48
SIZES = [1_200, 3_300] if SMOKE else [2_500, 8_300, 24_600]
SEAL = 500 if SMOKE else 2_000
NQ = 8 if SMOKE else 32
QUERY_ROUNDS = 4 if SMOKE else 12
FAULT_PROB = 0.10


def _sorted_pks(res) -> np.ndarray:
    return np.sort(res.pks, axis=1)


def _build(store=None, injector=None) -> ManuSystem:
    return ManuSystem(
        ManuConfig(num_query_nodes=2, seal_rows=SEAL, slice_rows=SEAL // 2),
        store=store,
        injector=injector,
    )


def _ingest(system: ManuSystem, n: int):
    coll = system.create_collection("c", dim=DIM)
    coll.create_index("vector", kind="flat")
    base = sift_like(n, DIM)
    flushed = (n // SEAL) * SEAL  # sealed to binlogs
    coll.insert({"vector": base[:flushed]})
    coll.flush()
    if n > flushed:
        coll.insert({"vector": base[flushed:]})  # WAL-only growing tail
    return coll


def _restart_rows() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    rng = np.random.default_rng(7)
    for n in SIZES:
        q = rng.standard_normal((NQ, DIM)).astype(np.float32)
        oracle = _ingest(_build(), n)
        expect = _sorted_pks(oracle.search(q, limit=10, staleness_ms=0.0))

        root = tempfile.mkdtemp(prefix="fig_recovery_")
        try:
            system = _build(store=FileObjectStore(root))
            _ingest(system, n)
            t0 = time.perf_counter()
            report = system.restart()
            coll = system.collections["c"]
            got = _sorted_pks(coll.search(q, limit=10, staleness_ms=0.0))
            elapsed_us = (time.perf_counter() - t0) * 1e6
            wrong = int((got != expect).sum())
            assert wrong == 0, f"restart at n={n} produced {wrong} wrong answers"
            rows.append(
                (
                    f"fig_recovery-restart-n{n}",
                    elapsed_us,
                    f"rows={n};wal_tail={n - (n // SEAL) * SEAL};"
                    f"tso_frontier={report['tso_frontier']};wrong=0",
                )
            )
        finally:
            shutil.rmtree(root, ignore_errors=True)
    return rows


def _faulted_search_rows() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    n = SIZES[-1]
    rng = np.random.default_rng(11)
    oracle = _ingest(_build(), n)

    inj = FaultInjector(seed=42)
    inj.transient("object_store.get", FAULT_PROB)
    system = _build(injector=inj)
    faulted = _ingest(system, n)

    wrong = 0
    for variant, coll in (("clean", oracle), ("faulted", faulted)):
        lats: list[float] = []
        for _ in range(QUERY_ROUNDS):
            q = rng.standard_normal((NQ, DIM)).astype(np.float32)
            expect = _sorted_pks(oracle.search(q, limit=10, staleness_ms=0.0))
            t0 = time.perf_counter()
            got = _sorted_pks(coll.search(q, limit=10, staleness_ms=0.0))
            lats.append((time.perf_counter() - t0) / NQ * 1e6)
            wrong += int((got != expect).sum())
        p50, p95, p99 = np.percentile(lats, [50, 95, 99])
        rows.append(
            (
                f"fig_recovery-search-{variant}",
                float(p50),
                f"p50={p50:.0f}us;p95={p95:.0f}us;p99={p99:.0f}us;"
                f"fault_prob={FAULT_PROB if variant == 'faulted' else 0};"
                f"wrong={wrong}",
            )
        )
    assert wrong == 0, f"retry plane leaked {wrong} wrong answers"
    counters = system.metrics().to_dict()["counters"]
    recovered = sum(
        v for k, v in counters.items() if k.startswith("retry_recovered_total")
    )
    injected = sum(
        v for k, v in counters.items() if k.startswith("faults_injected_total")
    )
    rows.append(
        (
            "fig_recovery-retries",
            float(recovered),
            f"injected={injected};recovered={recovered};wrong=0",
        )
    )
    return rows


def main() -> list[tuple[str, float, str]]:
    return _restart_rows() + _faulted_search_rows()


if __name__ == "__main__":
    emit(main())
