"""Fig. 9: elasticity under a diurnal workload trace.

The paper drives Manu with one day of e-commerce traffic and shows the
query-node count tracking load while latency stays inside a target band
(scale to 2x above 150 ms, to 0.5x below 100 ms, scaled here).  We replay a
sinusoidal trace, apply the same threshold policy on measured latency, and
report per-phase node counts and latency.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ManuConfig, ManuSystem

from .common import emit, sift_like

DIM = 64
TARGET_HI_MS = 40.0  # scaled-down thresholds for the container
TARGET_LO_MS = 10.0


def main() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    system = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=2_000, slice_rows=1_000))
    coll = system.create_collection("c", dim=DIM)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 32, "nprobe": 4})
    base = sift_like(16_000, DIM)
    for lo in range(0, len(base), 4_000):
        coll.insert({"vector": base[lo : lo + 4_000]})
    coll.flush()

    # diurnal trace: queries per phase
    phases = (20 + 180 * np.clip(np.sin(np.linspace(0, np.pi, 8)), 0, None)).astype(int)
    rows = []
    for t, load in enumerate(phases):
        q = rng.standard_normal((int(load), DIM)).astype(np.float32)
        live = [n for n, qn in system.query_nodes.items() if qn.alive]
        t0 = time.perf_counter()
        # simulate node-parallel serving: per-node latency = work / nodes
        coll.search(q, limit=10)
        wall = (time.perf_counter() - t0) * 1e3
        latency_ms = wall / max(len(live), 1)
        # the paper's policy: latency > hi -> add nodes to 2x; < lo -> 0.5x
        if latency_ms > TARGET_HI_MS:
            for _ in range(len(live)):
                system.add_query_node()
        elif latency_ms < TARGET_LO_MS and len(live) > 1:
            for _ in range(max(1, len(live) // 2)):
                system.remove_query_node()
        live_after = len([n for n, qn in system.query_nodes.items() if qn.alive])
        rows.append((
            f"fig9-phase{t}", latency_ms * 1e3,
            f"load={load};nodes_before={len(live)};nodes_after={live_after}",
        ))
    return rows


if __name__ == "__main__":
    emit(main())
