"""Fig. 9: elasticity — kill a query node mid-run, zero wrong answers.

The paper's claim is that log-subscriber decoupling gives failover without
correctness loss: replicas of every sealed segment live on independent
nodes, and the HealthMonitor/StateReconciler loop re-routes a dead node's
share to survivors.  We replay a phased query trace against a 3-node
cluster with replication_factor=2, crash one node *mid-request* halfway
through, and check every phase's answers bit-for-bit against an
undisturbed single-node oracle.  The per-phase latency curve shows the
failover blip and the recovery; ``wrong`` must stay 0 throughout.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import ManuConfig, ManuSystem

from .common import emit, sift_like

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DIM = 32 if SMOKE else 64
N = 6_000 if SMOKE else 16_000
NQ = 8 if SMOKE else 32
SEAL = 1_000 if SMOKE else 2_000
PHASES = 6 if SMOKE else 8
KILL_PHASE = PHASES // 2


def _build(num_query_nodes: int, replication_factor: int) -> "tuple":
    system = ManuSystem(
        ManuConfig(
            num_query_nodes=num_query_nodes,
            replication_factor=replication_factor,
            seal_rows=SEAL,
            slice_rows=SEAL // 2,
        )
    )
    coll = system.create_collection("c", dim=DIM)
    coll.create_index("vector", kind="flat")
    base = sift_like(N, DIM)
    for lo in range(0, N, N // 4):
        coll.insert({"vector": base[lo : lo + N // 4]})
    coll.flush()
    return system, coll


def _sorted_pks(res) -> np.ndarray:
    return np.sort(res.pks, axis=1)


def main() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    oracle_sys, oracle_coll = _build(1, 1)
    system, coll = _build(3, 2)

    rows: list[tuple[str, float, str]] = []
    wrong = 0
    victim_id = next(
        n for n, st in system.query_coord.nodes.items() if st.segments
    )
    for t in range(PHASES):
        q = rng.standard_normal((NQ, DIM)).astype(np.float32)
        expect = _sorted_pks(oracle_coll.search(q, limit=10, staleness_ms=0.0))
        if t == KILL_PHASE:
            # crash mid-request: the node dies between planning and scan
            victim = system.query_nodes[victim_id]

            def dying(request):
                victim.alive = False
                raise RuntimeError("injected crash mid-request")

            victim.search_request = dying
        live_before = len(
            [n for n, qn in system.query_nodes.items() if qn.alive]
        )
        t0 = time.perf_counter()
        res = coll.search(q, limit=10, staleness_ms=0.0)
        lat_us = (time.perf_counter() - t0) / NQ * 1e6
        wrong += int((_sorted_pks(res) != expect).sum())
        rows.append(
            (
                f"fig9-phase{t}",
                lat_us,
                f"nodes={live_before};wrong={wrong}"
                + (";killed=1" if t == KILL_PHASE else ""),
            )
        )

    cs = system.cluster_state()
    reassigned = victim_id not in cs.live_node_ids and all(
        victim_id not in p.replicas for p in cs.placement
    )
    healed = cs.under_replicated == 0
    rows.append(
        (
            "fig9-recovery",
            rows[KILL_PHASE][1],  # latency at the failover phase (the blip)
            f"wrong={wrong};reassigned={int(reassigned)};healed={int(healed)}",
        )
    )
    # Serving-latency distribution across the whole run (failover blip
    # included), from the system's own metrics histograms.
    h = system.metrics().histogram("proxy_search_latency_us")
    if h is not None:
        rows.append((
            "fig9-latency-dist", h.p50,
            f"p50={h.p50:.0f}us;p95={h.p95:.0f}us;p99={h.p99:.0f}us;"
            f"count={h.count}",
        ))
    assert wrong == 0, f"failover produced {wrong} wrong answers"
    assert reassigned, "cluster_state still lists the dead node"
    return rows


if __name__ == "__main__":
    emit(main())
