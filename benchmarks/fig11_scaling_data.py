"""Fig. 11: QPS scales with the reciprocal of data volume (fixed nodes)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import ManuConfig, ManuSystem

from .common import emit, sift_like

DIM, NQ = 64, 32


def qps_at(n_rows: int) -> float:
    rng = np.random.default_rng(0)
    system = ManuSystem(ManuConfig(num_query_nodes=2, seal_rows=1_500))
    coll = system.create_collection("c", dim=DIM)
    coll.create_index("vector", kind="flat")  # brute scan: cost tracks volume
    base = sift_like(n_rows, DIM)
    for lo in range(0, n_rows, 6_000):
        coll.insert({"vector": base[lo : lo + 6_000]})
    coll.flush()
    q = rng.standard_normal((NQ, DIM)).astype(np.float32)
    coll.search(q, limit=10)  # warmup (BLAS thread pools etc.)
    t0 = time.perf_counter()
    for _ in range(3):
        coll.search(q, limit=10)
    return 3 * NQ / (time.perf_counter() - t0)


def main() -> list[tuple[str, float, str]]:
    rows = []
    base_qps = None
    for n in (8_000, 16_000, 32_000):
        qps = qps_at(n)
        base_qps = base_qps or qps
        rows.append((f"fig11-rows{n}", 1e6 / qps,
                     f"qps={qps:.0f};vs_8k={qps/base_qps:.2f}x"))
    return rows


if __name__ == "__main__":
    emit(main())
