"""Fig. 8: recall vs throughput frontier on SIFT-like (L2) and DEEP-like
(IP) data for IVF-FLAT, HNSW and the bucket index, sweeping the quality
knob of each."""

from __future__ import annotations

import time

import numpy as np

from repro.core.collection import Metric
from repro.index import IndexSpec, create_index

from .common import brute_force_topk, deep_like, emit, queries_from, recall_of, sift_like

N, NQ, K = 8_000, 32, 50


def frontier(dataset: str, base, metric: Metric):
    queries = queries_from(base, NQ)
    gt = brute_force_topk(base, queries, K, metric.value if metric is Metric.L2 else "ip")
    rows = []
    sweeps = [
        ("ivf_flat", "nprobe", [1, 2, 4, 8, 16], {"nlist": 64}),
        ("hnsw", "ef_search", [16, 32, 64, 128], {"m": 12, "ef_construction": 80}),
        ("bucket", "nprobe_buckets", [2, 4, 8, 16], {"target_bucket_rows": 96, "replicas": 2}),
    ]
    for kind, knob, values, fixed in sweeps:
        idx = create_index(IndexSpec(kind=kind, metric=metric, params=dict(fixed, **{knob: values[-1]})))
        t_build = time.perf_counter()
        idx.build(base)
        build_s = time.perf_counter() - t_build
        for v in values:
            idx.params[knob] = v
            if hasattr(idx, knob):
                setattr(idx, knob, v)
            t0 = time.perf_counter()
            _s, found = idx.search(queries, K)
            dt = time.perf_counter() - t0
            r = recall_of(found, gt)
            qps = NQ / dt
            rows.append((
                f"fig8-{dataset}-{kind}-{knob}{v}",
                dt / NQ * 1e6,
                f"recall={r:.3f};qps={qps:.0f};build_s={build_s:.1f}",
            ))
    return rows


def main() -> list[tuple[str, float, str]]:
    rows = []
    rows += frontier("sift", sift_like(N, 128), Metric.L2)
    rows += frontier("deep", deep_like(N, 96), Metric.IP)
    return rows


if __name__ == "__main__":
    emit(main())
