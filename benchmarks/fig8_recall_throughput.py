"""Fig. 8: recall vs throughput frontier on SIFT-like (L2) and DEEP-like
(IP) data for IVF-FLAT, HNSW and the bucket index, sweeping the quality
knob of each.  A second section measures the node-level search execution
engine (planner + fused segment scans + merge_topk reduce) on a
many-segment collection — the paper's segment-parallel configuration."""

from __future__ import annotations

import time

import numpy as np

from repro.core.collection import Metric
from repro.core.consistency import GuaranteeTs
from repro.core.log import LogBroker
from repro.core.object_store import MemoryObjectStore
from repro.core.query_node import QueryNode, SealedHandle
from repro.core.segment import Segment
from repro.core.timestamp import INFINITE_STALENESS
from repro.index import IndexSpec, create_index

from .common import brute_force_topk, deep_like, emit, queries_from, recall_of, sift_like

N, NQ, K = 8_000, 32, 50


def frontier(dataset: str, base, metric: Metric):
    queries = queries_from(base, NQ)
    gt = brute_force_topk(base, queries, K, metric.value if metric is Metric.L2 else "ip")
    rows = []
    sweeps = [
        ("ivf_flat", "nprobe", [1, 2, 4, 8, 16], {"nlist": 64}),
        ("hnsw", "ef_search", [16, 32, 64, 128], {"m": 12, "ef_construction": 80}),
        ("bucket", "nprobe_buckets", [2, 4, 8, 16], {"target_bucket_rows": 96, "replicas": 2}),
    ]
    for kind, knob, values, fixed in sweeps:
        idx = create_index(IndexSpec(kind=kind, metric=metric, params=dict(fixed, **{knob: values[-1]})))
        t_build = time.perf_counter()
        idx.build(base)
        build_s = time.perf_counter() - t_build
        for v in values:
            idx.params[knob] = v
            if hasattr(idx, knob):
                setattr(idx, knob, v)
            t0 = time.perf_counter()
            _s, found = idx.search(queries, K)
            dt = time.perf_counter() - t0
            r = recall_of(found, gt)
            qps = NQ / dt
            rows.append((
                f"fig8-{dataset}-{kind}-{knob}{v}",
                dt / NQ * 1e6,
                f"recall={r:.3f};qps={qps:.0f};build_s={build_s:.1f}",
            ))
    return rows


def multiseg_engine(n_seg: int = 16, rows_per_seg: int = 500, nq: int = 64):
    """End-to-end node-level search over a many-segment collection: the
    fused engine (plan -> batched scans -> merge_topk) vs brute-force
    ground truth, at the paper's segment-parallel configuration."""
    dim = 128
    base = sift_like(n_seg * rows_per_seg, dim)
    queries = queries_from(base, nq)
    gt = brute_force_topk(base, queries, K, "l2")

    node = QueryNode("bench-qn", LogBroker(), MemoryObjectStore())
    for sid in range(n_seg):
        lo = sid * rows_per_seg
        seg = Segment(sid, "bench", 0, dim)
        seg.append(
            np.arange(lo, lo + rows_per_seg),
            base[lo : lo + rows_per_seg],
            np.full(rows_per_seg, 100, np.int64),
        )
        node.sealed[("bench", sid)] = SealedHandle(seg)
    g = GuaranteeTs(query_ts=10_000, staleness_ms=INFINITE_STALENESS)

    node.search("bench", queries, K, Metric.L2, g)  # warmup
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        _s, found = node.search("bench", queries, K, Metric.L2, g)
    dt = (time.perf_counter() - t0) / iters
    r = recall_of(found, gt)
    qps = nq / dt
    return [(
        f"fig8-multiseg-engine-{n_seg}x{rows_per_seg}",
        dt / nq * 1e6,
        f"recall={r:.3f};qps={qps:.0f};nq={nq};k={K}",
    )]


def main() -> list[tuple[str, float, str]]:
    rows = []
    rows += frontier("sift", sift_like(N, 128), Metric.L2)
    rows += frontier("deep", deep_like(N, 96), Metric.IP)
    rows += multiseg_engine()
    return rows


if __name__ == "__main__":
    emit(main())
