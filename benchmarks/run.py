# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV and writes the same rows to a ``BENCH_results.json`` trajectory file
# (per-row name/value/units) so CI and future PRs have a perf baseline to
# diff against.

from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import time
import traceback

from . import (
    fig6_mixed_workload,
    fig8_recall_throughput,
    fig9_elasticity,
    fig10_scaling_nodes,
    fig11_scaling_data,
    fig12_grace_time,
    fig13_index_build,
    fig_compaction,
    fig_filtered,
    fig_ingest,
    fig_recovery,
    kernels_micro,
)
from .common import emit

MODULES = [
    ("fig6", fig6_mixed_workload),
    ("fig8", fig8_recall_throughput),
    ("fig9", fig9_elasticity),
    ("fig10", fig10_scaling_nodes),
    ("fig11", fig11_scaling_data),
    ("fig12", fig12_grace_time),
    ("fig13", fig13_index_build),
    ("fig_compaction", fig_compaction),
    ("fig_filtered", fig_filtered),
    ("fig_ingest", fig_ingest),
    ("fig_recovery", fig_recovery),
    ("kernels", kernels_micro),
]


def run_metadata() -> dict:
    """Environment fingerprint stamped on every results file so a perf
    diff across runs can tell code changes from environment drift."""
    meta: dict = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "hostname": socket.gethostname(),
        "python": sys.version.split()[0],
    }
    try:
        meta["git_commit"] = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        meta["git_commit"] = None
    try:
        import numpy as _np

        meta["numpy"] = _np.__version__
    except Exception:
        meta["numpy"] = None
    try:
        import jax as _jax

        meta["jax"] = _jax.__version__
    except Exception:
        meta["jax"] = None
    return meta


def write_results(all_rows: "list[tuple[str, float, str]]", path: str) -> None:
    """Persist the benchmark trajectory: one entry per emitted row."""
    payload = {
        "schema": "repro-bench/v1",
        "smoke": os.environ.get("REPRO_BENCH_SMOKE") == "1",
        "meta": run_metadata(),
        "rows": [
            {"name": name, "value": round(us, 3), "units": "us_per_call",
             "derived": derived}
            for name, us, derived in all_rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def main() -> None:
    only = set(sys.argv[1:])
    unknown = only - {tag for tag, _ in MODULES}
    if unknown:  # a typo'd tag must not pass as an empty (green) run
        print(f"# unknown benchmark tags: {sorted(unknown)}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failures = 0
    all_rows: list[tuple[str, float, str]] = []
    for tag, mod in MODULES:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.main()
            emit(rows)
            all_rows += rows
            print(f"# {tag} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {tag} FAILED", file=sys.stderr)
            traceback.print_exc()
    out = os.environ.get("REPRO_BENCH_OUT", "BENCH_results.json")
    write_results(all_rows, out)
    print(f"# wrote {out} ({len(all_rows)} rows)", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
