# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.

from __future__ import annotations

import sys
import time
import traceback

from . import (
    fig6_mixed_workload,
    fig8_recall_throughput,
    fig9_elasticity,
    fig10_scaling_nodes,
    fig11_scaling_data,
    fig12_grace_time,
    fig13_index_build,
    fig_compaction,
    kernels_micro,
)
from .common import emit

MODULES = [
    ("fig6", fig6_mixed_workload),
    ("fig8", fig8_recall_throughput),
    ("fig9", fig9_elasticity),
    ("fig10", fig10_scaling_nodes),
    ("fig11", fig11_scaling_data),
    ("fig12", fig12_grace_time),
    ("fig13", fig13_index_build),
    ("fig_compaction", fig_compaction),
    ("kernels", kernels_micro),
]


def main() -> None:
    only = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failures = 0
    for tag, mod in MODULES:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.main()
            emit(rows)
            print(f"# {tag} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {tag} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
