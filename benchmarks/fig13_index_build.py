"""Fig. 13: index build time scales linearly with data volume.

Faithful mechanism: Manu builds one index per 512MB segment, so build time
is (#segments) x (per-segment build) — linear in volume.  We ingest
increasing volumes at a fixed seal size and time the full background
index-build drain."""

from __future__ import annotations

import time

from repro.core import ManuConfig, ManuSystem

from .common import emit, sift_like

DIM = 64
SEAL_ROWS = 2_000


def build_time(n_rows: int) -> float:
    system = ManuSystem(ManuConfig(num_query_nodes=1, num_index_nodes=1,
                                   seal_rows=SEAL_ROWS))
    coll = system.create_collection("c", dim=DIM)
    base = sift_like(n_rows, DIM)
    for lo in range(0, n_rows, SEAL_ROWS):
        coll.insert({"vector": base[lo : lo + SEAL_ROWS]})
    coll.flush()  # all segments sealed, none indexed yet
    t0 = time.perf_counter()
    # batch indexing (paper S3.5): builds fan out over every sealed segment
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 32})
    return time.perf_counter() - t0


def main() -> list[tuple[str, float, str]]:
    rows = []
    base_time = None
    for n in (4_000, 8_000, 16_000):
        dt = build_time(n)
        base_time = base_time or dt
        rows.append((f"fig13-rows{n}", dt * 1e6,
                     f"build_s={dt:.2f};vs_4k={dt/base_time:.2f}x"))
    return rows


if __name__ == "__main__":
    emit(main())
