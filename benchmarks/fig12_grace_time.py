"""Fig. 12: search latency vs grace time (tau) for several time-tick
intervals — the tunable-consistency trade-off, measured on the THREADED
runtime with a live async insert stream (the only benchmark that needs
real wall-clock waiting).

The inserter feeds the serving-tier scheduler (``insert_async`` with a
small age trigger, so the threaded pump loop flushes the micro-batches),
and each (tick, tau) cell reports p50/p99 over the sampled searches:
latency includes the delta-consistency wait, which shrinks monotonically
as tau grows.

The ``fig12-route-rf2`` row measures the watermark-aware routing path:
with a warm channel follower (replication_factor=2) a BOUNDED read is
served by whichever replica already covers the guarantee, so its latency
sits near the eventual floor instead of paying the tick wait.
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np

from repro.core import ConsistencyLevel, ManuConfig, ManuSystem

from .common import emit

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DIM = 16


def _sample(tau_ms: float, tick_ms: float, searches: int,
            replication: int = 1, consistency=None):
    rng = np.random.default_rng(0)
    system = ManuSystem(ManuConfig(
        num_query_nodes=max(1, replication), num_shards=1, seal_rows=100_000,
        manual_clock=False, threaded=True, tick_interval_ms=tick_ms,
        replication_factor=replication, bounded_staleness_ms=tau_ms,
        ingest_flush_rows=10_000, ingest_flush_ms=2.0,
    ))
    coll = system.create_collection("c", dim=DIM)
    stop = threading.Event()

    def inserter():
        # Async admission: the pump thread's age trigger flushes the
        # micro-batches, so the WAL crossing never blocks this loop.
        while not stop.is_set():
            coll.insert_async(
                {"vector": rng.standard_normal((20, DIM)).astype(np.float32)})
            time.sleep(0.01)

    t = threading.Thread(target=inserter, daemon=True)
    t.start()
    time.sleep(0.2)
    q = rng.standard_normal((1, DIM)).astype(np.float32)
    lats = []
    try:
        for _ in range(searches):
            t0 = time.perf_counter()
            if consistency is None:
                coll.search(q, limit=5, staleness_ms=tau_ms)
            else:
                coll.search(q, limit=5, consistency=consistency)
            lats.append(time.perf_counter() - t0)
            time.sleep(0.005)
    finally:
        stop.set()
        system.stop_threads()
    lat_us = np.asarray(lats) * 1e6
    covered = system.telemetry.counter_value(
        "consistency_routes_total", {"outcome": "covered"})
    return (float(np.percentile(lat_us, 50)), float(np.percentile(lat_us, 99)),
            covered)


def main() -> list[tuple[str, float, str]]:
    searches = 6 if SMOKE else 12
    ticks = (10.0,) if SMOKE else (10.0, 50.0)
    taus = (0.0, 25.0, 1e9) if SMOKE else (0.0, 25.0, 100.0, 1e9)
    rows = []
    for tick_ms in ticks:
        for tau_ms in taus:
            p50, p99, _ = _sample(tau_ms, tick_ms, searches)
            tau_label = "inf" if tau_ms >= 1e9 else f"{tau_ms:.0f}ms"
            base = f"fig12-tick{tick_ms:.0f}ms-tau{tau_label}"
            rows.append((f"{base}-p50", p50, "latency_includes_consistency_wait"))
            rows.append((f"{base}-p99", p99, "latency_includes_consistency_wait"))
    # Watermark-aware routing: BOUNDED reads with a warm rf=2 follower are
    # served by a covering replica instead of waiting out the tick.
    p50, p99, covered = _sample(100.0, 50.0, searches, replication=2,
                                consistency=ConsistencyLevel.BOUNDED)
    rows.append(("fig12-route-rf2-tau100ms-p50", p50,
                 f"covered_routes={covered:.0f}"))
    rows.append(("fig12-route-rf2-tau100ms-p99", p99,
                 f"covered_routes={covered:.0f}"))
    return rows


if __name__ == "__main__":
    emit(main())
