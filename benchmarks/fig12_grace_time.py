"""Fig. 12: search latency vs grace time (tau) for several time-tick
intervals — the tunable-consistency trade-off, measured on the THREADED
runtime with a live insert stream (the only benchmark that needs real
wall-clock waiting)."""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import ManuConfig, ManuSystem

from .common import emit

DIM = 16


def latency_at(tau_ms: float, tick_ms: float, searches: int = 12) -> float:
    rng = np.random.default_rng(0)
    system = ManuSystem(ManuConfig(
        num_query_nodes=1, seal_rows=100_000, manual_clock=False, threaded=True,
        tick_interval_ms=tick_ms,
    ))
    coll = system.create_collection("c", dim=DIM)
    stop = threading.Event()

    def inserter():
        while not stop.is_set():
            coll.insert({"vector": rng.standard_normal((20, DIM)).astype(np.float32)})
            time.sleep(0.01)

    t = threading.Thread(target=inserter, daemon=True)
    t.start()
    time.sleep(0.2)
    q = rng.standard_normal((1, DIM)).astype(np.float32)
    lats = []
    for _ in range(searches):
        t0 = time.perf_counter()
        coll.search(q, limit=5, staleness_ms=tau_ms)
        lats.append(time.perf_counter() - t0)
        time.sleep(0.005)
    stop.set()
    system.stop_threads()
    return float(np.mean(lats) * 1e6)


def main() -> list[tuple[str, float, str]]:
    rows = []
    for tick_ms in (10.0, 50.0):
        for tau_ms in (0.0, 25.0, 100.0, 1e9):
            us = latency_at(tau_ms, tick_ms)
            tau_label = "inf" if tau_ms >= 1e9 else f"{tau_ms:.0f}ms"
            rows.append((f"fig12-tick{tick_ms:.0f}ms-tau{tau_label}", us,
                         "latency_includes_consistency_wait"))
    return rows


if __name__ == "__main__":
    emit(main())
