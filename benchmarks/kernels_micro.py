"""Kernel microbenchmarks: host fast path vs Pallas interpret (correctness
path); on TPU the pallas path compiles natively.

``REPRO_BENCH_SMOKE=1`` switches to reduced sizes so the suite doubles as
a fast CI regression gate (seconds, not minutes).
"""

from __future__ import annotations

import os

import numpy as np

from repro.kernels import ops
from repro.kernels.l2_topk import l2_topk_pallas

from .common import emit, python_dedup_merge, timeit_us

import jax.numpy as jnp

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def merge_rows(rng) -> list[tuple[str, float, str]]:
    """merge_topk vs the Python dedup loop: the §3.6 reduce stage at
    nq queries x n_segments pools of k candidates each."""
    nq, n_seg, k = (32, 8, 20) if SMOKE else (64, 16, 50)
    m = n_seg * k
    s = np.abs(rng.standard_normal((nq, m))).astype(np.float32)
    p = rng.integers(0, m // 2, (nq, m)).astype(np.int64)  # heavy pk dup rate
    p[rng.random((nq, m)) < 0.05] = -1
    t_py = timeit_us(lambda: python_dedup_merge(s, p, k, "l2"), best_of=5)
    t_vec = timeit_us(lambda: ops.merge_topk(s, p, k, "l2"), best_of=5)
    speedup = t_py / max(t_vec, 1e-9)
    return [
        ("kern-merge_topk-python-loop", t_py, f"nq={nq},segs={n_seg},k={k}"),
        ("kern-merge_topk-vectorized", t_vec,
         f"nq={nq},segs={n_seg},k={k};speedup={speedup:.1f}x"),
    ]


def fused_scan_rows(rng) -> list[tuple[str, float, str]]:
    """topk_scan_segmented vs one topk_scan dispatch per segment: the
    batched-scan stage over many small segments."""
    nq, n_seg, rows, dim, k = (8, 4, 256, 32, 10) if SMOKE else (64, 64, 256, 128, 50)
    q = rng.standard_normal((nq, dim)).astype(np.float32)
    bases = [rng.standard_normal((rows, dim)).astype(np.float32) for _ in range(n_seg)]
    valids = [rng.random(rows) < 0.9 for _ in range(n_seg)]

    def per_segment():
        for b, v in zip(bases, valids):
            ops.topk_scan(q, b, k, metric="l2", valid=v)

    t_seg = timeit_us(per_segment, best_of=5)
    t_fused = timeit_us(
        lambda: ops.topk_scan_segmented(q, bases, k, metric="l2", valids=valids),
        best_of=5,
    )
    speedup = t_seg / max(t_fused, 1e-9)
    return [
        ("kern-scan-per-segment", t_seg, f"nq={nq},segs={n_seg}x{rows}x{dim},k={k}"),
        ("kern-scan-fused", t_fused,
         f"nq={nq},segs={n_seg}x{rows}x{dim},k={k};speedup={speedup:.1f}x"),
    ]


def delta_mask_rows(rng) -> list[tuple[str, float, str]]:
    """Per-request doomed-pk materialization + sorted probe vs the old
    per-segment np.array rebuild + np.isin (QueryNode delta-delete masks)."""
    n_del, n_seg, rows = (1_000, 4, 512) if SMOKE else (10_000, 16, 4_096)
    all_pks = rng.permutation(n_seg * rows)
    dd = {int(pk): 100 + i for i, pk in enumerate(all_pks[:n_del])}
    seg_pks = [
        np.sort(all_pks[s * rows : (s + 1) * rows]).astype(np.int64)
        for s in range(n_seg)
    ]
    ts = 1 << 60

    def per_segment():  # the seed path: rebuilt once per segment per query
        for pks in seg_pks:
            doomed = np.array([pk for pk, dts in dd.items() if dts <= ts])
            np.isin(pks, doomed)

    def per_request():  # materialize once, binary-search probe per segment
        pks_a = np.asarray(list(dd.keys()))
        dts_a = np.asarray(list(dd.values()), np.int64)
        doomed = np.sort(pks_a[dts_a <= ts])
        for pks in seg_pks:
            ops.isin_sorted(pks, doomed)

    t_old = timeit_us(per_segment, best_of=3)
    t_new = timeit_us(per_request, best_of=3)
    speedup = t_old / max(t_new, 1e-9)
    return [
        ("kern-delta-mask-per-segment", t_old, f"dels={n_del},segs={n_seg}"),
        ("kern-delta-mask-per-request", t_new,
         f"dels={n_del},segs={n_seg};speedup={speedup:.1f}x"),
    ]


def main() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    n, nq, k = (1_024, 8, 10) if SMOKE else (8_192, 32, 50)
    q = rng.standard_normal((nq, 128)).astype(np.float32)
    x = rng.standard_normal((n, 128)).astype(np.float32)
    rows.append(("kern-topk_scan-host", timeit_us(lambda: ops.topk_scan(q, x, k)),
                 f"{n}x128,k={k}"))
    qj, xj = jnp.asarray(q), jnp.asarray(x)
    vj = jnp.ones(n, jnp.int32)
    rows.append((
        "kern-l2topk-pallas-interpret",
        timeit_us(lambda: l2_topk_pallas(qj, xj, vj, k, tq=max(8, nq), tn=512,
                                         interpret=True).__getitem__(0).block_until_ready(),
                  warmup=1, iters=1),
        "interpret-mode(correctness-path)",
    ))
    luts = rng.standard_normal((8, 16, 256)).astype(np.float32)
    codes = rng.integers(0, 256, (n, 16)).astype(np.int32)
    rows.append(("kern-pq_adc-host", timeit_us(lambda: ops.pq_adc_topk(luts, codes, k)),
                 f"{n}x16sub"))
    vmin, vmax = x.min(0), x.max(0)
    c = ops.sq_encode(x, vmin, vmax)
    rows.append(("kern-sq_scan-host",
                 timeit_us(lambda: ops.sq_topk_scan(q, c, vmin, vmax, k)),
                 f"{n}x128-int8"))
    cents = rng.standard_normal((256, 128)).astype(np.float32)
    rows.append(("kern-kmeans_assign-host",
                 timeit_us(lambda: ops.kmeans_assign(x, cents)), f"{n}rows-256cents"))
    rows += merge_rows(rng)
    rows += fused_scan_rows(rng)
    rows += delta_mask_rows(rng)
    return rows


if __name__ == "__main__":
    emit(main())
