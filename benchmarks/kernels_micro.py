"""Kernel microbenchmarks: host fast path vs Pallas interpret (correctness
path); on TPU the pallas path compiles natively.

``REPRO_BENCH_SMOKE=1`` switches to reduced sizes so the suite doubles as
a fast CI regression gate (seconds, not minutes).
"""

from __future__ import annotations

import os

import numpy as np

from repro.kernels import ops
from repro.kernels.l2_topk import l2_topk_pallas

from .common import emit, python_dedup_merge, timeit_us

import jax.numpy as jnp

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def merge_rows(rng) -> list[tuple[str, float, str]]:
    """merge_topk vs the Python dedup loop: the §3.6 reduce stage at
    nq queries x n_segments pools of k candidates each."""
    nq, n_seg, k = (32, 8, 20) if SMOKE else (64, 16, 50)
    m = n_seg * k
    s = np.abs(rng.standard_normal((nq, m))).astype(np.float32)
    p = rng.integers(0, m // 2, (nq, m)).astype(np.int64)  # heavy pk dup rate
    p[rng.random((nq, m)) < 0.05] = -1
    t_py = timeit_us(lambda: python_dedup_merge(s, p, k, "l2"), best_of=5)
    t_vec = timeit_us(lambda: ops.merge_topk(s, p, k, "l2"), best_of=5)
    speedup = t_py / max(t_vec, 1e-9)
    return [
        ("kern-merge_topk-python-loop", t_py, f"nq={nq},segs={n_seg},k={k}"),
        ("kern-merge_topk-vectorized", t_vec,
         f"nq={nq},segs={n_seg},k={k};speedup={speedup:.1f}x"),
    ]


def fused_scan_rows(rng) -> list[tuple[str, float, str]]:
    """topk_scan_segmented vs one topk_scan dispatch per segment: the
    batched-scan stage over many small segments."""
    nq, n_seg, rows, dim, k = (8, 4, 256, 32, 10) if SMOKE else (64, 64, 256, 128, 50)
    q = rng.standard_normal((nq, dim)).astype(np.float32)
    bases = [rng.standard_normal((rows, dim)).astype(np.float32) for _ in range(n_seg)]
    valids = [rng.random(rows) < 0.9 for _ in range(n_seg)]

    def per_segment():
        for b, v in zip(bases, valids):
            ops.topk_scan(q, b, k, metric="l2", valid=v)

    t_seg = timeit_us(per_segment, best_of=5)
    t_fused = timeit_us(
        lambda: ops.topk_scan_segmented(q, bases, k, metric="l2", valids=valids),
        best_of=5,
    )
    speedup = t_seg / max(t_fused, 1e-9)
    return [
        ("kern-scan-per-segment", t_seg, f"nq={nq},segs={n_seg}x{rows}x{dim},k={k}"),
        ("kern-scan-fused", t_fused,
         f"nq={nq},segs={n_seg}x{rows}x{dim},k={k};speedup={speedup:.1f}x"),
    ]


def delta_mask_rows(rng) -> list[tuple[str, float, str]]:
    """Per-request doomed-pk materialization + sorted probe vs the old
    per-segment np.array rebuild + np.isin (QueryNode delta-delete masks)."""
    n_del, n_seg, rows = (1_000, 4, 512) if SMOKE else (10_000, 16, 4_096)
    all_pks = rng.permutation(n_seg * rows)
    dd = {int(pk): 100 + i for i, pk in enumerate(all_pks[:n_del])}
    seg_pks = [
        np.sort(all_pks[s * rows : (s + 1) * rows]).astype(np.int64)
        for s in range(n_seg)
    ]
    ts = 1 << 60

    def per_segment():  # the seed path: rebuilt once per segment per query
        for pks in seg_pks:
            doomed = np.array([pk for pk, dts in dd.items() if dts <= ts])
            np.isin(pks, doomed)

    def per_request():  # materialize once, binary-search probe per segment
        pks_a = np.asarray(list(dd.keys()))
        dts_a = np.asarray(list(dd.values()), np.int64)
        doomed = np.sort(pks_a[dts_a <= ts])
        for pks in seg_pks:
            ops.isin_sorted(pks, doomed)

    t_old = timeit_us(per_segment, best_of=3)
    t_new = timeit_us(per_request, best_of=3)
    speedup = t_old / max(t_new, 1e-9)
    return [
        ("kern-delta-mask-per-segment", t_old, f"dels={n_del},segs={n_seg}"),
        ("kern-delta-mask-per-request", t_new,
         f"dels={n_del},segs={n_seg};speedup={speedup:.1f}x"),
    ]


def hybrid_fuse_rows(rng) -> list[tuple[str, float, str]]:
    """ops.hybrid_fuse (weighted + RRF) vs a per-row dict-accumulate loop:
    the proxy-side fusion stage of multi-vector hybrid requests."""
    nq, k, n_fields = (16, 10, 2) if SMOKE else (64, 50, 3)
    pool = k
    scores = [
        np.sort(np.abs(rng.standard_normal((nq, pool))).astype(np.float32), axis=1)
        for _ in range(n_fields)
    ]
    pks = [
        rng.integers(0, pool * 2, (nq, pool)).astype(np.int64)
        for _ in range(n_fields)
    ]
    weights = [1.0] * n_fields

    def python_fuse():
        out = []
        for r in range(nq):
            acc: dict[int, float] = {}
            for f in range(n_fields):
                for rank in range(pool):
                    pk = int(pks[f][r, rank])
                    if pk < 0:
                        continue
                    sim = 1.0 / (1.0 + max(float(scores[f][r, rank]), 0.0))
                    acc[pk] = acc.get(pk, 0.0) + weights[f] * sim
            out.append(sorted(acc.items(), key=lambda kv: -kv[1])[:k])
        return out

    t_py = timeit_us(python_fuse, best_of=5)
    t_w = timeit_us(
        lambda: ops.hybrid_fuse(scores, pks, k, "l2", weights, kind="weighted"),
        best_of=5,
    )
    t_rrf = timeit_us(
        lambda: ops.hybrid_fuse(scores, pks, k, "l2", weights, kind="rrf"),
        best_of=5,
    )
    shape = f"nq={nq},fields={n_fields},k={k}"
    return [
        ("kern-hybrid-fuse-python-loop", t_py, shape),
        ("kern-hybrid-fuse-weighted", t_w,
         f"{shape};speedup={t_py / max(t_w, 1e-9):.1f}x"),
        ("kern-hybrid-fuse-rrf", t_rrf, shape),
    ]


def filtered_range_rows(rng) -> list[tuple[str, float, str]]:
    """The filtered + range read path: fused brute scan under a selective
    attribute mask, and the post-scan radius cut on merged results."""
    nq, n_seg, rows, dim, k = (8, 4, 256, 32, 10) if SMOKE else (64, 16, 1_024, 128, 50)
    q = rng.standard_normal((nq, dim)).astype(np.float32)
    bases = [rng.standard_normal((rows, dim)).astype(np.float32) for _ in range(n_seg)]
    # 10% selectivity: the attribute filter's typical hot case
    valids = [rng.random(rows) < 0.1 for _ in range(n_seg)]
    t_filtered = timeit_us(
        lambda: ops.topk_scan_segmented(q, bases, k, metric="l2", valids=valids),
        best_of=5,
    )
    s, p = ops.topk_scan_segmented(q, bases, k, metric="l2")
    radius = float(np.median(s))
    t_cut = timeit_us(
        lambda: ops.range_cut(s, p, "l2", radius, radius * 0.2), best_of=5
    )
    shape = f"nq={nq},segs={n_seg}x{rows}x{dim},k={k}"
    return [
        ("kern-scan-filtered-10pct", t_filtered, f"{shape};sel=0.1"),
        ("kern-range-cut", t_cut, f"nq={nq},m={s.shape[1]}"),
    ]


def filter_plan_rows(rng) -> list[tuple[str, float, str]]:
    """The filtered-search planner's per-request kernels: attribute-index
    bitmap resolution vs row-wise FilterExpr evaluation, visibility-and-
    filter mask intersection, and the post-filter interloper cut."""
    from repro.index.attribute import FilterExpr, build_attribute_index

    n, nq, k = (50_000, 8, 10) if SMOKE else (500_000, 32, 50)
    price = rng.uniform(0, 100, n)
    label = rng.choice(np.array(["a", "b", "c", "d"]), n)
    cols = {"price": price, "label": label}
    attrs = {f: build_attribute_index(v) for f, v in cols.items()}
    expr = FilterExpr("price < 30 and label == 'a'")

    t_rowwise = timeit_us(lambda: expr.evaluate(cols, n), best_of=5)
    t_bitmap = timeit_us(lambda: expr.bitmap(attrs, n), best_of=5)
    speedup = t_rowwise / max(t_bitmap, 1e-9)
    rows = [
        ("kern-filter-rowwise", t_rowwise, f"n={n},clauses=2"),
        ("kern-filter-bitmap", t_bitmap,
         f"n={n},clauses=2;speedup={speedup:.1f}x"),
    ]

    vis = rng.random(n) < 0.95
    fmask = expr.bitmap(attrs, n)
    rows.append((
        "kern-filter-intersect",
        timeit_us(lambda: ops.mask_intersect(vis, fmask), best_of=5),
        f"n={n}",
    ))

    m = 16 * k  # a pooled candidate list at post-filter width
    scores = np.abs(rng.standard_normal((nq, m))).astype(np.float32)
    idx = rng.integers(0, n, (nq, m)).astype(np.int64)
    idx[rng.random((nq, m)) < 0.05] = -1
    rows.append((
        "kern-filter-postcut",
        timeit_us(lambda: ops.post_filter_cut(scores, idx, fmask, "l2"),
                  best_of=5),
        f"nq={nq},m={m}",
    ))
    return rows


def ingest_rows(rng) -> list[tuple[str, float, str]]:
    """Write-pipeline shard split: the seed per-row ``shard_of_pk`` Python
    loop + boolean masks vs one vectorized hash + bincount/argsort scatter
    (what ``Logger.mutate`` runs on every insert/upsert batch)."""
    from repro.core.log import shard_of_pk, shards_of_pks

    n, shards = (20_000, 4) if SMOKE else (200_000, 4)
    pks = rng.permutation(n).astype(np.int64)

    def python_loop():
        sh = np.array([shard_of_pk(int(pk), shards) for pk in pks.tolist()])
        return [pks[sh == s] for s in range(shards)]

    def vectorized():
        order, offsets = ops.shard_split(shards_of_pks(pks, shards), shards)
        return [pks[order[offsets[s] : offsets[s + 1]]] for s in range(shards)]

    t_py = timeit_us(python_loop, best_of=3)
    t_vec = timeit_us(vectorized, best_of=3)
    shape = f"n={n},shards={shards}"
    return [
        ("kern-ingest-shardsplit-python-loop", t_py, shape),
        ("kern-ingest-shardsplit-vectorized", t_vec,
         f"{shape};speedup={t_py / max(t_vec, 1e-9):.1f}x"),
    ]


def upsert_rows(rng) -> list[tuple[str, float, str]]:
    """kern-upsert: one atomic UPSERT record per shard vs the delete+insert
    pair through the same proxy -> logger -> WAL pipeline (two LSNs, twice
    the records, plus a second shard split)."""
    from repro.core import (
        DeleteRequest,
        InsertRequest,
        ManuConfig,
        ManuSystem,
        UpsertRequest,
    )

    n, dim, batch = (4_000, 16, 512) if SMOKE else (32_000, 32, 4_096)
    system = ManuSystem(ManuConfig(num_shards=2, seal_rows=1 << 30,
                                   num_query_nodes=1))
    coll = system.create_collection("w", dim=dim)
    pks = np.arange(n, dtype=np.int64)
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    system.proxy.mutate(coll.info, InsertRequest({"pk": pks, "vector": vecs}))
    sel = rng.choice(n, batch, replace=False)
    newv = rng.standard_normal((batch, dim)).astype(np.float32)
    rows = {"pk": pks[sel], "vector": newv}

    t_pair = timeit_us(
        lambda: (
            system.proxy.mutate(coll.info, DeleteRequest(pks[sel])),
            system.proxy.mutate(coll.info, InsertRequest(rows)),
        ),
        best_of=3,
    )
    t_up = timeit_us(
        lambda: system.proxy.mutate(coll.info, UpsertRequest(rows)), best_of=3
    )
    shape = f"batch={batch},dim={dim},shards=2"
    return [
        ("kern-upsert-delete-insert-pair", t_pair, shape),
        ("kern-upsert-atomic", t_up,
         f"{shape};speedup={t_pair / max(t_up, 1e-9):.1f}x"),
    ]


def _make_ivf_flat(x, nlist, nprobe, rng):
    """CSR-partition ``x`` with sampled centroids (one assignment pass —
    the scan benchmarks measure search, not k-means)."""
    from repro.core.collection import Metric
    from repro.index.ivf import IVFFlatIndex

    cents = x[rng.choice(len(x), nlist, replace=False)]
    assign, _ = ops.kmeans_assign(x, cents)
    order = np.argsort(assign, kind="stable")
    counts = np.bincount(assign, minlength=nlist)
    idx = IVFFlatIndex(metric=Metric.L2, nlist=nlist, nprobe=nprobe)
    idx.centroids = cents
    idx.list_offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    idx.row_ids = order.astype(np.int64)
    idx.storage = x[order]
    idx.num_rows = len(x)
    return idx, counts, order


def ivf_rows(rng) -> list[tuple[str, float, str]]:
    """Batched CSR probe-gather-scan engine vs the scalar per-list
    reference loop (the seed IVF search): single sealed index for
    FLAT/SQ/PQ, plus the segment-parallel configuration where
    ``search_batched`` replaces one ``index.search`` per co-located
    segment.  Timings cover the scan+merge phase (probe is shared and
    <1% of either path)."""
    from repro.core.collection import Metric
    from repro.index.ivf import IVFPQIndex, IVFSQIndex
    from repro.index.pq import pq_encode
    from .common import queries_from, sift_like

    if SMOKE:
        n, dim, nlist, nprobe, nq, k = 20_000, 64, 64, 8, 16, 10
        n_seg, m = 4, 8
    else:
        n, dim, nlist, nprobe, nq, k = 200_000, 128, 256, 16, 64, 10
        n_seg, m = 8, 8
    x = sift_like(n, dim, n_clusters=nlist)
    q = queries_from(x, nq)
    rows: list[tuple[str, float, str]] = []
    shape = f"nq={nq},nlist={nlist},nprobe={nprobe},{n}x{dim},k={k}"

    def pair(tag, idx):
        t_ref = timeit_us(lambda: idx._search_reference(q, k), iters=1, best_of=3)
        t_new = timeit_us(lambda: idx.search(q, k), iters=1, best_of=3)
        speedup = t_ref / max(t_new, 1e-9)
        rows.append((f"kern-ivf-{tag}-reference", t_ref, shape))
        rows.append(
            (f"kern-ivf-{tag}-batched", t_new, f"{shape};speedup={speedup:.1f}x")
        )

    flat, counts, order = _make_ivf_flat(x, nlist, nprobe, rng)
    xp = x[order]
    pair("flat", flat)

    sq = IVFSQIndex(metric=Metric.L2, nlist=nlist, nprobe=nprobe)
    sq.centroids, sq.list_offsets, sq.row_ids = (
        flat.centroids, flat.list_offsets, flat.row_ids,
    )
    sq.num_rows = n
    sq.vmin, sq.vmax = xp.min(0), xp.max(0)
    sq.codes = ops.sq_encode(xp, sq.vmin, sq.vmax)
    pair("sq", sq)

    ksub = 64 if SMOKE else 256
    pq = IVFPQIndex(metric=Metric.L2, nlist=nlist, nprobe=nprobe, m=m, ksub=ksub)
    pq.centroids, pq.list_offsets, pq.row_ids = (
        flat.centroids, flat.list_offsets, flat.row_ids,
    )
    pq.num_rows = n
    pq.codebooks = (rng.standard_normal((m, ksub, dim // m)) * 0.5).astype(np.float32)
    pq.codes = pq_encode(xp - flat.centroids[np.repeat(np.arange(nlist), counts)],
                         pq.codebooks)
    pq._perm_assign = np.repeat(np.arange(nlist), counts).astype(np.int32)
    pair("pq", pq)

    # Segment-parallel: the same rows served as co-located sealed segments.
    # Seed = one scalar index.search per segment + node merge; batched =
    # ONE search_batched candidate-pool dispatch + one merge.
    rows_seg = n // n_seg
    segs = [
        _make_ivf_flat(x[s * rows_seg : (s + 1) * rows_seg], nlist, nprobe, rng)[0]
        for s in range(n_seg)
    ]

    def seed_node_scan():
        pool_s, pool_i = [], []
        for u, idx in enumerate(segs):
            s, i = idx._search_reference(q, k)
            pool_i.append(np.where(i >= 0, i + u * rows_seg, -1))
            pool_s.append(s)
        return ops.merge_topk(
            np.concatenate(pool_s, 1), np.concatenate(pool_i, 1), k, "l2"
        )

    def batched_node_scan():
        from repro.index.ivf import IVFFlatIndex

        s, i, splits = IVFFlatIndex.search_batched(segs, q, k)
        i = np.concatenate(
            [
                np.where(
                    i[:, splits[u] : splits[u + 1]] >= 0,
                    i[:, splits[u] : splits[u + 1]] + u * rows_seg,
                    -1,
                )
                for u in range(n_seg)
            ],
            axis=1,
        )
        return ops.merge_topk(s, i, k, "l2")

    t_ref = timeit_us(seed_node_scan, iters=1, best_of=3)
    t_new = timeit_us(batched_node_scan, iters=1, best_of=3)
    mshape = f"nq={nq},segs={n_seg}x{rows_seg},nlist={nlist},nprobe={nprobe},k={k}"
    rows.append(("kern-ivf-multiseg-reference", t_ref, mshape))
    rows.append(
        (
            "kern-ivf-multiseg-batched",
            t_new,
            f"{mshape};speedup={t_ref / max(t_new, 1e-9):.1f}x",
        )
    )
    return rows


def telemetry_overhead_rows(rng) -> list[tuple[str, float, str]]:
    """Registry-on vs registry-off cost of the QueryNode scan path's
    instrumentation (one ``observe`` + one labelled ``inc`` per scan,
    exactly what ``_execute_plan`` records).  The derived field carries
    the relative overhead; the CI smoke gate holds it at <= 3%."""
    from repro.core.telemetry import MetricsRegistry

    if SMOKE:
        n, dim, nlist, nprobe, nq, k = 20_000, 64, 64, 8, 16, 10
    else:
        n, dim, nlist, nprobe, nq, k = 100_000, 128, 256, 16, 32, 10
    from .common import queries_from, sift_like

    x = sift_like(n, dim, n_clusters=nlist)
    q = queries_from(x, nq)
    idx, _, _ = _make_ivf_flat(x, nlist, nprobe, rng)
    reg = MetricsRegistry()

    def bare():
        return idx.search(q, k)

    def instrumented():
        import time as _t

        t0 = _t.perf_counter()
        out = idx.search(q, k)
        reg.observe("query_node_scan_us", (_t.perf_counter() - t0) * 1e6,
                    labels={"class": "indexed"})
        reg.inc("query_node_rows_scanned_total", n,
                labels={"class": "indexed"})
        return out

    t_off = timeit_us(bare, iters=3, best_of=3)
    t_on = timeit_us(instrumented, iters=3, best_of=3)
    overhead = (t_on - t_off) / max(t_off, 1e-9) * 100.0
    shape = f"nq={nq},{n}x{dim},nlist={nlist},nprobe={nprobe}"
    return [(
        "kern-telemetry-overhead",
        t_on,
        f"{shape};bare_us={t_off:.1f};overhead={overhead:.2f}%",
    )]


def sched_batch_rows(rng) -> list[tuple[str, float, str]]:
    """Serving-tier scheduler overhead: N async admissions + one
    micro-batched flush vs N direct ``Proxy.mutate`` calls, per-request
    cost.  Admission pays validate/route/queue bookkeeping but the flush
    amortizes the proxy/logger crossing and its lock, so the overhead must
    stay small; the CI smoke gate holds it at <= 3%."""
    from repro.core import InsertRequest, ManuConfig, ManuSystem, RequestScheduler

    n_req, rows_per = (8, 64) if SMOKE else (16, 64)
    dim = 32

    def build():
        system = ManuSystem(ManuConfig(
            num_query_nodes=1, num_shards=1, seal_rows=100_000_000,
        ))
        coll = system.create_collection("c", dim=dim)
        batch = rng.standard_normal((rows_per, dim)).astype(np.float32)
        return system, coll, batch

    # Separate identically-built systems: state growth during the timing
    # loop (pk allocation, WAL append) stays symmetric across the paths.
    d_system, d_coll, d_batch = build()
    s_system, s_coll, s_batch = build()
    # Standalone scheduler (no on_flush pump): both paths stop at the WAL.
    sched = RequestScheduler(
        s_system.proxy, clock=s_system.clock, queue_rows=1 << 30,
        flush_rows=1 << 30, flush_interval_ms=1e12,
        metrics=s_system.telemetry,
    )

    def direct():
        for _ in range(n_req):
            d_system.proxy.mutate(d_coll.info, InsertRequest({"vector": d_batch}))

    def scheduled():
        for _ in range(n_req):
            sched.submit_mutation(s_coll.info, InsertRequest({"vector": s_batch}))
        sched.flush_writes()

    import time as _time

    def measure(fn, iters=3):
        t0 = _time.perf_counter()
        for _ in range(iters):
            fn()
        return (_time.perf_counter() - t0) / iters * 1e6

    direct()
    scheduled()  # warmup both paths
    t_direct = t_sched = float("inf")
    # Interleaved best-of: CPU frequency drift mid-benchmark would bias a
    # sequential A-then-B comparison.
    for _ in range(5):
        t_direct = min(t_direct, measure(direct))
        t_sched = min(t_sched, measure(scheduled))
    t_direct /= n_req
    t_sched /= n_req
    overhead = (t_sched - t_direct) / max(t_direct, 1e-9) * 100.0
    return [(
        "kern-sched-batch",
        t_sched,
        f"reqs={n_req}x{rows_per}rows;direct_us={t_direct:.1f};"
        f"overhead={overhead:.2f}%",
    )]


def main() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    n, nq, k = (1_024, 8, 10) if SMOKE else (8_192, 32, 50)
    q = rng.standard_normal((nq, 128)).astype(np.float32)
    x = rng.standard_normal((n, 128)).astype(np.float32)
    rows.append(("kern-topk_scan-host", timeit_us(lambda: ops.topk_scan(q, x, k)),
                 f"{n}x128,k={k}"))
    qj, xj = jnp.asarray(q), jnp.asarray(x)
    vj = jnp.ones(n, jnp.int32)
    rows.append((
        "kern-l2topk-pallas-interpret",
        timeit_us(lambda: l2_topk_pallas(qj, xj, vj, k, tq=max(8, nq), tn=512,
                                         interpret=True).__getitem__(0).block_until_ready(),
                  warmup=1, iters=1),
        "interpret-mode(correctness-path)",
    ))
    luts = rng.standard_normal((8, 16, 256)).astype(np.float32)
    codes = rng.integers(0, 256, (n, 16)).astype(np.int32)
    rows.append(("kern-pq_adc-host", timeit_us(lambda: ops.pq_adc_topk(luts, codes, k)),
                 f"{n}x16sub"))
    vmin, vmax = x.min(0), x.max(0)
    c = ops.sq_encode(x, vmin, vmax)
    rows.append(("kern-sq_scan-host",
                 timeit_us(lambda: ops.sq_topk_scan(q, c, vmin, vmax, k)),
                 f"{n}x128-int8"))
    cents = rng.standard_normal((256, 128)).astype(np.float32)
    rows.append(("kern-kmeans_assign-host",
                 timeit_us(lambda: ops.kmeans_assign(x, cents)), f"{n}rows-256cents"))
    rows += merge_rows(rng)
    rows += fused_scan_rows(rng)
    rows += delta_mask_rows(rng)
    rows += hybrid_fuse_rows(rng)
    rows += filtered_range_rows(rng)
    rows += filter_plan_rows(rng)
    rows += ingest_rows(rng)
    rows += upsert_rows(rng)
    rows += ivf_rows(rng)
    rows += telemetry_overhead_rows(rng)
    rows += sched_batch_rows(rng)
    return rows


if __name__ == "__main__":
    emit(main())
