"""Kernel microbenchmarks: host fast path vs Pallas interpret (correctness
path); on TPU the pallas path compiles natively."""

from __future__ import annotations

import numpy as np

from repro.kernels import ops
from repro.kernels.l2_topk import l2_topk_pallas

from .common import emit, timeit_us

import jax.numpy as jnp


def main() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    q = rng.standard_normal((32, 128)).astype(np.float32)
    x = rng.standard_normal((8_192, 128)).astype(np.float32)
    rows.append(("kern-topk_scan-host", timeit_us(lambda: ops.topk_scan(q, x, 50)),
                 "8192x128,k=50"))
    qj, xj = jnp.asarray(q), jnp.asarray(x)
    vj = jnp.ones(8_192, jnp.int32)
    rows.append((
        "kern-l2topk-pallas-interpret",
        timeit_us(lambda: l2_topk_pallas(qj[:32], xj, vj, 50, tq=32, tn=512,
                                         interpret=True).__getitem__(0).block_until_ready(),
                  warmup=1, iters=1),
        "interpret-mode(correctness-path)",
    ))
    luts = rng.standard_normal((8, 16, 256)).astype(np.float32)
    codes = rng.integers(0, 256, (8_192, 16)).astype(np.int32)
    rows.append(("kern-pq_adc-host", timeit_us(lambda: ops.pq_adc_topk(luts, codes, 50)),
                 "8192x16sub"))
    vmin, vmax = x.min(0), x.max(0)
    c = ops.sq_encode(x, vmin, vmax)
    rows.append(("kern-sq_scan-host",
                 timeit_us(lambda: ops.sq_topk_scan(q, c, vmin, vmax, 50)), "8192x128-int8"))
    cents = rng.standard_normal((256, 128)).astype(np.float32)
    rows.append(("kern-kmeans_assign-host",
                 timeit_us(lambda: ops.kmeans_assign(x, cents)), "8192rows-256cents"))
    return rows


if __name__ == "__main__":
    emit(main())
