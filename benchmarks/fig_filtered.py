"""fig_filtered: filtered search across the selectivity spectrum.

Mechanism under test (attribute-index subsystem + adaptive planner): a
fixed filtered-search strategy has a regime where it wins and a regime
where it collapses — post-filter inflates k by the worst-case interloper
bound (k' ~ segment size when the filter is tight), brute-filter
gathers and copies the surviving rows per request (catastrophic once
most rows survive), pre-filter pays the full fused masked scan even
when almost nothing does.  The planner reads the per-segment attribute
satellites, estimates selectivity per (segment, filter) unit, and must
track the best fixed strategy everywhere without knowing the regime in
advance.

The collection carries no vector index, so every strategy is exact:
recall@k against the row-wise oracle must be 1.0 for all of them — the
sweep measures pure strategy cost, not quality trade-offs.

Measurement notes (single-vCPU CI boxes are noisy): strategies are
timed round-robin within each round with a warmup call per block, the
per-strategy figure is the min over rounds, and adaptive is measured
immediately after pre — its expensive-regime twin — so cache state
cannot separate two runs of the same code path (brute's per-request
gathers evict the dataset; whoever runs right after it pays that back).

Emits, per selectivity s in {0.001, 0.01, 0.1, 0.5, 0.9}:
    fig_filtered-sel<s>-{pre,post,brute,adaptive}   us/search (recall, qps)
    fig_filtered-sel<s>-summary                     adaptive vs best/worst
and one acceptance row:
    fig_filtered-adaptive-summary   worst-case adaptive/best ratio across
                                    the sweep + extreme-regime speedups
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import FieldSchema, FieldType, ManuConfig, ManuSystem, SearchRequest

from .common import emit

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

SELECTIVITIES = (0.001, 0.01, 0.1, 0.5, 0.9)
# adaptive deliberately second: right after its expensive-regime twin
STRATEGIES = ("pre", "adaptive", "post", "brute")


def main() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(7)
    n, dim, seal, nq, k, iters, rounds = (
        (8_192, 64, 512, 2, 10, 2, 3) if SMOKE else (32_768, 512, 512, 2, 100, 2, 12)
    )
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, seal_rows=seal, slice_rows=seal // 4)
    )
    coll = system.create_collection(
        "c", dim=dim, seal_rows=seal,
        extra_fields=[FieldSchema("price", FieldType.FLOAT)],
    )
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    # price = row rank / n: "price < s" passes exactly floor(n*s) rows,
    # so the sweep's selectivities are exact, not approximate
    price = (rng.permutation(n) / n).astype(np.float64)
    for lo in range(0, n, seal):
        coll.insert({"vector": vecs[lo : lo + seal], "price": price[lo : lo + seal]})
    coll.flush()
    q = rng.standard_normal((nq, dim)).astype(np.float32)

    def oracle_topk(sel: float) -> np.ndarray:
        keep = np.nonzero(price < sel)[0]
        base = vecs[keep]
        d = (
            np.sum(q**2, 1, keepdims=True)
            - 2 * q @ base.T
            + np.sum(base**2, 1)
        )
        return keep[np.argsort(d, axis=1, kind="stable")[:, :k]]

    rows: list[tuple[str, float, str]] = []
    worst_ratio = 0.0  # max over sels of adaptive/best-fixed
    extreme_speedups = []  # adaptive speedup vs worst fixed at 0.001 / 0.9
    for sel in SELECTIVITIES:
        expr = f"price < {sel}"
        want = oracle_topk(sel)
        reqs = {
            strat: SearchRequest.single(
                q, k=k, filter=expr,
                filter_strategy=None if strat == "adaptive" else strat,
                staleness_ms=0.0,
            )
            for strat in STRATEGIES
        }
        cell_recall: dict[str, float] = {}
        for strat, req in reqs.items():
            res = coll.search(req)
            hits = sum(
                len(set(res.pks[r][res.pks[r] >= 0].tolist())
                    & set(want[r].tolist()))
                for r in range(nq)
            )
            denom = nq * min(k, want.shape[1])
            cell_recall[strat] = hits / denom if denom else 1.0
        cell_us = {strat: float("inf") for strat in STRATEGIES}
        for _ in range(rounds):
            for strat in STRATEGIES:
                req = reqs[strat]
                coll.search(req)  # warm this path's cache footprint back in
                t0 = time.perf_counter()
                for _ in range(iters):
                    coll.search(req)
                cell_us[strat] = min(
                    cell_us[strat], (time.perf_counter() - t0) / iters * 1e6
                )
        best_fixed = min(cell_us[s] for s in ("pre", "post", "brute"))
        worst_fixed = max(cell_us[s] for s in ("pre", "post", "brute"))
        ratio = cell_us["adaptive"] / best_fixed
        worst_ratio = max(worst_ratio, ratio)
        if sel in (SELECTIVITIES[0], SELECTIVITIES[-1]):
            extreme_speedups.append(worst_fixed / cell_us["adaptive"])
        for strat in ("pre", "post", "brute", "adaptive"):
            us = cell_us[strat]
            rows.append((
                f"fig_filtered-sel{sel}-{strat}",
                us,
                f"recall={cell_recall[strat]:.3f};qps={1e6 / us:.1f};"
                f"rows_pass={int(n * sel)}",
            ))
        rows.append((
            f"fig_filtered-sel{sel}-summary",
            cell_us["adaptive"],
            f"vs_best={ratio:.2f}x;vs_worst={worst_fixed / cell_us['adaptive']:.2f}x",
        ))
    rows.append((
        "fig_filtered-adaptive-summary",
        worst_ratio * 100.0,  # percent of best-fixed, worst case
        f"max_vs_best={worst_ratio:.2f}x;"
        f"extreme_speedups={','.join(f'{x:.1f}x' for x in extreme_speedups)};"
        f"within5={worst_ratio <= 1.05};"
        f"ge2x_extremes={all(x >= 2.0 for x in extreme_speedups)}",
    ))
    return rows


if __name__ == "__main__":
    emit(main())
