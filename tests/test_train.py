"""Training substrate: optimizer behaviour, step-atomic checkpoint/restart,
resume-after-crash, gradient accumulation equivalence."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core.object_store import MemoryObjectStore
from repro.models import model as M
from repro.train.checkpoint import (
    committed_steps,
    prune_checkpoints,
    restore_latest,
    save_checkpoint,
)
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=1)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # below the threshold: untouched
    g2 = {"a": jnp.full((4,), 0.01)}
    same, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g2["a"]))


def test_checkpoint_roundtrip_and_atomicity():
    store = MemoryObjectStore()
    cfg = ARCHS["yi-9b"].reduced(num_layers=2, d_model=32, num_heads=2,
                                 num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64)
    params = M.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    save_checkpoint(store, "run", 10, params, opt, extra={"note": "x"})
    save_checkpoint(store, "run", 20, params, opt)
    assert committed_steps(store, "run") == [10, 20]
    step, p2, o2, _ = restore_latest(store, "run", params, opt)
    assert step == 20
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        params, p2,
    )
    # a partial checkpoint (no manifest) is invisible
    store.put("ckpt/run/0000000030/params/embed", b"garbage")
    assert committed_steps(store, "run") == [10, 20]
    prune_checkpoints(store, "run", keep=1)
    assert committed_steps(store, "run") == [20]


def test_train_resume_is_seamless():
    store = MemoryObjectStore()
    cfg = ARCHS["yi-9b"].reduced(num_layers=2, d_model=32, num_heads=2,
                                 num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64)
    tc = TrainConfig(steps=6, batch=2, seq_len=16, checkpoint_every=3,
                     log_every=100, run_name="resume-test")
    # full run
    _p_full, _o, losses_full = train(cfg, store, tc)
    # interrupted run: 3 steps, then a fresh process resumes to 6
    store2 = MemoryObjectStore()
    tc3 = TrainConfig(steps=3, batch=2, seq_len=16, checkpoint_every=3,
                      log_every=100, run_name="resume-test")
    train(cfg, store2, tc3)
    _p_res, _o2, losses_res = train(cfg, store2, tc)  # resumes at step 3
    assert len(losses_res) == 3  # only steps 3..6 executed
    np.testing.assert_allclose(losses_full[3:], losses_res, rtol=2e-4, atol=2e-4)


def test_microbatch_grads_match_full_batch():
    """Gradient accumulation over N microbatches == one full-batch step."""
    from repro.launch.steps import build_train_cell
    from repro.models.config import ShapeConfig

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ARCHS["yi-9b"].reduced(num_layers=2, d_model=32, num_heads=2,
                                 num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64)
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (4, 16), 0, 64),
        "labels": jax.random.randint(jax.random.key(2), (4, 16), 0, 64),
    }
    params = M.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    outs = []
    with mesh:
        for mb in (1, 2):
            step, shardings, _structs, _don = build_train_cell(
                cfg, shape, mesh, microbatches=mb)
            p2, _o2, metrics = jax.jit(step)(params, opt, batch)
            outs.append((p2, float(metrics["loss"])))
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-3)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-3),
        outs[0][0], outs[1][0],
    )
