"""Chaos tests for the elasticity control plane (paper §6.3): replica
groups, the HealthMonitor/StateReconciler loop, replica-aware dispatch
with mid-request failover, and the typed cluster-admin API
(``ManuSystem.cluster_state()`` / ``ManuCollection.describe()``)."""

import time

import numpy as np
import pytest

from repro.core import ManuConfig, ManuSystem, SearchRequest


def ingest(coll, rng, n, dim, batches=4):
    vecs = rng.standard_normal((n, dim)).astype(np.float32)
    step = n // batches
    for i in range(batches):
        coll.insert({"vector": vecs[i * step : (i + 1) * step]})
    return vecs


def sorted_rows(res):
    """(pks, scores) of a SearchResult, row-sorted by pk for bit-for-bit
    comparison independent of merge order."""
    order = np.argsort(res.pks, axis=1)
    return (
        np.take_along_axis(res.pks, order, 1),
        np.take_along_axis(res.scores, order, 1),
    )


# --------------------------------------------------------- replica groups


def test_replica_groups_full_replication(rng):
    """rf=2 over 3 nodes: every sealed segment gets two distinct replicas,
    each with the copy actually loaded, and nothing is under-replicated."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=3, replication_factor=2, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 1000, 8, batches=5)
    coll.flush()
    sealed = system.data_coord.sealed_segments("c")
    assert len(sealed) >= 3
    cs = system.cluster_state()
    assert cs.replication_factor == 2
    assert cs.under_replicated == 0
    for sid in sealed:
        reps = cs.replicas_of("c", sid)
        assert len(reps) == 2 and len(set(reps)) == 2
        for n in reps:
            assert ("c", sid) in system.query_nodes[n].sealed
    # replicated reads return unique pks (dedup at the global reduce)
    q = rng.standard_normal((2, 8)).astype(np.float32)
    res = coll.search(q, limit=10, staleness_ms=0.0)
    for r in range(2):
        live = res.pks[r][res.pks[r] >= 0]
        assert len(set(live.tolist())) == len(live) == 10


def test_replication_factor_validated(rng):
    system = ManuSystem(ManuConfig(num_query_nodes=1))
    with pytest.raises(ValueError):
        system.create_collection("bad", dim=4, replication_factor=0)
    with pytest.raises(ValueError):
        system.create_collection("bad2", dim=4, replication_factor=1.5)


def test_per_collection_override_degrades_gracefully(rng):
    """replication_factor=3 on a 2-node cluster must not raise: placements
    commit with 2 replicas and a recorded under-replication flag."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, replication_factor=1, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8, replication_factor=3)
    assert coll.describe().replication_factor == 3
    ingest(coll, rng, 600, 8, batches=3)
    coll.flush()
    cs = system.cluster_state()
    placed = [p for p in cs.placement if p.collection == "c"]
    assert placed
    for p in placed:
        assert len(p.replicas) == 2  # capacity-limited, not raised
        assert p.under_replicated
    assert cs.under_replicated == len(placed)
    q = rng.standard_normal((1, 8)).astype(np.float32)
    assert (coll.search(q, limit=5, staleness_ms=0.0).pks[0] >= 0).all()


# ------------------------------------------------------------- failover


def test_kill_node_mid_search_bit_for_bit(rng):
    """A node dying between planning and scan: the proxy reports it to the
    control loop, re-dispatches to surviving replicas, and the answer is
    bit-for-bit the single-node oracle's."""
    dim, n = 8, 900
    oracle_sys = ManuSystem(
        ManuConfig(num_query_nodes=1, seal_rows=200, num_shards=2)
    )
    system = ManuSystem(
        ManuConfig(
            num_query_nodes=3, replication_factor=2, seal_rows=200,
            num_shards=2,
        )
    )
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    o_coll = oracle_sys.create_collection("c", dim=dim)
    o_coll.create_index("vector", kind="flat")
    coll = system.create_collection("c", dim=dim)
    coll.create_index("vector", kind="flat")
    ingest(o_coll, rng_a, n, dim, batches=3)
    ingest(coll, rng_b, n, dim, batches=3)
    o_coll.flush()
    coll.flush()
    q = np.random.default_rng(9).standard_normal((4, dim)).astype(np.float32)
    oracle = o_coll.search(q, limit=10, staleness_ms=0.0)

    # victim: any node holding sealed replicas; dies on its next scan
    victim_id = next(
        n for n, st in system.query_coord.nodes.items() if st.segments
    )
    victim = system.query_nodes[victim_id]

    def dying(request):
        victim.alive = False
        raise RuntimeError("injected crash mid-request")

    victim.search_request = dying
    res = coll.search(q, limit=10, staleness_ms=0.0)
    pk_a, sc_a = sorted_rows(oracle)
    pk_b, sc_b = sorted_rows(res)
    np.testing.assert_array_equal(pk_a, pk_b)
    np.testing.assert_allclose(sc_a, sc_b, rtol=1e-5)

    # the control loop reassigned: cluster_state reflects the takeover
    cs = system.cluster_state()
    assert victim_id not in cs.live_node_ids
    for p in cs.placement:
        assert victim_id not in p.replicas
        assert not p.under_replicated  # healed back to rf=2 on survivors


def test_node_join_heals_under_replication(rng):
    """Under-replicated (1 node, rf=2) -> node join -> the reconciler heals
    every segment back to full replication."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=1, replication_factor=2, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 600, 8, batches=3)
    coll.flush()
    cs = system.cluster_state()
    assert cs.under_replicated == len(cs.placement) > 0
    system.add_query_node()
    cs = system.cluster_state()
    assert cs.under_replicated == 0
    for p in cs.placement:
        assert len(p.replicas) == 2
    q = rng.standard_normal((2, 8)).astype(np.float32)
    res = coll.search(q, limit=10, staleness_ms=0.0)
    assert (res.pks >= 0).all()


def test_heartbeat_expiry_reassignment_cas_safe(rng):
    """A dead node detected by lease expiry is reassigned through the CAS
    loop even when a concurrent rebalance commits first: the healer retries
    against the winner's committed record instead of clobbering it."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=3, replication_factor=1, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 900, 8, batches=3)
    coll.flush()
    coord = system.query_coord
    victim_id = next(n for n, st in coord.nodes.items() if st.segments)
    survivors = sorted(set(coord.nodes) - {victim_id})
    system.query_nodes[victim_id].alive = False  # crash: no dereg

    # heartbeats stop; the manual clock sails past the lease TTL.  The
    # survivors keep beating (one pump round), the victim cannot.
    system.clock.advance(system.config.heartbeat_ttl_ms + 1)
    system.pump()
    statuses = coord.health.observe()
    assert statuses[victim_id] == "dead"
    assert all(statuses[n] == "healthy" for n in survivors)

    # interleave a competing committed write under the first CAS attempt
    real_cas = system.meta.cas
    raced = {"hit": 0}

    def racing_cas(key, rev, value):
        if key.startswith("assignment/c/") and raced["hit"] == 0:
            raced["hit"] += 1
            cur = system.meta.get(key) or {}
            competitor = dict(cur)
            competitor["nodes"] = [survivors[0]]
            competitor["node"] = survivors[0]
            system.meta.put(key, competitor)  # bumps rev: CAS below loses
        return real_cas(key, rev, value)

    system.meta.cas = racing_cas
    try:
        report = system.query_coord.reconciler.reconcile()
    finally:
        system.meta.cas = real_cas
    system.run_until_idle()
    assert victim_id in report["dead"]
    assert raced["hit"] == 1  # the race actually fired

    # converged: committed records match the in-memory mirror, the dead
    # node is gone everywhere, and every segment kept exactly one replica
    for (c, sid), reps in coord.replica_sets.items():
        rec = system.meta.get(f"assignment/{c}/{sid}")
        assert rec["nodes"] == list(reps)
        assert victim_id not in reps
        assert len(reps) == 1
    q = rng.standard_normal((2, 8)).astype(np.float32)
    res = coll.search(q, limit=10, staleness_ms=0.0)
    assert (res.pks >= 0).all()


def test_drain_preserves_pinned_mvcc_reads(rng):
    """Graceful scale-down moves replicas load-before-release with their
    MVCC epoch pins intact: a read pinned before the drain returns the
    exact same rows afterwards (including through a compaction swap)."""
    system = ManuSystem(
        ManuConfig(
            num_query_nodes=2, replication_factor=1, seal_rows=200,
            compaction_delete_ratio=0.1,
        )
    )
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 800, 8, batches=4)
    coll.flush()
    coll.delete(rng.choice(800, 200, replace=False))
    coll.compact()  # placements now carry visible_from_ts epoch pins
    q = rng.standard_normal((3, 8)).astype(np.float32)
    pinned = coll.search(q, limit=10, staleness_ms=0.0)
    pins_before = {
        p.segment_id: p.visible_from_ts
        for p in system.cluster_state().placement
    }
    assert any(ts > 0 for ts in pins_before.values())

    drained = system.remove_query_node()
    assert drained is not None
    cs = system.cluster_state()
    for p in cs.placement:
        assert drained not in p.replicas
        assert p.visible_from_ts == pins_before[p.segment_id]  # pin intact

    replay = coll.search(q, limit=10, time_travel_ts=pinned.query_ts)
    pk_a, sc_a = sorted_rows(pinned)
    pk_b, sc_b = sorted_rows(replay)
    np.testing.assert_array_equal(pk_a, pk_b)
    np.testing.assert_allclose(sc_a, sc_b, rtol=1e-5)


# ----------------------------------------------------- hedged dispatch


def test_hedge_goes_to_different_replica(rng):
    """With rf=2 a straggler's plan units hedge to the *other* replica:
    the request completes well under the injected delay, exactly."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, replication_factor=2, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 600, 8, batches=3)
    coll.flush()
    q = rng.standard_normal((2, 8)).astype(np.float32)
    oracle = coll.search(q, limit=10, staleness_ms=0.0)

    straggler = next(
        system.query_nodes[n]
        for n, st in system.query_coord.nodes.items()
        if st.segments
    )
    straggler.inject_delay_s = 2.0
    t0 = time.perf_counter()
    res = coll.search(q, limit=10, staleness_ms=0.0, hedge_timeout_s=0.05)
    elapsed = time.perf_counter() - t0
    straggler.inject_delay_s = 0.0
    np.testing.assert_array_equal(sorted_rows(oracle)[0], sorted_rows(res)[0])
    assert elapsed < 1.5  # did not block on the straggler's full delay


# ------------------------------------------------- traced chaos coverage


def _executed_dispatch_leaves(trace):
    """(node, frozenset(segments)) of every dispatch in the span tree that
    actually executed (has a plan_search child), asserting along the way
    that each dispatch's segment scope == its plan's scoped segments ==
    the union of its scan spans' segments."""
    leaves = set()
    for span in trace.walk():
        if span.name not in ("dispatch", "hedge_dispatch"):
            continue
        plans = [c for c in span.children if c.name == "plan_search"]
        if not plans:
            continue  # dispatch died (or is still in flight): no scan ran
        plan_segs = set()
        for p in plans:
            plan_segs |= set(p.segment_ids)
        scan_segs = set()
        for c in span.children:
            if c.name.startswith("scan_"):
                scan_segs |= set(c.segment_ids)
        assert plan_segs == set(span.segment_ids)
        assert scan_segs == plan_segs
        leaves.add((span.node_id, frozenset(span.segment_ids)))
    return leaves


def test_traced_chaos_search_span_tree_covers_every_scan(rng):
    """Kill a node mid-search, then hedge a straggler, both with tracing
    on: the span tree's (segment, node) leaves must equal exactly the
    scans that actually ran — including the failover re-plan's dispatches
    and the hedge that won — and every span's segment-id set must equal
    its plan's scoped segments."""
    dim = 8
    system = ManuSystem(
        ManuConfig(
            num_query_nodes=3, replication_factor=2, seal_rows=200,
            num_shards=2,
        )
    )
    coll = system.create_collection("c", dim=dim)
    ingest(coll, rng, 900, dim, batches=3)
    coll.flush()
    q = rng.standard_normal((3, dim)).astype(np.float32)
    oracle = coll.search(q, limit=10, staleness_ms=0.0)

    # Ground truth: record every (node, scoped segment set) scan that
    # actually completes on any node.
    scanned: list[tuple[str, frozenset]] = []
    for node_id, qn in system.query_nodes.items():
        def wrapped(request, orig=qn.search_request, node_id=node_id):
            out = orig(request)
            assert request.segments is not None  # replica-scoped dispatch
            scanned.append((node_id, frozenset(request.segments)))
            return out

        qn.search_request = wrapped

    # --- phase 1: node dies between planning and scan (failover re-plan)
    victim_id = next(
        n for n, st in system.query_coord.nodes.items() if st.segments
    )
    victim = system.query_nodes[victim_id]

    def dying(request):
        victim.alive = False
        raise RuntimeError("injected crash mid-request")

    victim.search_request = dying
    res = coll.search(
        SearchRequest.single(q, field="vector", k=10, staleness_ms=0.0,
                             trace=True)
    )
    np.testing.assert_array_equal(sorted_rows(oracle)[0], sorted_rows(res)[0])
    trace = res.trace
    assert trace is not None and trace.kind == "search"
    assert trace.spans_named("failover_replan"), "no failover re-plan span"
    assert not trace.spans_named("hedge")
    assert _executed_dispatch_leaves(trace) == set(scanned)
    # the dead node's dispatch is in the tree but has no scan children
    dead_dispatches = [
        s for s in trace.walk()
        if s.name == "dispatch" and s.node_id == victim_id
    ]
    assert dead_dispatches and all(not s.children for s in dead_dispatches)

    # --- phase 2: a straggling survivor forces a hedge that wins
    system.run_until_idle()  # survivors finish loading healed replicas
    scanned.clear()
    straggler_id = next(
        n for n, st in system.query_coord.nodes.items()
        if st.segments and n != victim_id
    )
    straggler = system.query_nodes[straggler_id]
    straggler.inject_delay_s = 0.4
    res2 = coll.search(
        SearchRequest.single(q, field="vector", k=10, staleness_ms=0.0,
                             trace=True),
        hedge_timeout_s=0.05,
    )
    straggler.inject_delay_s = 0.0
    np.testing.assert_array_equal(sorted_rows(oracle)[0], sorted_rows(res2)[0])
    trace2 = res2.trace
    assert trace2.spans_named("hedge"), "no hedge span despite straggler"
    # let the abandoned straggler thread finish so its late scan lands in
    # both the span tree and the ground truth before comparing
    time.sleep(0.6)
    hedge_wins = [
        s for s in trace2.walk()
        if s.name == "hedge_dispatch"
        and any(c.name == "plan_search" for c in s.children)
    ]
    assert hedge_wins, "the hedged re-dispatch never executed"
    assert _executed_dispatch_leaves(trace2) == set(scanned)


# ------------------------------------------------------ cluster-admin API


def test_cluster_state_and_describe_typed_api(rng):
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, replication_factor=2, seal_rows=200)
    )
    coll = system.create_collection("c", dim=8)
    coll.create_index("vector", kind="ivf_flat", params={"nlist": 4})
    ingest(coll, rng, 600, 8, batches=3)
    coll.flush()
    coll.create_partition("hot")

    desc = coll.describe()
    assert desc.name == "c"
    assert {f.name for f in desc.fields} >= {"pk", "vector"}
    assert "hot" in desc.partitions
    assert desc.num_entities == 600
    assert desc.replication_factor == 2
    ix = desc.index_on("vector")
    assert ix is not None and ix.kind == "ivf_flat"
    assert ix.params["nlist"] == 4

    cs = system.cluster_state()
    assert set(cs.live_node_ids) == set(system.query_nodes)
    for ns in cs.nodes:
        assert ns.status == "healthy"
        assert ns.load == len(ns.segments)
    assert cs.node(cs.nodes[0].node_id) is cs.nodes[0]
    with pytest.raises(KeyError):
        cs.node("qn-nope")
    # legacy stats() survives as a facade over the same state
    st = system.stats()
    assert set(st["query_nodes"]) == set(system.query_nodes)
    for n, entry in st["query_nodes"].items():
        assert entry["status"] == "healthy"
    assert st["cluster"]["under_replicated"] == cs.under_replicated


def test_reconcile_rebalances_on_join(rng):
    """Node join: the reconciler's rebalance step converges replica counts
    toward even load without ever dropping below the replication factor."""
    system = ManuSystem(
        ManuConfig(num_query_nodes=2, replication_factor=1, seal_rows=100)
    )
    coll = system.create_collection("c", dim=8)
    ingest(coll, rng, 800, 8, batches=4)
    coll.flush()
    system.add_query_node()
    counts = {
        n: len(st.segments) for n, st in system.query_coord.nodes.items()
    }
    assert max(counts.values()) - min(counts.values()) <= 1
    assert system.cluster_state().under_replicated == 0
